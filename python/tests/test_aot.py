"""AOT path: HLO text generation + manifest consistency.

Uses a throwaway micro-preset so the test is fast and does not depend on
`make artifacts` having run.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as m


@pytest.fixture(scope="module")
def micro_out(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    m.PRESETS["micro"] = m.ModelConfig(
        vocab_size=11, d_model=8, n_heads=2, n_layers=1, d_ff=16,
        seq_len=6, batch_size=2)
    try:
        manifest = aot.lower_preset("micro", str(out))
    finally:
        del m.PRESETS["micro"]
    return str(out), manifest


def test_artifact_files_exist(micro_out):
    out, manifest = micro_out
    for f in manifest["artifacts"].values():
        path = os.path.join(out, f)
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), text[:50]
        # no Mosaic custom-calls (would be unloadable on CPU PJRT)
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()


def test_manifest_matches_model(micro_out):
    _, manifest = micro_out
    cfg = m.ModelConfig(vocab_size=11, d_model=8, n_heads=2, n_layers=1,
                        d_ff=16, seq_len=6, batch_size=2)
    specs = m.param_specs(cfg)
    assert len(manifest["params"]) == len(specs)
    for got, want in zip(manifest["params"], specs):
        assert got["name"] == want.name
        assert tuple(got["shape"]) == want.shape
        assert got["init"] == want.init
    assert manifest["model"]["n_params"] == m.n_params(cfg)
    assert manifest["io"]["train_outputs"][0] == "loss"
    assert len(manifest["io"]["train_outputs"]) == 1 + len(specs)


def test_manifest_json_roundtrip(micro_out):
    out, manifest = micro_out
    on_disk = json.load(open(os.path.join(out, "manifest_micro.json")))
    assert on_disk == manifest


def test_hlo_executes_via_jax_cpu(micro_out):
    """Round-trip the HLO text through XLA's own parser and execute it —
    this is exactly what the rust runtime does via the xla crate."""
    from jax._src.lib import xla_client as xc

    out, manifest = micro_out
    cfg = m.ModelConfig(vocab_size=11, d_model=8, n_heads=2, n_layers=1,
                        d_ff=16, seq_len=6, batch_size=2)
    text = open(os.path.join(out, manifest["artifacts"]["eval"])).read()
    # if the text parses, ids were re-assigned fine
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None

    # numeric cross-check: jax eval_step == direct eval of the lowered fn
    params = m.init_params(cfg, 0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    tok = jax.random.randint(k1, (2, 6), 0, 11)
    tgt = jax.random.randint(k2, (2, 6), 0, 11)
    loss, n_correct = m.eval_step(params, tok, tgt, cfg)
    assert np.isfinite(float(loss))
    assert 0 <= int(n_correct) <= 12

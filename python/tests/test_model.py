"""L2 correctness: transformer LM shapes, loss behaviour, grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile.kernels import ref as ref_lib


CFG = m.ModelConfig(vocab_size=17, d_model=16, n_heads=2, n_layers=2,
                    d_ff=32, seq_len=12, batch_size=3)


def data(cfg, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    tok = jax.random.randint(k1, (cfg.batch_size, cfg.seq_len), 0,
                             cfg.vocab_size)
    tgt = jax.random.randint(k2, (cfg.batch_size, cfg.seq_len), 0,
                             cfg.vocab_size)
    return tok, tgt


def test_param_specs_count_and_order():
    specs = m.param_specs(CFG)
    # 2 emb + 12/layer + 2 final
    assert len(specs) == 2 + 12 * CFG.n_layers + 2
    assert specs[0].name == "tok_emb"
    assert specs[-1].name == "ln_f.bias"
    # names unique
    names = [s.name for s in specs]
    assert len(set(names)) == len(names)
    assert m.n_params(CFG) == sum(int(np.prod(s.shape)) for s in specs)


def test_forward_shape_and_dtype():
    params = m.init_params(CFG, 0)
    tok, _ = data(CFG)
    logits = m.forward(params, tok, CFG)
    assert logits.shape == (CFG.batch_size, CFG.seq_len, CFG.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    params = m.init_params(CFG, 0)
    tok, tgt = data(CFG)
    loss = m.loss_fn(params, tok, tgt, CFG)
    assert abs(float(loss) - np.log(CFG.vocab_size)) < 0.3


def test_train_step_output_arity():
    params = m.init_params(CFG, 0)
    tok, tgt = data(CFG)
    out = m.train_step(params, tok, tgt, CFG)
    assert len(out) == 1 + len(params)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_sgd_overfits_single_batch():
    params = m.init_params(CFG, 0)
    tok, tgt = data(CFG)
    step = jax.jit(lambda ps: m.train_step(ps, tok, tgt, CFG))
    loss0 = float(step(params)[0])
    for _ in range(40):
        out = step(params)
        params = [p - 0.5 * g for p, g in zip(params, out[1:])]
    loss1 = float(m.loss_fn(params, tok, tgt, CFG))
    assert loss1 < loss0 - 1.0, f"{loss0} -> {loss1}"


def test_eval_step_consistent_with_loss():
    params = m.init_params(CFG, 1)
    tok, tgt = data(CFG, 2)
    loss, n_correct = m.eval_step(params, tok, tgt, CFG)
    assert float(loss) == pytest.approx(
        float(m.loss_fn(params, tok, tgt, CFG)), rel=1e-6)
    assert 0 <= int(n_correct) <= CFG.batch_size * CFG.seq_len


def test_eval_perfect_when_targets_are_argmax():
    params = m.init_params(CFG, 3)
    tok, _ = data(CFG, 3)
    logits = m.forward(params, tok, CFG)
    tgt = jnp.argmax(logits, axis=-1)
    _, n_correct = m.eval_step(params, tok, tgt, CFG)
    assert int(n_correct) == CFG.batch_size * CFG.seq_len


def test_causal_dependency_structure():
    """Logits at position i must not depend on tokens after i."""
    params = m.init_params(CFG, 4)
    tok, _ = data(CFG, 4)
    logits = m.forward(params, tok, CFG)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab_size)
    logits2 = m.forward(params, tok2, CFG)
    np.testing.assert_allclose(np.asarray(logits[:, :-1]),
                               np.asarray(logits2[:, :-1]),
                               rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(logits[:, -1]),
                           np.asarray(logits2[:, -1]))


def test_model_uses_pallas_attention_matches_ref_model():
    """Swapping the Pallas attention for the jnp reference must not change
    the forward output (same math, different kernel)."""
    import compile.model as model_mod
    params = m.init_params(CFG, 5)
    tok, _ = data(CFG, 5)
    out_pallas = m.forward(params, tok, CFG)

    orig = model_mod.attention
    model_mod.attention = (
        lambda q, k, v, causal=True: ref_lib.attention_ref(q, k, v, causal))
    try:
        out_ref = m.forward(params, tok, CFG)
    finally:
        model_mod.attention = orig
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_ref),
                               rtol=3e-5, atol=3e-5)


def test_presets_are_valid():
    for name, cfg in m.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        specs = m.param_specs(cfg)
        assert specs, name
        assert m.n_params(cfg) > 0

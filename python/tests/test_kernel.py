"""L1 correctness: Pallas attention kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer — both the
forward kernel and the custom_vjp backward kernel are swept over shapes
and dtypes with hypothesis and asserted allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref

jax.config.update("jax_enable_x64", False)


def rand_qkv(key, b, h, s, d, dtype=jnp.float32, scale=1.0):
    ks = jax.random.split(key, 3)
    return tuple(
        (jax.random.normal(k, (b, h, s, d), jnp.float32) * scale).astype(dtype)
        for k in ks)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 4, 4),
    (2, 2, 16, 8),
    (1, 4, 64, 16),
    (2, 1, 33, 8),   # non-power-of-two sequence
    (1, 2, 7, 5),    # odd everything
])
def test_fwd_matches_ref(b, h, s, d, causal):
    q, k, v = rand_qkv(jax.random.PRNGKey(0), b, h, s, d)
    out = attention(q, k, v, causal)
    ref = attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 4),
    s=st.integers(2, 48),
    d=st.integers(2, 24),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_hypothesis_sweep(b, h, s, d, causal, seed):
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), b, h, s, d)
    out = attention(q, k, v, causal)
    ref = attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.1, 1.0, 5.0]))
def test_fwd_scale_robustness(seed, scale):
    """Softmax must stay stable for large-magnitude scores."""
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), 1, 2, 16, 8, scale=scale)
    out = attention(q, k, v, True)
    ref = attention_ref(q, k, v, True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fwd_bf16():
    q, k, v = rand_qkv(jax.random.PRNGKey(3), 2, 2, 16, 8, dtype=jnp.bfloat16)
    out = attention(q, k, v, True)
    ref = attention_ref(q, k, v, True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2)


def test_causal_masks_future():
    """Changing future K/V rows must not change causal output at row i."""
    q, k, v = rand_qkv(jax.random.PRNGKey(5), 1, 1, 8, 4)
    out = attention(q, k, v, True)
    k2 = k.at[:, :, 5:, :].set(99.0)
    v2 = v.at[:, :, 5:, :].set(-99.0)
    out2 = attention(q, k2, v2, True)
    np.testing.assert_allclose(np.asarray(out[:, :, :5]),
                               np.asarray(out2[:, :, :5]),
                               rtol=1e-5, atol=1e-5)
    # sanity: non-causal output *does* change
    nc1 = attention(q, k, v, False)
    nc2 = attention(q, k2, v2, False)
    assert not np.allclose(np.asarray(nc1[:, :, 0]), np.asarray(nc2[:, :, 0]))


# ---------------------------------------------------------------------------
# backward (custom_vjp kernels vs autodiff of the reference)
# ---------------------------------------------------------------------------


def grads_of(fn, q, k, v, causal):
    def scalar(q, k, v):
        return jnp.sum(jnp.sin(fn(q, k, v, causal)))
    return jax.grad(scalar, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 4, 4),
    (2, 2, 16, 8),
    (1, 2, 32, 16),
    (1, 1, 9, 5),
])
def test_bwd_matches_ref_grads(b, h, s, d, causal):
    q, k, v = rand_qkv(jax.random.PRNGKey(1), b, h, s, d)
    g_kernel = grads_of(attention, q, k, v, causal)
    g_ref = grads_of(attention_ref, q, k, v, causal)
    for name, a, r in zip("qkv", g_kernel, g_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(r), rtol=2e-4, atol=2e-5,
            err_msg=f"d{name} mismatch")


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    s=st.integers(2, 24),
    d=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_hypothesis_sweep(b, h, s, d, seed):
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), b, h, s, d)
    g_kernel = grads_of(attention, q, k, v, True)
    g_ref = grads_of(attention_ref, q, k, v, True)
    for a, r in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=5e-4, atol=5e-5)


def test_bwd_finite_differences():
    """Directional-derivative check, independent of the reference impl."""
    q, k, v = rand_qkv(jax.random.PRNGKey(9), 1, 1, 6, 4)

    def scalar(q):
        return jnp.sum(attention(q, k, v, True) ** 2)

    g = jax.grad(scalar)(q)
    key = jax.random.PRNGKey(10)
    direction = jax.random.normal(key, q.shape, jnp.float32)
    eps = 1e-3
    fd = (scalar(q + eps * direction) - scalar(q - eps * direction)) / (2 * eps)
    analytic = jnp.sum(g * direction)
    np.testing.assert_allclose(np.asarray(fd), np.asarray(analytic),
                               rtol=2e-2, atol=2e-3)


def test_jit_compatible():
    """The kernel must lower inside jit (the AOT path does exactly this)."""
    q, k, v = rand_qkv(jax.random.PRNGKey(11), 1, 2, 8, 4)
    jitted = jax.jit(lambda q, k, v: attention(q, k, v, True))
    np.testing.assert_allclose(np.asarray(jitted(q, k, v)),
                               np.asarray(attention_ref(q, k, v, True)),
                               rtol=2e-5, atol=2e-5)

"""AOT lowering: JAX (L2 + L1) -> HLO text + manifest.json for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs, per model preset, under ``--out`` (default ../artifacts):

  train_<preset>.hlo.txt   train_step(params..., tokens, targets)
                             -> tuple(loss, grads...)
  eval_<preset>.hlo.txt    eval_step(params..., tokens, targets)
                             -> tuple(loss, n_correct)
  manifest_<preset>.json   model dims + ordered param specs + io schema

Run once via ``make artifacts``; python never appears on the training path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_lib


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_preset(preset: str, out_dir: str) -> dict:
    """Lower train/eval for one preset; returns the manifest dict."""
    cfg = model_lib.PRESETS[preset]
    specs = model_lib.param_specs(cfg)

    param_args = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in specs]
    tokens = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len), jnp.int32)
    targets = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len), jnp.int32)

    def train_fn(*args):
        params = list(args[: len(specs)])
        return model_lib.train_step(params, args[-2], args[-1], cfg)

    def eval_fn(*args):
        params = list(args[: len(specs)])
        return model_lib.eval_step(params, args[-2], args[-1], cfg)

    files = {}
    for name, fn in (("train", train_fn), ("eval", eval_fn)):
        lowered = jax.jit(fn).lower(*param_args, tokens, targets)
        text = to_hlo_text(lowered)
        fname = f"{name}_{preset}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[name] = fname
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB hlo text)")

    manifest = {
        "preset": preset,
        "model": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch_size": cfg.batch_size,
            "n_params": model_lib.n_params(cfg),
        },
        "params": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "init": s.init,
                "std": s.std,
            }
            for s in specs
        ],
        "io": {
            # argument order: params..., tokens, targets
            "extra_inputs": [
                {"name": "tokens",
                 "shape": [cfg.batch_size, cfg.seq_len], "dtype": "i32"},
                {"name": "targets",
                 "shape": [cfg.batch_size, cfg.seq_len], "dtype": "i32"},
            ],
            # tuple outputs
            "train_outputs": ["loss"] + [f"grad:{s.name}" for s in specs],
            "eval_outputs": ["loss", "n_correct"],
        },
        "artifacts": files,
    }
    mname = os.path.join(out_dir, f"manifest_{preset}.json")
    with open(mname, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest_{preset}.json "
          f"({manifest['model']['n_params']/1e6:.2f}M params)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifests")
    ap.add_argument("--presets", default="tiny,small",
                    help="comma-separated preset names "
                         f"(available: {','.join(model_lib.PRESETS)})")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for preset in args.presets.split(","):
        preset = preset.strip()
        if preset not in model_lib.PRESETS:
            sys.exit(f"unknown preset {preset!r}; "
                     f"available: {', '.join(model_lib.PRESETS)}")
        print(f"lowering preset {preset} ...")
        lower_preset(preset, args.out)


if __name__ == "__main__":
    main()

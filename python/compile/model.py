"""L2: GPT-style transformer language model in JAX (build-time only).

The federated workload of the paper is "a pre-trained large-scale language
model" trained on WikiText-103 across three clouds. This module defines the
scaled-down stand-in (see DESIGN.md substitution table): a pre-LN causal
transformer LM whose attention runs through the L1 Pallas kernels.

Everything the rust coordinator needs at runtime is lowered AOT by
``aot.py`` into two HLO modules:

  * ``train_step(params..., tokens, targets) -> (loss, grads...)``
  * ``eval_step(params..., tokens, targets)  -> (loss, n_correct)``

Parameters are handled as a *flat ordered list* of leaves; the ordering is
the single source of truth shared with rust via ``manifest.json``
(name/shape/init per leaf, in argument order).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the transformer LM."""

    vocab_size: int = 96
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 256
    seq_len: int = 64
    batch_size: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Named presets shared with the rust side (manifest records the one used).
PRESETS: Dict[str, ModelConfig] = {
    # unit-test scale: seconds per artifact build
    "tiny": ModelConfig(vocab_size=96, d_model=64, n_heads=2, n_layers=2,
                        d_ff=256, seq_len=64, batch_size=8),
    # bench scale for the paper tables
    "small": ModelConfig(vocab_size=96, d_model=128, n_heads=4, n_layers=4,
                         d_ff=512, seq_len=128, batch_size=8),
    # end-to-end example scale (~6.4M params)
    "e2e": ModelConfig(vocab_size=96, d_model=256, n_heads=8, n_layers=8,
                       d_ff=1024, seq_len=128, batch_size=8),
}


# ---------------------------------------------------------------------------
# parameter schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str        # "normal" | "zeros" | "ones"
    std: float = 0.0  # for init == "normal"


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """The flat, ordered parameter schema. Order == HLO argument order."""
    w_std = 0.02
    # residual-branch output projections get the GPT-2 depth-scaled init
    o_std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    specs: List[ParamSpec] = [
        ParamSpec("tok_emb", (cfg.vocab_size, cfg.d_model), "normal", w_std),
        ParamSpec("pos_emb", (cfg.seq_len, cfg.d_model), "normal", w_std),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            ParamSpec(p + "ln1.scale", (cfg.d_model,), "ones"),
            ParamSpec(p + "ln1.bias", (cfg.d_model,), "zeros"),
            ParamSpec(p + "attn.wq", (cfg.d_model, cfg.d_model), "normal", w_std),
            ParamSpec(p + "attn.wk", (cfg.d_model, cfg.d_model), "normal", w_std),
            ParamSpec(p + "attn.wv", (cfg.d_model, cfg.d_model), "normal", w_std),
            ParamSpec(p + "attn.wo", (cfg.d_model, cfg.d_model), "normal", o_std),
            ParamSpec(p + "ln2.scale", (cfg.d_model,), "ones"),
            ParamSpec(p + "ln2.bias", (cfg.d_model,), "zeros"),
            ParamSpec(p + "mlp.w1", (cfg.d_model, cfg.d_ff), "normal", w_std),
            ParamSpec(p + "mlp.b1", (cfg.d_ff,), "zeros"),
            ParamSpec(p + "mlp.w2", (cfg.d_ff, cfg.d_model), "normal", o_std),
            ParamSpec(p + "mlp.b2", (cfg.d_model,), "zeros"),
        ]
    specs += [
        ParamSpec("ln_f.scale", (cfg.d_model,), "ones"),
        ParamSpec("ln_f.bias", (cfg.d_model,), "zeros"),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Initialize the flat parameter list (used by python tests; the rust
    runtime re-implements the same init from the manifest)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.init == "normal":
            params.append(
                jax.random.normal(sub, spec.shape, jnp.float32) * spec.std)
        elif spec.init == "zeros":
            params.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.init == "ones":
            params.append(jnp.ones(spec.shape, jnp.float32))
        else:  # pragma: no cover - schema is closed
            raise ValueError(spec.init)
    return params


def n_params(cfg: ModelConfig) -> int:
    total = 0
    for spec in param_specs(cfg):
        n = 1
        for d in spec.shape:
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _unpack(params: List[jnp.ndarray], cfg: ModelConfig):
    """Flat list -> name-addressable dict, following param_specs order."""
    return {spec.name: p for spec, p in zip(param_specs(cfg), params)}


def forward(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """tokens: i32 (B, S) -> logits f32 (B, S, V)."""
    p = _unpack(params, cfg)
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layer_norm(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        q = h @ p[pre + "attn.wq"]
        k = h @ p[pre + "attn.wk"]
        v = h @ p[pre + "attn.wv"]
        # (B, S, D) -> (B, H, S, Dh) for the Pallas kernel
        def split(t):
            return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(
                0, 2, 1, 3)
        o = attention(split(q), split(k), split(v), True)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + o @ p[pre + "attn.wo"]

        h = _layer_norm(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        h = jax.nn.gelu(h @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + h @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]

    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    # tied output head
    return x @ p["tok_emb"].T


def loss_fn(params: List[jnp.ndarray], tokens: jnp.ndarray,
            targets: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Mean cross-entropy over all (B, S) positions."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------


def train_step(params: List[jnp.ndarray], tokens: jnp.ndarray,
               targets: jnp.ndarray, cfg: ModelConfig):
    """-> (loss, *grads). The rust side owns the optimizer update."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(ps, tokens, targets, cfg))(params)
    return (loss, *grads)


def eval_step(params: List[jnp.ndarray], tokens: jnp.ndarray,
              targets: jnp.ndarray, cfg: ModelConfig):
    """-> (loss, n_correct) where n_correct counts top-1 next-token hits."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    pred = jnp.argmax(logits, axis=-1)
    n_correct = jnp.sum((pred == targets).astype(jnp.int32))
    return jnp.mean(nll), n_correct

"""L1: fused multi-head self-attention as Pallas kernels (fwd + bwd).

The paper's compute hot-spot (the transformer's attention) is written as a
pair of Pallas kernels wired together with ``jax.custom_vjp`` so the whole
fwd+bwd trains through the kernels and lowers into the single AOT HLO module
the rust runtime executes.

TPU adaptation (see DESIGN.md §Hardware-Adaptation):
  * grid = (batch * heads,): one grid cell owns the full (S, D) Q/K/V tiles
    in VMEM. For the model sizes this repo targets (S <= 256, D <= 64) the
    per-cell footprint is Q+K+V+O+dO+scratch ~= 6*S*D*4B + S*S*4B < 1 MiB,
    far under the ~16 MiB VMEM budget — no inner K/V loop needed.
  * the (S,D)x(D,S) and (S,S)x(S,D) matmuls are MXU-shaped with
    ``preferred_element_type=jnp.float32``.
  * ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; interpret mode lowers to plain HLO so the same module runs
    under the rust CPU client. Real-TPU performance is *estimated* in
    DESIGN.md §Perf, not measured.

The forward kernel saves the per-row log-sum-exp so the backward kernel can
re-materialize the probability matrix without re-running the softmax
reduction (the standard flash-attention recompute formulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret mode is mandatory on this image (CPU PJRT): real TPU lowering
# emits a Mosaic custom-call the CPU plugin rejects.
INTERPRET = True

_NEG_INF = -1e30


def _causal_mask(s: int) -> jnp.ndarray:
    """(s, s) additive mask: 0 on/below the diagonal, -inf above."""
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    return jnp.where(row >= col, 0.0, _NEG_INF).astype(jnp.float32)


def _mxu_matmul(a, b, dims):
    """dot_general with f32 accumulate — the MXU-shaped contraction."""
    return jax.lax.dot_general(
        a, b, dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale: float,
                     causal: bool):
    """One grid cell = one (batch, head) pair; full sequence in VMEM.

    Block shapes per cell: q/k/v/o: (1, S, D), lse: (1, S).
    """
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    # (S, S) score matrix on the MXU: s = q k^T * scale
    s = _mxu_matmul(q, k, ((1,), (1,))) * scale
    if causal:
        s = s + _causal_mask(q.shape[0])

    # numerically stable softmax with saved log-sum-exp
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    o = _mxu_matmul(p, v, ((1,), (0,))) / l
    o_ref[0] = o.astype(o_ref.dtype)
    lse_ref[0] = (m[:, 0] + jnp.log(l[:, 0])).astype(lse_ref.dtype)


def _attn_fwd_call(q, k, v, *, scale: float, causal: bool):
    """q/k/v: (BH, S, D) -> (o: (BH, S, D), lse: (BH, S))."""
    bh, s, d = q.shape
    block = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    lse_block = pl.BlockSpec((1, s), lambda i: (i, 0))
    kernel = functools.partial(_attn_fwd_kernel, scale=scale, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[block, block, block],
        out_specs=[block, lse_block],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward kernel
# ---------------------------------------------------------------------------


def _attn_bwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref,
                     dq_ref, dk_ref, dv_ref, *, scale: float, causal: bool):
    """Recompute-formulation backward for one (batch, head) cell."""
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    o = o_ref[0].astype(jnp.float32)
    lse = lse_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)

    s = _mxu_matmul(q, k, ((1,), (1,))) * scale
    if causal:
        s = s + _causal_mask(q.shape[0])
    # p is the exact softmax matrix (re-materialized from the saved lse)
    p = jnp.exp(s - lse[:, None])

    # dV = P^T dO
    dv = _mxu_matmul(p, do, ((0,), (0,)))
    # dP = dO V^T
    dp = _mxu_matmul(do, v, ((1,), (1,)))
    # delta_i = sum_j dO_ij O_ij  (softmax jacobian diagonal term)
    delta = jnp.sum(do * o, axis=1, keepdims=True)
    ds = p * (dp - delta)
    # dQ = dS K * scale ; dK = dS^T Q * scale
    dq = _mxu_matmul(ds, k, ((1,), (0,))) * scale
    dk = _mxu_matmul(ds, q, ((0,), (0,))) * scale

    dq_ref[0] = dq.astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _attn_bwd_call(q, k, v, o, lse, do, *, scale: float, causal: bool):
    bh, s, d = q.shape
    block = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    lse_block = pl.BlockSpec((1, s), lambda i: (i, 0))
    kernel = functools.partial(_attn_bwd_kernel, scale=scale, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[block, block, block, block, lse_block, block],
        out_specs=[block, block, block],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        ],
        interpret=INTERPRET,
    )(q, k, v, o, lse, do)


# ---------------------------------------------------------------------------
# public API: custom_vjp attention over (B, H, S, D)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Fused multi-head attention. q/k/v: (B, H, S, D) -> (B, H, S, D).

    Forward and backward both run as Pallas kernels; gradients w.r.t.
    q, k and v flow through ``jax.custom_vjp``.
    """
    out, _ = _attention_fwd_rule(q, k, v, causal)
    return out


def _attention_fwd_rule(q, k, v, causal: bool):
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    o, lse = _attn_fwd_call(qf, kf, vf, scale=scale, causal=causal)
    return o.reshape(b, h, s, d), (qf, kf, vf, o, lse, (b, h, s, d))


def _attention_bwd_rule(causal: bool, res, g):
    qf, kf, vf, o, lse, (b, h, s, d) = res
    scale = 1.0 / (d ** 0.5)
    gf = g.reshape(b * h, s, d)
    dq, dk, dv = _attn_bwd_call(qf, kf, vf, o, lse, gf,
                                scale=scale, causal=causal)
    return (dq.reshape(b, h, s, d), dk.reshape(b, h, s, d),
            dv.reshape(b, h, s, d))


attention.defvjp(_attention_fwd_rule, _attention_bwd_rule)

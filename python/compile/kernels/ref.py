"""Pure-jnp oracle for the Pallas kernels.

Everything here is reference-quality, not performance-quality: the pytest
suite asserts the Pallas kernels (and their custom_vjp gradients) match
these functions to tight tolerances.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True):
    """Reference multi-head attention. q/k/v: (B, H, S, D)."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        row = jnp.arange(s)[:, None]
        col = jnp.arange(s)[None, :]
        scores = jnp.where(row >= col, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v).astype(q.dtype)


def attention_lse_ref(q, k, v, causal: bool = True):
    """Reference per-row log-sum-exp, matching the fwd kernel's save."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        row = jnp.arange(s)[:, None]
        col = jnp.arange(s)[None, :]
        scores = jnp.where(row >= col, scores, _NEG_INF)
    return jax.scipy.special.logsumexp(scores, axis=-1)

//! The paper's cost claim, in dollars: price a flat star against the
//! two-level hierarchy at `paper_default_scaled(16)` (48 nodes) with the
//! paper-default price book, and let the placement optimizer pick the
//! leader cloud.
//!
//! Asserts (CI runs this — a regression fails the build):
//!
//! * hierarchical egress dollars ≤ 1/4 of the flat star's,
//! * `placement: auto` never costs more per round than the *worst*
//!   fixed leader choice,
//! * dollars decompose exactly (total == sum of per-cloud entries).
//!
//! Runs on the mock backend (no artifacts needed):
//!
//!     cargo run --release --example cost_report

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::cost::Placement;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::report;
use crossfed::runtime::MockRuntime;

const ROUNDS: usize = 4;
const NODES_PER_CLOUD: usize = 16;

/// Params big enough that update traffic dwarfs the one-off shard
/// distribution.
fn init_params() -> ParamSet {
    let a: Vec<f32> = (0..8192).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let b: Vec<f32> = (0..4096).map(|i| ((i % 89) as f32) * -0.01 + 0.4).collect();
    ParamSet { leaves: vec![a, b] }
}

fn cfg(name: &str, hier: bool, placement: Placement) -> ExperimentConfig {
    let mut c = preset("paper-hier-cost").expect("builtin preset");
    c.name = name.to_string();
    c.hierarchical = hier;
    c.placement = placement;
    c.rounds = ROUNDS;
    c.eval_every = 2;
    c.eval_batches = 1;
    c.local_steps = 2;
    c.local_lr = 3.0;
    c.server_lr = 3.0;
    c.target_loss = None;
    // enough docs that every dirichlet shard is populated at 48 nodes
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

/// Returns (result, egress $/round over the training rounds, leader cloud).
fn run(c: ExperimentConfig) -> anyhow::Result<(RunResult, f64, usize)> {
    let cluster = ClusterSpec::paper_default_scaled(NODES_PER_CLOUD);
    let backend = MockRuntime::new(0.4);
    let mut coord = Coordinator::new(c, cluster, &backend, init_params(), 4, 16)?;
    let leader_cloud = coord.leader_cloud();
    let r = coord.run()?;
    let egress: f64 =
        r.history.iter().map(|h| h.cost.egress_total_usd()).sum();
    Ok((r, egress / ROUNDS as f64, leader_cloud))
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();

    let (star, star_usd, _) = run(cfg("star", false, Placement::Fixed(0)))?;
    let mut fixed = Vec::new();
    for c in 0..3 {
        fixed.push(run(cfg(&format!("hier-fixed{c}"), true, Placement::Fixed(c)))?);
    }
    let (auto, auto_usd, auto_cloud) = run(cfg("hier-auto", true, Placement::Auto))?;

    println!(
        "{:>12} {:>8} {:>16} {:>12}",
        "mode", "leader", "egress $/round", "total $"
    );
    println!(
        "{:>12} {:>8} {:>16.4} {:>12.2}",
        "star", 0, star_usd, star.cost_usd()
    );
    for (c, (r, usd, _)) in fixed.iter().enumerate() {
        println!("{:>12} {:>8} {:>16.4} {:>12.2}", format!("hier-fix{c}"), c, usd, r.cost_usd());
    }
    println!(
        "{:>12} {:>8} {:>16.4} {:>12.2}",
        "hier-auto", auto_cloud, auto_usd, auto.cost_usd()
    );

    let rrefs: Vec<&RunResult> =
        std::iter::once(&star).chain(fixed.iter().map(|(r, _, _)| r)).chain(std::iter::once(&auto)).collect();
    println!("\n{}", report::table_cost(&rrefs));
    println!("{}", report::table_cost_clouds(&auto));
    report::save("cost_report.json", &auto.to_json().to_string_pretty());

    // --- the cost story, asserted --------------------------------------
    // 1. hierarchy's egress dollars at 1/4 or better of the flat star
    let (_, hier0_usd, _) = fixed[0];
    anyhow::ensure!(
        hier0_usd * 4.0 <= star_usd,
        "hierarchy lost its dollar advantage: star ${star_usd:.4}/round \
         vs hier ${hier0_usd:.4}/round"
    );
    println!(
        "\negress dollars: hierarchy at {:.1}x below the flat star",
        star_usd / hier0_usd.max(1e-12)
    );
    // 2. auto placement is never worse than the worst fixed choice
    let worst = fixed
        .iter()
        .map(|&(_, usd, _)| usd)
        .fold(f64::MIN, f64::max);
    anyhow::ensure!(
        auto_usd <= worst,
        "auto placement (cloud {auto_cloud}, ${auto_usd:.4}/round) costs \
         more than the worst fixed leader (${worst:.4}/round)"
    );
    // ...and exactly matches the fixed run for its chosen cloud
    let (_, chosen_usd, _) = fixed[auto_cloud];
    anyhow::ensure!(
        (auto_usd - chosen_usd).abs() < 1e-12,
        "auto != fixed:{auto_cloud}: ${auto_usd} vs ${chosen_usd}"
    );
    // 3. dollars decompose exactly
    let mut manual = 0.0f64;
    for c in 0..auto.cost.n_clouds() {
        manual += auto.cost.compute_usd[c];
        for e in &auto.cost.egress_usd[c] {
            manual += e;
        }
    }
    anyhow::ensure!(
        manual.to_bits() == auto.cost.total_usd().to_bits(),
        "cost breakdown does not decompose exactly"
    );
    println!("auto placement picked cloud {auto_cloud}; all cost assertions hold");
    Ok(())
}

//! Heterogeneity scenario: strongly non-IID shards + skewed compute.
//!
//! The paper's central claim (§3.3, Tables 2–3) is that dynamic weighted
//! and gradient aggregation beat FedAvg when "data distribution across
//! cloud platforms varies significantly". This example constructs that
//! regime explicitly — Dirichlet(0.1) topic skew, 4x compute spread — and
//! prints the head-to-head.
//!
//!     cargo run --release --example heterogeneous_clouds

use crossfed::aggregation::AggregationKind;
use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::data::{dirichlet_shards, skew_tv, SyntheticCorpus};
use crossfed::model::{Manifest, ParamSet};
use crossfed::partition::PartitionStrategy;
use crossfed::runtime::StepRuntime;
use crossfed::util::bytes::human_duration;

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();
    let manifest = Manifest::load(std::path::Path::new("artifacts"), "tiny")?;
    let backend = StepRuntime::load(&manifest)?;

    // show how skewed the shards actually are
    let base = preset("paper-fedavg").unwrap();
    let corpus = SyntheticCorpus::generate(&base.corpus);
    for alpha in [100.0, 0.3, 0.1] {
        let shards = dirichlet_shards(&corpus, 3, alpha, 42);
        println!(
            "dirichlet alpha={alpha:>6}: topic-skew TV={:.3}  shard sizes={:?}",
            skew_tv(&shards),
            shards.iter().map(|s| s.doc_ids.len()).collect::<Vec<_>>()
        );
    }
    println!();

    let cluster = ClusterSpec::heterogeneous(3, 4.0);
    let mut rows = Vec::new();
    for agg in ["fedavg", "dynamic", "gradient"] {
        let mut cfg = preset("paper-fedavg").unwrap();
        cfg.name = agg.to_string();
        cfg.aggregation = AggregationKind::parse(agg).unwrap();
        cfg.partition = PartitionStrategy::DirichletSkew { alpha: 0.1 };
        cfg.rounds = 40;
        cfg.target_loss = None;
        cfg.eval_every = 5;
        let init = ParamSet::init(&manifest, cfg.seed);
        let mut coord = Coordinator::new(
            cfg,
            cluster.clone(),
            &backend,
            init,
            manifest.model.batch_size,
            manifest.model.seq_len,
        )?;
        let r = coord.run()?;
        println!(
            "{agg:<10} eval_loss={:.3} acc={:.1}% sim={}",
            r.final_eval_loss,
            r.acc_pct(),
            human_duration(r.sim_secs)
        );
        rows.push((agg.to_string(), r));
    }

    // the paper's ordering must hold in this regime
    let loss = |name: &str| {
        rows.iter().find(|(n, _)| n == name).unwrap().1.final_eval_loss
    };
    println!(
        "\nordering check (paper Table 3): gradient {:.3} <= dynamic {:.3} <= fedavg {:.3}",
        loss("gradient"),
        loss("dynamic"),
        loss("fedavg")
    );
    Ok(())
}

//! Cross-cloud serving day: 1M+ requests from a diurnal population
//! against the trained model, replicated on six clouds in two regions.
//!
//! Exercises the serving subsystem end-to-end on the arena event engine
//! and the routed WAN (CI executes this): a population skewed toward
//! cloud 0 (region 0, expensive compute) generates over a million
//! requests in one simulated day; one replica per cloud serves them
//! under each routing policy against a deliberately asymmetric price
//! book (cloud 4, region 1, is by far the cheapest accelerator). The
//! example asserts the economics the paper's "broad application
//! prospects" framing rests on:
//!
//!   1. the latency-optimal placement differs from the cost-optimal one
//!      (latency routing concentrates near the users, cost routing on
//!      the cheap cloud);
//!   2. blended routing dominates both pure policies on the weighted
//!      objective it internalizes (J = w·lat/lat_ref + (1−w)·$/usd_ref);
//!   3. two repeat runs are bit-identical — the serving simulator is a
//!      pure function of its seed, like every other subsystem.
//!
//!     cargo run --release --example serve_cross_cloud

use crossfed::cluster::ClusterSpec;
use crossfed::cost::PriceBook;
use crossfed::report;
use crossfed::serve::{self, RoutePolicy, ServeConfig, ServeResult, TrafficSpec};

const N_CLOUDS: usize = 6; // clouds 0-3 in region0, clouds 4-5 in region1
// 1.6M requests/day averages 18.5 req/s — deliberately above the cheap
// replica's ~17.4 req/s full-batch capacity, so pure cost routing
// (which sends every request there) saturates and its queue melts down,
// while any policy that spreads load stays comfortable.
const USERS: u64 = 1_600_000;
const BLEND_W: f64 = 0.5;
const LAT_REF_SECS: f64 = 0.15;
const USD_REF: f64 = 3e-5; // $30 per million requests

fn config(route: RoutePolicy) -> ServeConfig {
    // cloud 4 is ~3x cheaper than the user-heavy clouds: cost routing
    // must leave the users' region to win
    let mut book = PriceBook::uniform(3.2, 0.08);
    book.name = "serve-asym".into();
    book.compute_per_node_hour = vec![4.5, 3.9, 3.6, 3.3, 1.2, 2.8];
    ServeConfig {
        name: format!("serve-{}", route.name()),
        route,
        traffic: TrafficSpec { users: USERS, reqs_per_user_day: 1.0, ..TrafficSpec::default() },
        price_book: book,
        lat_ref_secs: LAT_REF_SECS,
        usd_ref: USD_REF,
        ..ServeConfig::default()
    }
}

fn run(route: RoutePolicy) -> anyhow::Result<ServeResult> {
    let cluster = ClusterSpec::scaled(N_CLOUDS, &[1]);
    let r = serve::run(&config(route), &cluster)?;
    println!(
        "{:<18} req={:<8} p50={:>6.1}ms p99={:>7.1}ms maxq={:<5} \
         stale={:>6.0}s busiest=cloud{} ${:>6.2}/M-req  J={:.3}",
        r.policy,
        r.requests,
        r.p50_ms,
        r.p99_ms,
        r.max_queue_depth,
        r.staleness_mean_secs,
        r.busiest_replica(),
        r.usd_per_million(),
        objective(&r),
    );
    Ok(r)
}

/// The shared weighted objective (same normalizers the blended router
/// scores with, so the comparison is on blended's own yardstick).
fn objective(r: &ServeResult) -> f64 {
    r.objective(BLEND_W, LAT_REF_SECS * 1e3, USD_REF * 1e6)
}

fn main() -> anyhow::Result<()> {
    println!("== serving day: {N_CLOUDS} clouds / 2 regions, {USERS} users, diurnal +/-60% ==");
    let lat = run(RoutePolicy::Latency)?;
    let cost = run(RoutePolicy::Cost)?;
    let blend = run(RoutePolicy::Blended(BLEND_W))?;

    // -- scale: a real serving day on the event engine
    assert!(lat.requests >= 1_000_000, "expected 1M+ requests/day, got {}", lat.requests);
    assert_eq!(lat.requests, cost.requests, "same population every run");
    assert_eq!(lat.requests, blend.requests, "same population every run");

    // -- 1. latency-optimal placement != cost-optimal placement
    let (lat_hot, cost_hot) = (lat.busiest_replica(), cost.busiest_replica());
    assert_ne!(
        lat_hot, cost_hot,
        "latency routing must concentrate near the users while cost \
         routing concentrates on the cheap cloud"
    );
    assert_eq!(cost_hot, 4, "cloud 4 is priced to win every cost argmin");
    assert!(
        cost.usd_per_million() < lat.usd_per_million(),
        "cost routing must be cheaper: ${:.2}/M vs ${:.2}/M",
        cost.usd_per_million(),
        lat.usd_per_million()
    );
    assert!(
        lat.p50_ms < cost.p50_ms,
        "latency routing must be faster at the median: {:.1}ms vs {:.1}ms",
        lat.p50_ms,
        cost.p50_ms
    );

    // -- 2. blended dominates both pure policies on the weighted objective
    let (j_lat, j_cost, j_blend) = (objective(&lat), objective(&cost), objective(&blend));
    assert!(
        j_blend < j_lat && j_blend < j_cost,
        "blended must dominate: J(blend)={j_blend:.3} vs \
         J(latency)={j_lat:.3}, J(cost)={j_cost:.3}"
    );
    println!(
        "blended dominates: J={j_blend:.3} < min(J_latency={j_lat:.3}, \
         J_cost={j_cost:.3})"
    );

    // -- 3. repeats are bit-identical
    let cluster = ClusterSpec::scaled(N_CLOUDS, &[1]);
    let again = serve::run(&config(RoutePolicy::Latency), &cluster)?;
    assert_eq!(again.requests, lat.requests, "repeat: request count");
    assert_eq!(again.wire_bytes, lat.wire_bytes, "repeat: wire bytes");
    assert_eq!(again.requests_by_replica, lat.requests_by_replica, "repeat: placement");
    for (a, b, what) in [
        (again.p50_ms, lat.p50_ms, "p50"),
        (again.p99_ms, lat.p99_ms, "p99"),
        (again.mean_ms, lat.mean_ms, "mean latency"),
        (again.staleness_mean_secs, lat.staleness_mean_secs, "staleness"),
        (again.cost.total_usd(), lat.cost.total_usd(), "dollars"),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "repeat: {what} must be bit-identical");
    }
    println!("repeat run bit-identical (placement, latency, dollars)");

    let rrefs = [&lat, &cost, &blend];
    println!("\n{}", report::table_serve(&rrefs));
    report::save(
        "serve_cross_cloud.txt",
        &format!(
            "{}\nJ(latency)={j_lat:.4} J(cost)={j_cost:.4} \
             J(blended)={j_blend:.4}\n",
            report::table_serve(&rrefs)
        ),
    );
    Ok(())
}

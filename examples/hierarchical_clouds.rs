//! Hierarchical vs flat-star aggregation across scaled clouds.
//!
//! The paper treats the inter-cloud WAN as the bottleneck; the standard
//! scaling move is to reduce inside each cloud over fat intra-region
//! links and exchange only one partial aggregate per cloud across
//! regions. This example sweeps `nodes_per_cloud ∈ {1, 4, 16}` on the
//! paper's 3 clouds and prints, for each scale, the per-round
//! inter-region WAN bytes and simulated round time of both modes —
//! star traffic grows linearly with the node count while hierarchical
//! traffic stays flat at one partial per cloud.
//!
//! Runs on the mock backend (no artifacts needed — CI executes this):
//!
//!     cargo run --release --example hierarchical_clouds

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::model::ParamSet;
use crossfed::netsim::LinkClass;
use crossfed::runtime::MockRuntime;
use crossfed::util::bytes::human_bytes;

const ROUNDS: usize = 2;

/// Returns (inter-region bytes/round, intra-AZ bytes/round, sim secs/round,
/// final eval loss).
fn run(nodes_per_cloud: usize, hierarchical: bool) -> anyhow::Result<(u64, u64, f64, f32)> {
    let mut cfg = preset("quick").expect("builtin preset");
    cfg.name = format!(
        "{}-x{nodes_per_cloud}",
        if hierarchical { "hier" } else { "star" }
    );
    cfg.hierarchical = hierarchical;
    cfg.rounds = ROUNDS;
    cfg.eval_every = 1;
    cfg.eval_batches = 1;
    cfg.local_lr = 3.0;
    cfg.server_lr = 3.0;
    cfg.target_loss = None;
    // enough docs that every dirichlet shard is populated at 48 nodes
    cfg.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };

    let cluster = ClusterSpec::paper_default_scaled(nodes_per_cloud);
    let backend = MockRuntime::new(0.4);
    let init = ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] };
    let mut coord = Coordinator::new(cfg, cluster, &backend, init, 4, 16)?;
    // measure round traffic only (shard distribution is mode-independent)
    let inter0 = coord.inter_region_wire_bytes();
    let intra0 = coord.wire_bytes_class(LinkClass::IntraAz);
    let sim0 = coord.sim_secs();
    let r = coord.run()?;
    Ok((
        (coord.inter_region_wire_bytes() - inter0) / ROUNDS as u64,
        (coord.wire_bytes_class(LinkClass::IntraAz) - intra0) / ROUNDS as u64,
        (r.sim_secs - sim0) / ROUNDS as f64,
        r.final_eval_loss,
    ))
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();
    println!(
        "{:>5} {:>6} {:>14} {:>14} {:>12} {:>10}",
        "nodes", "mode", "inter-region/r", "intra-az/r", "sim secs/r", "eval loss"
    );
    for nodes_per_cloud in [1usize, 4, 16] {
        let mut inter = [0u64; 2];
        for (i, hier) in [false, true].into_iter().enumerate() {
            let (ir, ia, secs, loss) = run(nodes_per_cloud, hier)?;
            inter[i] = ir;
            println!(
                "{:>5} {:>6} {:>14} {:>14} {:>12.1} {:>10.3}",
                nodes_per_cloud * 3,
                if hier { "hier" } else { "star" },
                human_bytes(ir),
                human_bytes(ia),
                secs,
                loss
            );
        }
        let reduction = inter[0] as f64 / inter[1].max(1) as f64;
        println!("      -> hierarchical sends {reduction:.1}x fewer inter-region bytes\n");
        // topology regression guard: CI fails if the hierarchy stops
        // paying off at scale
        if nodes_per_cloud >= 4 {
            anyhow::ensure!(
                inter[1] * 4 <= inter[0],
                "hierarchical mode lost its inter-region advantage at \
                 {nodes_per_cloud} nodes/cloud: star {} vs hier {}",
                inter[0],
                inter[1]
            );
        }
    }
    Ok(())
}

//! Privacy-hardened federated training: AES-sealed transport, pairwise-
//! mask secure aggregation, and differential privacy with an (ε, δ)
//! accountant — the paper's §3.1 "Ensure Data Security" phase plus its
//! encryption / differential-privacy discussion, end to end.
//!
//!     cargo run --release --example private_training

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::crypto::he_cost;
use crossfed::model::{Manifest, ParamSet};
use crossfed::privacy::DpConfig;
use crossfed::runtime::StepRuntime;
use crossfed::util::bytes::{human_bytes, human_duration};

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();
    let manifest = Manifest::load(std::path::Path::new("artifacts"), "tiny")?;
    let backend = StepRuntime::load(&manifest)?;
    let cluster = ClusterSpec::paper_default();

    let variants: Vec<(&str, Box<dyn Fn(&mut crossfed::config::ExperimentConfig)>)> = vec![
        ("baseline (no crypto)", Box::new(|c| {
            c.encrypt = false;
        })),
        ("aes transport", Box::new(|c| {
            c.encrypt = true;
        })),
        ("aes + secure-agg", Box::new(|c| {
            c.encrypt = true;
            c.secure_agg = true;
        })),
        // NOTE on the noise multiplier: with only N=3 cross-silo clients
        // there is no averaging over thousands of updates, so meaningful
        // (ε < 10) DP noise would destroy this small model. z=0.02 shows
        // the full mechanism (clip → noise → accountant) with honest —
        // i.e. weak — ε, which we report as such.
        ("aes + secure-agg + dp", Box::new(|c| {
            c.encrypt = true;
            c.secure_agg = true;
            c.dp = DpConfig { clip_norm: 2.0, noise_multiplier: 0.02, delta: 1e-5 };
        })),
    ];

    println!("{:<24} {:>10} {:>10} {:>8} {:>8} {:>10}",
             "variant", "eval_loss", "acc", "comm", "time", "epsilon");
    for (name, tweak) in variants {
        let mut cfg = preset("paper-fedavg").unwrap();
        cfg.name = name.to_string();
        cfg.rounds = 30;
        cfg.target_loss = None;
        cfg.eval_every = 5;
        tweak(&mut cfg);
        cfg.validate()?;
        let init = ParamSet::init(&manifest, cfg.seed);
        let mut coord = Coordinator::new(
            cfg,
            cluster.clone(),
            &backend,
            init,
            manifest.model.batch_size,
            manifest.model.seq_len,
        )?;
        let r = coord.run()?;
        let eps = r.history.last().map(|h| h.epsilon).unwrap_or(0.0);
        println!(
            "{name:<24} {:>10.3} {:>9.1}% {:>8} {:>8} {:>10}",
            r.final_eval_loss,
            r.acc_pct(),
            human_bytes(r.wire_bytes),
            human_duration(r.sim_secs),
            if eps > 0.0 { format!("{eps:.1}") } else { "-".into() },
        );
    }

    // price the homomorphic-encryption alternative the paper names
    let n = manifest.model.n_params;
    let he = he_cost();
    println!(
        "\nfor reference, Paillier-2048 HE on this model ({n} params):\n  \
         {} per update on the wire (vs {} masked) and ~{} extra per round",
        human_bytes(he.wire_bytes(n)),
        human_bytes((n * 4) as u64),
        human_duration(he.round_secs(3, n)),
    );
    println!("masking-based secure aggregation delivers the same sum-only \
              visibility at ~zero cost — see DESIGN.md §Substitutions");
    Ok(())
}

//! Kill the coordinator mid-run, resume from the write-ahead log,
//! finish bit-identically.
//!
//! The run attaches a WAL (`--wal DIR` on the CLI; `cfg.wal_dir` here)
//! and a `coordinator-crash:at=3` fault: at the start of round 3 the
//! leader "process" dies — after the round-2 record was fsynced, before
//! round 3 touched anything. `Coordinator::resume` reopens the log,
//! validates the header (experiment, seed, worker count, model shape),
//! replays the parameter chain (periodic snapshots + XOR-of-bit-pattern
//! deltas) and every RNG/ledger/channel state, strips the spent crash
//! event, and continues at round 3. The example asserts the stitched
//! run equals an uninterrupted one bit-for-bit — losses, simulated
//! time, per-class wire bytes and the dollar bill — and prints what
//! the durability costs per round in log bytes.
//!
//! Runs on the mock backend (no artifacts needed — CI executes this):
//!
//!     cargo run --release --example crash_resume

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::{Coordinator, CoordinatorCrashed};
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::FaultPlan;
use crossfed::runtime::MockRuntime;
use crossfed::util::bytes::human_bytes;

const ROUNDS: usize = 6;
const CRASH_AT: usize = 3;

fn cfg(faults: &str) -> anyhow::Result<ExperimentConfig> {
    let mut c = preset("quick").expect("builtin preset");
    c.rounds = ROUNDS;
    c.eval_every = 2;
    c.local_lr = 3.0;
    c.faults = FaultPlan::parse(faults)?;
    Ok(c)
}

fn init() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] }
}

fn main() -> anyhow::Result<()> {
    let backend = MockRuntime::new(0.4);
    let cluster = ClusterSpec::paper_default;
    // a straggler fault keeps the WAN/fault machinery active across the
    // crash boundary — resume must restore its effects too
    let base_faults = "node-slowdown:node=1,at=1,factor=2";

    // --- the uninterrupted reference run (no WAL)
    let baseline = Coordinator::new(
        cfg(base_faults)?,
        cluster(),
        &backend,
        init(),
        4,
        16,
    )?
    .run()?;

    // --- the crashing run: WAL attached, leader dies at round CRASH_AT
    let wal_dir = std::env::temp_dir().join("crossfed-example-wal");
    std::fs::remove_dir_all(&wal_dir).ok();
    let mut c = cfg(&format!(
        "{base_faults};coordinator-crash:at={CRASH_AT}"
    ))?;
    c.wal_dir = Some(wal_dir.to_string_lossy().into_owned());

    let mut coord =
        Coordinator::new(c.clone(), cluster(), &backend, init(), 4, 16)?;
    let err = coord.run().expect_err("the injected crash must fire");
    let crash = err
        .downcast_ref::<CoordinatorCrashed>()
        .expect("typed crash error");
    assert_eq!(crash.round, CRASH_AT);
    let wal_bytes = coord.wal_len_bytes().expect("WAL attached");
    let logged = coord.rounds_completed();
    println!(
        "crashed at round {} ({} rounds durable, WAL {} — {}/round)",
        crash.round,
        logged,
        human_bytes(wal_bytes),
        human_bytes(wal_bytes / logged.max(1) as u64),
    );
    // the parameter chain (snapshots + XOR deltas) rides the
    // delta-varint lossless stage on disk; report what that saves
    let (param_raw, param_enc) = coord.wal_param_bytes();
    assert!(
        param_enc < param_raw,
        "delta-varint WAL params must beat raw words ({param_enc} vs {param_raw})"
    );
    println!(
        "WAL parameter chain: {} raw -> {} on disk ({:.2}x)",
        human_bytes(param_raw),
        human_bytes(param_enc),
        param_raw as f64 / param_enc.max(1) as f64,
    );
    drop(coord); // the coordinator process is gone

    // --- resume against the same directory and finish the run
    let mut resumed_coord =
        Coordinator::resume(c, cluster(), &backend, init(), 4, 16)?;
    assert_eq!(resumed_coord.rounds_completed(), CRASH_AT);
    let resumed = resumed_coord.run()?;
    println!(
        "resumed at round {CRASH_AT}, finished {} rounds (WAL now {})",
        resumed.rounds_run,
        human_bytes(resumed_coord.wal_len_bytes().unwrap_or(0)),
    );

    // --- the stitched run must be indistinguishable from the clean one
    assert_bit_identical(&baseline, &resumed);
    println!(
        "crash/resume is bit-identical to the uninterrupted run: \
         final eval loss {:.4}, {} on the wire, ${:.4} billed",
        resumed.final_eval_loss,
        human_bytes(resumed.wire_bytes),
        resumed.cost.total_usd(),
    );
    std::fs::remove_dir_all(&wal_dir).ok();
    Ok(())
}

fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.history.len(), b.history.len(), "round count");
    for (ra, rb) in a.history.iter().zip(&b.history) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "train loss r{}",
            ra.round
        );
        assert_eq!(
            ra.eval_loss.map(f32::to_bits),
            rb.eval_loss.map(f32::to_bits),
            "eval loss r{}",
            ra.round
        );
        assert_eq!(
            ra.sim_secs.to_bits(),
            rb.sim_secs.to_bits(),
            "sim secs r{}",
            ra.round
        );
        assert_eq!(
            ra.cum_cost_usd.to_bits(),
            rb.cum_cost_usd.to_bits(),
            "cum cost r{}",
            ra.round
        );
    }
    assert_eq!(a.wire_bytes, b.wire_bytes, "wire bytes");
    assert_eq!(a.wire_bytes_class, b.wire_bytes_class, "wire bytes by class");
    assert_eq!(
        a.final_eval_loss.to_bits(),
        b.final_eval_loss.to_bits(),
        "final eval loss"
    );
    assert_eq!(
        a.cost.total_usd().to_bits(),
        b.cost.total_usd().to_bits(),
        "total cost"
    );
}

//! Planet-scale simulator smoke: 10k+ nodes across 64 clouds.
//!
//! Exercises the scale path end-to-end on the mock backend: the
//! heterogeneous cluster generator (`ClusterSpec::scaled`), the indexed
//! WAN (CSR adjacency over ~1.7M directed links), the arena-backed event
//! engine and the per-cloud parallel round scheduler (`par_rounds`). The
//! run executes twice — single-threaded and multi-threaded — and asserts
//! the histories are bit-identical: parallelism must never change a
//! simulated result, only the wall-clock it takes to produce it.
//!
//! Runs on the mock backend (no artifacts needed — CI executes this):
//!
//!     cargo run --release --example planet_scale

use std::time::Instant;

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::partition::PartitionStrategy;
use crossfed::runtime::MockRuntime;
use crossfed::util::bytes::human_bytes;
use crossfed::util::par::with_threads;

const N_CLOUDS: usize = 64;
/// AZ-node counts cycled across the clouds: 22×192 + 21×160 + 21×128
/// = 10_272 worker nodes.
const CLOUD_SIZES: [usize; 3] = [192, 160, 128];
const ROUNDS: usize = 2;

/// One full run at `threads` host threads. Returns the result plus the
/// wall seconds and the simulator event count.
fn run(threads: usize) -> anyhow::Result<(RunResult, f64, u64)> {
    let mut cfg = preset("quick").expect("builtin preset");
    cfg.name = "planet-scale".into();
    cfg.hierarchical = true;
    cfg.par_rounds = true;
    cfg.rounds = ROUNDS;
    cfg.eval_every = 1;
    cfg.eval_batches = 1;
    cfg.local_steps = 2;
    cfg.target_loss = None;
    // one doc per worker: equal_shards needs docs >= workers to keep
    // every cloud's reduce weight positive
    cfg.partition = PartitionStrategy::Fixed;
    cfg.corpus =
        CorpusConfig { n_docs: 12_000, doc_sentences: 1, n_topics: 6, seed: 11 };

    let cluster = ClusterSpec::scaled(N_CLOUDS, &CLOUD_SIZES);
    let n_nodes = cluster.n();
    anyhow::ensure!(n_nodes >= 10_000, "scale floor: {n_nodes} nodes");
    let backend = MockRuntime::new(0.4);
    let init = ParamSet { leaves: vec![vec![0.5f32; 64], vec![-0.25f32; 32]] };
    with_threads(threads, || {
        let mut coord = Coordinator::new(cfg, cluster, &backend, init, 4, 16)?;
        let t0 = Instant::now();
        let r = coord.run()?;
        Ok((r, t0.elapsed().as_secs_f64(), coord.sim_events()))
    })
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();
    let n_nodes: usize = (0..N_CLOUDS).map(|c| CLOUD_SIZES[c % 3]).sum();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .max(2);
    println!(
        "planet scale: {n_nodes} nodes / {N_CLOUDS} clouds / {ROUNDS} rounds"
    );

    let (serial, serial_wall, serial_events) = run(1)?;
    let (parallel, parallel_wall, parallel_events) = run(threads)?;

    for (label, r, wall, events) in [
        ("1 thread", &serial, serial_wall, serial_events),
        ("N threads", &parallel, parallel_wall, parallel_events),
    ] {
        println!(
            "{label:>9}: wall={wall:>6.2}s  {:>9.0} node-rounds/s  \
             {:>9.0} events/s  wire={}  sim={:.0}s",
            (n_nodes * ROUNDS) as f64 / wall,
            events as f64 / wall,
            human_bytes(r.wire_bytes),
            r.sim_secs,
        );
    }
    println!(
        "speedup: {:.2}x at {threads} threads",
        serial_wall / parallel_wall
    );

    // determinism gate: the simulated outcome is a pure function of the
    // seed — thread count must not leak into any simulated quantity
    anyhow::ensure!(serial.history.len() == parallel.history.len());
    for (a, b) in serial.history.iter().zip(&parallel.history) {
        anyhow::ensure!(
            a.train_loss.to_bits() == b.train_loss.to_bits(),
            "round {}: train loss diverged across thread counts",
            a.round
        );
        anyhow::ensure!(
            a.sim_secs.to_bits() == b.sim_secs.to_bits(),
            "round {}: simulated time diverged across thread counts",
            a.round
        );
        anyhow::ensure!(
            a.wire_bytes == b.wire_bytes,
            "round {}: wire bytes diverged across thread counts",
            a.round
        );
        anyhow::ensure!(
            a.cum_cost_usd.to_bits() == b.cum_cost_usd.to_bits(),
            "round {}: dollar bill diverged across thread counts",
            a.round
        );
    }
    anyhow::ensure!(serial.wire_bytes == parallel.wire_bytes);
    anyhow::ensure!(serial_events == parallel_events);
    anyhow::ensure!(
        serial.final_eval_loss.to_bits() == parallel.final_eval_loss.to_bits()
    );
    println!("determinism: 1-thread and {threads}-thread histories are bit-identical");
    Ok(())
}

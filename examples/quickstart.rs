//! Quickstart: federated training of the AOT-compiled LM across three
//! simulated clouds, in ~30 lines of API surface.
//!
//!     make artifacts            # once: lowers the JAX+Pallas model
//!     cargo run --release --example quickstart
//!
//! What happens: the coordinator partitions a synthetic corpus across
//! AWS/GCP/Azure-like platforms (non-IID), each platform runs local SGD
//! steps through the PJRT runtime (the Pallas attention kernels compiled
//! into the HLO), updates are compressed + AES-sealed, shipped over the
//! simulated WAN, and FedAvg (paper formula 1) merges them.

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::model::{Manifest, ParamSet};
use crossfed::runtime::StepRuntime;
use crossfed::util::bytes::{human_bytes, human_duration};

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();

    // 1. load the AOT artifacts (train + eval HLO, compiled once)
    let manifest = Manifest::load(std::path::Path::new("artifacts"), "tiny")?;
    let backend = StepRuntime::load(&manifest)?;
    println!(
        "model: {} params, {} layers, vocab {}",
        manifest.model.n_params, manifest.model.n_layers, manifest.model.vocab_size
    );

    // 2. configure the experiment (presets mirror the paper's Table 1)
    let mut cfg = preset("quick").expect("builtin preset");
    cfg.rounds = 10;
    cfg.eval_every = 2;

    // 3. build the coordinator over the 3-platform cluster and run
    let cluster = ClusterSpec::paper_default();
    let init = ParamSet::init(&manifest, cfg.seed);
    let mut coord = Coordinator::new(
        cfg,
        cluster,
        &backend,
        init,
        manifest.model.batch_size,
        manifest.model.seq_len,
    )?;
    let result = coord.run()?;

    // 4. inspect the outcome
    println!("\nround  train_loss  eval_loss  comm");
    for r in &result.history {
        println!(
            "{:>5}  {:>10.3}  {:>9}  {}",
            r.round,
            r.train_loss,
            r.eval_loss.map_or("-".into(), |l| format!("{l:.3}")),
            human_bytes(r.wire_bytes),
        );
    }
    println!(
        "\nfinal: eval loss {:.3}, accuracy {:.1}%, {} on the wire, {} simulated",
        result.final_eval_loss,
        result.acc_pct(),
        human_bytes(result.wire_bytes),
        human_duration(result.sim_secs),
    );
    Ok(())
}

//! The paper's spot-market question, answered end to end: is 3× spot
//! capacity at ~10%/hour preemption *cheaper to a target loss* than the
//! on-demand synchronous baseline?
//!
//! Two runs on the paper price book (spot at the familiar ~70% discount
//! off on-demand):
//!
//! * **baseline** — `paper-hier-cost`: synchronous hierarchical FedAvg
//!   on 12 on-demand nodes (3 clouds × 4), preset learning rates.
//! * **spot** — `paper-hier-async-spot`: the buffered asynchronous
//!   hierarchy on 36 spot nodes (3 clouds × 12) churned by a seeded
//!   [`FaultPlan::spot_preemptions`] plan (each non-anchor node
//!   preempted with p = 0.10 per round, capacity back 2 rounds later).
//!   The async run trains with a hotter local lr — the usual FedBuff
//!   recipe, compensating the staleness discount the gateway and leader
//!   apply to late updates.
//!
//! The target loss is whatever the baseline actually reaches; each
//! run's cost-to-target is the cumulative dollar bill at its first
//! evaluation at or below that loss. Asserts (CI runs this — a
//! regression fails the build):
//!
//! * the preemption plan really churns the roster and the run survives
//!   every leave/join with secure aggregation on,
//! * the spot fleet reaches the baseline's final loss,
//! * it gets there for fewer dollars (the paper's claim),
//! * the blended compute rate actually billed is under half the
//!   baseline's — the spot discount is real, not a wire-cost artifact.
//!
//! Runs on the mock backend (no artifacts needed):
//!
//!     cargo run --release --example spot_market

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::FaultPlan;
use crossfed::runtime::MockRuntime;

const BASE_NODES_PER_CLOUD: usize = 4;
const SPOT_NODES_PER_CLOUD: usize = 12; // 3x the baseline capacity
const BASE_ROUNDS: usize = 6;
const SPOT_ROUNDS: usize = 12; // generous cap; the run is judged on cost
const P_PREEMPT: f64 = 0.10;
const RECOVERY_ROUNDS: usize = 2;

fn cfg(preset_name: &str, rounds: usize) -> ExperimentConfig {
    let mut c = preset(preset_name).expect("builtin preset");
    c.rounds = rounds;
    c.eval_every = 1; // cost-to-target needs a loss reading every round
    c.eval_batches = 1;
    c.target_loss = None; // the race is scored from the histories
    c
}

fn run(mut c: ExperimentConfig, nodes_per_cloud: usize) -> anyhow::Result<RunResult> {
    let cluster = ClusterSpec::paper_default_scaled(nodes_per_cloud);
    let backend = MockRuntime::new(0.4);
    let init = ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] };
    c.name = format!("{}-x{nodes_per_cloud}", c.name);
    let mut coord = Coordinator::new(c, cluster, &backend, init, 4, 16)?;
    coord.run()
}

/// Cumulative dollars at the first evaluation at or below `target`.
fn cost_to_target(r: &RunResult, target: f32) -> Option<(usize, f64)> {
    r.history
        .iter()
        .find(|h| h.eval_loss.is_some_and(|l| l <= target))
        .map(|h| (h.round, h.cum_cost_usd))
}

/// Blended compute rate actually billed, $/node-hour.
fn blended_rate(r: &RunResult) -> f64 {
    let node_hours: f64 = r
        .history
        .iter()
        .map(|h| h.platform_secs.iter().sum::<f64>())
        .sum::<f64>()
        / 3600.0;
    r.cost.compute_total_usd() / node_hours.max(1e-12)
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();

    let baseline = run(cfg("paper-hier-cost", BASE_ROUNDS), BASE_NODES_PER_CLOUD)?;

    let mut spot_cfg = cfg("paper-hier-async-spot", SPOT_ROUNDS);
    // the async fleet compensates the staleness discount locally
    spot_cfg.local_lr = 3.0;
    // swap the preset's fixed churn script for the seeded market model
    let spot_cluster = ClusterSpec::paper_default_scaled(SPOT_NODES_PER_CLOUD);
    spot_cfg.faults = FaultPlan::spot_preemptions(
        spot_cfg.seed,
        SPOT_ROUNDS,
        &spot_cluster,
        P_PREEMPT,
        RECOVERY_ROUNDS,
    );
    let spot = run(spot_cfg, SPOT_NODES_PER_CLOUD)?;

    let target = baseline.final_eval_loss;
    let (base_round, base_usd) =
        cost_to_target(&baseline, target).expect("baseline reaches its own loss");
    let spot_hit = cost_to_target(&spot, target);

    println!(
        "{:>10} {:>6} {:>14} {:>12} {:>14}",
        "mode", "nodes", "round@target", "$ to target", "$/node-hour"
    );
    println!(
        "{:>10} {:>6} {:>14} {:>12.2} {:>14.2}",
        "on-demand",
        3 * BASE_NODES_PER_CLOUD,
        base_round,
        base_usd,
        blended_rate(&baseline)
    );
    if let Some((r, usd)) = spot_hit {
        println!(
            "{:>10} {:>6} {:>14} {:>12.2} {:>14.2}",
            "spot-3x",
            3 * SPOT_NODES_PER_CLOUD,
            r,
            usd,
            blended_rate(&spot)
        );
    }

    // --- the spot-market story, asserted ------------------------------
    // 1. the preemption plan really churned the roster mid-run...
    let full = 3 * SPOT_NODES_PER_CLOUD;
    let min_roster =
        spot.history.iter().map(|h| h.active_members).min().unwrap_or(full);
    anyhow::ensure!(
        min_roster < full,
        "the spot plan never preempted anyone (roster stayed at {full})"
    );
    // ...and the anchors kept every cloud alive
    anyhow::ensure!(min_roster >= 3, "a cloud was preempted to extinction");
    anyhow::ensure!(
        spot.rounds_run == SPOT_ROUNDS,
        "spot run stopped early at round {}",
        spot.rounds_run
    );
    println!(
        "\nroster: {full} nodes, low-water mark {min_roster} under \
         p={P_PREEMPT}/round preemption"
    );

    // 2. the spot fleet reaches the on-demand baseline's loss
    let (spot_round, spot_usd) = spot_hit.ok_or_else(|| {
        anyhow::anyhow!(
            "spot fleet never reached the baseline loss {target:.4} \
             (got to {:.4})",
            spot.final_eval_loss
        )
    })?;

    // 3. ...for fewer dollars: the paper's cheaper-to-target-loss claim
    anyhow::ensure!(
        spot_usd < base_usd,
        "3x spot capacity was NOT cheaper to loss {target:.4}: \
         ${spot_usd:.2} (round {spot_round}) vs on-demand ${base_usd:.2} \
         (round {base_round})"
    );
    println!(
        "cost to loss {target:.4}: spot ${spot_usd:.2} vs on-demand \
         ${base_usd:.2} ({:.1}x cheaper)",
        base_usd / spot_usd.max(1e-12)
    );

    // 4. the billed compute rate reflects the spot discount
    let (br, sr) = (blended_rate(&baseline), blended_rate(&spot));
    anyhow::ensure!(
        sr < 0.5 * br,
        "blended spot rate ${sr:.2}/node-hour is not under half the \
         on-demand ${br:.2}/node-hour"
    );
    println!("blended compute: on-demand ${br:.2} vs spot ${sr:.2} per node-hour");
    Ok(())
}

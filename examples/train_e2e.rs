//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Trains the transformer LM federatedly across the three simulated
//! clouds for a few hundred rounds with gradient aggregation (the
//! paper's best algorithm), on the synthetic topic corpus, and logs the
//! full loss curve. This is the run recorded in EXPERIMENTS.md.
//!
//!     make artifacts
//!     cargo run --release --example train_e2e [-- --rounds 300 --model tiny]
//!
//! Outputs: target/report/e2e_curve.csv + a summary block on stdout.

use crossfed::cluster::ClusterSpec;
use crossfed::compress::Compression;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::model::{Manifest, ParamSet};
use crossfed::report;
use crossfed::runtime::{execution_count, StepRuntime};
use crossfed::util::bytes::{human_bytes, human_duration};

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();
    let rounds: usize = arg("--rounds", "300").parse()?;
    let model: String = arg("--model", "tiny");

    let manifest = Manifest::load(std::path::Path::new("artifacts"), &model)?;
    let backend = StepRuntime::load(&manifest)?;
    println!(
        "e2e: {} preset, {:.2}M params, {} rounds x 3 platforms x local steps",
        model,
        manifest.model.n_params as f64 / 1e6,
        rounds
    );

    let mut cfg = preset("paper-gradient").expect("builtin");
    cfg.name = format!("e2e-{model}");
    cfg.rounds = rounds;
    cfg.target_loss = None; // run the full schedule, record the curve
    cfg.eval_every = 10;
    cfg.eval_batches = 8;
    cfg.local_steps = 4;
    cfg.compression = Compression::TopK { ratio: 0.25 };
    cfg.error_feedback = true;
    cfg.corpus = CorpusConfig {
        n_docs: 600,
        doc_sentences: 12,
        n_topics: 6,
        seed: 1234,
    };

    let cluster = ClusterSpec::paper_default();
    let init = ParamSet::init(&manifest, cfg.seed);
    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::new(
        cfg,
        cluster,
        &backend,
        init,
        manifest.model.batch_size,
        manifest.model.seq_len,
    )?;
    let result = coord.run()?;
    let host = t0.elapsed().as_secs_f64();

    report::save("e2e_curve.csv", &result.curve_csv());
    println!("\nloss curve written to target/report/e2e_curve.csv");
    println!("\n=== E2E summary ===");
    let first_eval = result
        .history
        .iter()
        .find_map(|r| r.eval_loss)
        .unwrap_or(f32::NAN);
    println!("rounds run          : {}", result.rounds_run);
    println!("eval loss           : {first_eval:.3} -> {:.3}", result.final_eval_loss);
    println!("token accuracy      : {:.1}%", result.acc_pct());
    println!("wire bytes          : {}", human_bytes(result.wire_bytes));
    println!("simulated time      : {}", human_duration(result.sim_secs));
    println!("host wall-clock     : {}", human_duration(host));
    println!("PJRT executions     : {}", execution_count());
    println!(
        "host compute share  : {:.0}% of wall-clock inside PJRT+agg",
        100.0 * result.host_compute_secs / host
    );

    // the run is only a valid E2E check if the model actually learned
    anyhow::ensure!(
        result.final_eval_loss < first_eval * 0.75,
        "E2E FAILED: eval loss did not improve enough \
         ({first_eval:.3} -> {:.3})",
        result.final_eval_loss
    );
    println!("\nE2E OK: loss curve decreased as expected");
    Ok(())
}

//! Gateway failover under fault injection: kill a cloud's WAN gateway
//! mid-run and finish training anyway.
//!
//! The `paper-hier-faulty` preset schedules cloud 1's gateway egress to
//! die at round 3 (plus a persistent straggler at round 5). The
//! hierarchical scheduler only observes the death at that cloud's
//! reduce: it re-elects the next member by id as gateway, rebuilds the
//! WAN mesh around the standby (dropping every warm connection),
//! re-routes the already-delivered member updates over the surviving
//! AZ fabric, and completes the round. This example runs that scenario
//! at `paper_default_scaled(16)` (48 nodes) against a clean flat star
//! and asserts:
//!
//! * all rounds complete and training improves despite the failover,
//! * the re-election is deterministic (same standby in a repeat run,
//!   bit-identical history),
//! * the inter-region savings survive: ≤ 1/4 of the star's WAN bytes.
//!
//! Runs on the mock backend (no artifacts needed — CI executes this):
//!
//!     cargo run --release --example gateway_failover

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::runtime::MockRuntime;
use crossfed::util::bytes::human_bytes;

const ROUNDS: usize = 6;
const NODES_PER_CLOUD: usize = 16;

fn cfg(preset_name: &str) -> ExperimentConfig {
    let mut c = preset(preset_name).expect("builtin preset");
    c.rounds = ROUNDS;
    c.eval_every = 2;
    c.eval_batches = 1;
    c.local_lr = 3.0;
    c.server_lr = 3.0;
    c.target_loss = None;
    // enough docs that every dirichlet shard is populated at 48 nodes
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

/// Returns (run result, per-round inter-region bytes, gateway of cloud 1
/// after the run).
fn run(mut cfg: ExperimentConfig, name: &str) -> anyhow::Result<(RunResult, u64, usize)> {
    cfg.name = name.to_string();
    let cluster = ClusterSpec::paper_default_scaled(NODES_PER_CLOUD);
    let backend = MockRuntime::new(0.4);
    let init = ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] };
    let mut coord = Coordinator::new(cfg, cluster, &backend, init, 4, 16)?;
    let inter0 = coord.inter_region_wire_bytes();
    let r = coord.run()?;
    let inter = (coord.inter_region_wire_bytes() - inter0) / ROUNDS as u64;
    Ok((r, inter, coord.cluster.gateway(1)))
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();

    // clean flat star reference at the same scale and codec settings
    let mut star_cfg = cfg("paper-fedavg");
    star_cfg.faults = Default::default();
    let (star, star_inter, _) = run(star_cfg, "star-clean")?;

    // hierarchical run that loses cloud 1's gateway at round 3
    let (faulty, hier_inter, gw) = run(cfg("paper-hier-faulty"), "hier-faulty")?;
    let (repeat, _, gw2) = run(cfg("paper-hier-faulty"), "hier-faulty-rep")?;

    println!(
        "{:>12} {:>7} {:>16} {:>10}",
        "mode", "rounds", "inter-region/r", "eval loss"
    );
    for (name, r, inter) in
        [("star", &star, star_inter), ("hier-faulty", &faulty, hier_inter)]
    {
        println!(
            "{name:>12} {:>7} {:>16} {:>10.3}",
            r.rounds_run,
            human_bytes(inter),
            r.final_eval_loss
        );
    }

    // --- the failover story, asserted ---------------------------------
    // 1. the run survives the mid-training gateway death
    anyhow::ensure!(faulty.rounds_run == ROUNDS, "faulty run stopped early");
    anyhow::ensure!(
        faulty.final_eval_loss < faulty.history[0].train_loss,
        "training did not improve across the failover"
    );
    // 2. cloud 1 = nodes {16..31}: node 16 died, 17 is the standby
    anyhow::ensure!(gw == 17, "unexpected re-elected gateway {gw}");
    println!("\ncloud 1 gateway after failover: node {gw} (was 16)");
    // 3. deterministic: the repeat run elects the same standby and is
    //    bit-identical
    anyhow::ensure!(gw2 == gw, "re-election not deterministic");
    anyhow::ensure!(
        repeat.sim_secs.to_bits() == faulty.sim_secs.to_bits()
            && repeat.wire_bytes == faulty.wire_bytes
            && repeat.final_eval_loss.to_bits() == faulty.final_eval_loss.to_bits(),
        "faulty run is not bit-reproducible"
    );
    // 4. the hierarchy keeps paying off across the failure
    anyhow::ensure!(
        hier_inter * 4 <= star_inter,
        "failover lost the inter-region advantage: star {star_inter} vs \
         faulty hier {hier_inter}"
    );
    let reduction = star_inter as f64 / hier_inter.max(1) as f64;
    println!(
        "inter-region bytes: {reduction:.1}x below the flat star, \
         failover included"
    );
    Ok(())
}

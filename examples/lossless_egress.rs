//! Lossless wire compression in egress dollars: the same hierarchical
//! training run (paper-hier preset, 3 clouds) priced with and without
//! the `--lossless auto` stage.
//!
//! Asserts (CI runs this — a regression fails the build):
//!
//! * the loss history is bit-identical with the stage on — lossless
//!   means *lossless*, training cannot tell it is there,
//! * training-round egress dollars drop by ≥20% at that equal loss,
//! * the staged run's dollars still decompose exactly
//!   (total == sum of per-cloud compute + egress entries).
//!
//! Runs on the mock backend (no artifacts needed):
//!
//!     cargo run --release --example lossless_egress

use crossfed::cluster::ClusterSpec;
use crossfed::compress::LosslessStage;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::runtime::MockRuntime;

const ROUNDS: usize = 4;
const NODES_PER_CLOUD: usize = 8;

/// Params big enough that update traffic dwarfs the one-off shard
/// distribution, patterned like a real dense gradient (smooth ramps).
fn init_params() -> ParamSet {
    let a: Vec<f32> = (0..8192).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let b: Vec<f32> = (0..4096).map(|i| ((i % 89) as f32) * -0.01 + 0.4).collect();
    ParamSet { leaves: vec![a, b] }
}

fn cfg(name: &str, stage: LosslessStage) -> ExperimentConfig {
    let mut c = preset("paper-hier").expect("builtin preset");
    c.name = name.to_string();
    c.lossless = stage;
    c.rounds = ROUNDS;
    c.eval_every = 2;
    c.eval_batches = 1;
    c.local_steps = 2;
    c.local_lr = 3.0;
    c.server_lr = 3.0;
    c.target_loss = None;
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

fn run(c: ExperimentConfig) -> anyhow::Result<RunResult> {
    let cluster = ClusterSpec::paper_default_scaled(NODES_PER_CLOUD);
    let backend = MockRuntime::new(0.4);
    let mut coord = Coordinator::new(c, cluster, &backend, init_params(), 4, 16)?;
    coord.run()
}

fn egress_usd(r: &RunResult) -> f64 {
    r.history.iter().map(|h| h.cost.egress_total_usd()).sum()
}

fn main() -> anyhow::Result<()> {
    crossfed::util::logging::init();

    let plain = run(cfg("paper-hier-plain", LosslessStage::None))?;
    let staged = run(cfg("paper-hier-lossless", LosslessStage::Auto))?;

    let plain_usd = egress_usd(&plain);
    let staged_usd = egress_usd(&staged);
    println!(
        "{:>12} {:>14} {:>16} {:>14}",
        "mode", "wire bytes", "egress $ total", "final loss"
    );
    println!(
        "{:>12} {:>14} {:>16.4} {:>14.4}",
        "plain", plain.wire_bytes, plain_usd, plain.final_eval_loss
    );
    println!(
        "{:>12} {:>14} {:>16.4} {:>14.4}",
        "lossless", staged.wire_bytes, staged_usd, staged.final_eval_loss
    );

    // --- the lossless story, asserted ----------------------------------
    // 1. training cannot tell the stage is there: every loss bit matches
    anyhow::ensure!(plain.history.len() == staged.history.len());
    for (a, b) in plain.history.iter().zip(&staged.history) {
        anyhow::ensure!(
            a.train_loss.to_bits() == b.train_loss.to_bits()
                && a.eval_loss.map(f32::to_bits) == b.eval_loss.map(f32::to_bits),
            "round {}: lossless stage perturbed the loss ({} vs {})",
            a.round,
            a.train_loss,
            b.train_loss
        );
    }
    anyhow::ensure!(
        plain.final_eval_loss.to_bits() == staged.final_eval_loss.to_bits(),
        "final eval loss diverged under the lossless stage"
    );
    // 2. the stage pays for itself: ≥20% fewer egress dollars
    anyhow::ensure!(
        staged_usd <= plain_usd * 0.8,
        "lossless stage saved under 20%: plain ${plain_usd:.4} vs \
         staged ${staged_usd:.4}"
    );
    println!(
        "\negress dollars: lossless stage at {:.1}% of the plain run, \
         equal losses",
        staged_usd / plain_usd.max(1e-12) * 100.0
    );
    // 3. staged dollars still decompose exactly
    let mut manual = 0.0f64;
    for c in 0..staged.cost.n_clouds() {
        manual += staged.cost.compute_usd[c];
        for e in &staged.cost.egress_usd[c] {
            manual += e;
        }
    }
    anyhow::ensure!(
        manual.to_bits() == staged.cost.total_usd().to_bits(),
        "staged cost breakdown does not decompose exactly"
    );
    println!("all lossless-egress assertions hold");
    Ok(())
}

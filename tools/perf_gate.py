#!/usr/bin/env python3
"""Numeric perf regression gate for the CI bench-smoke job.

Usage: perf_gate.py FLOORS.json FRESH.json

FLOORS is the committed BENCH_hotpath.json (the baseline the repo
promises); FRESH is the copy the bench just rewrote on this runner.
Compared metrics:

  - sim_scale[*].nodes_per_sec   (arena engine + indexed WAN core)
  - sim_scale[*].events_per_sec
  - serve_throughput.events_per_sec  (serving day on the event engine)
  - serve_throughput.requests_per_sec
  - lossless.{xor,varint,auto}_{encode,decode}_gbps  (wire stage codecs)
  - lossless.{xor,varint,auto}_ratio  (compression on the bench payload)

A fresh number more than TOLERANCE below its floor is a regression.
While the committed floors are null (no authoring container has had a
Rust toolchain yet) the gate soft-passes loudly; once real floors are
committed, regressions make the job fail. Runner noise is real, so the
tolerance is deliberately generous — this gate catches collapses, not
percent-level drift.

Exit codes: 0 pass / soft-pass, 1 regression against a real floor,
2 malformed input.
"""

import json
import sys

TOLERANCE = 0.30  # fresh may be up to 30% below the floor


def annotate(kind, msg):
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::{kind}::perf-gate: {msg}")


def pick(doc, path):
    """Walk a dotted path; list indexes are numeric components."""
    cur = doc
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            cur = cur.get(part)
        else:
            return None
        if cur is None:
            return None
    return cur


def metric_paths(floors):
    paths = []
    scale = floors.get("sim_scale")
    if isinstance(scale, list):
        for i in range(len(scale)):
            paths.append(f"sim_scale.{i}.nodes_per_sec")
            paths.append(f"sim_scale.{i}.events_per_sec")
    if isinstance(floors.get("serve_throughput"), dict):
        paths.append("serve_throughput.events_per_sec")
        paths.append("serve_throughput.requests_per_sec")
    if isinstance(floors.get("lossless"), dict):
        for stage in ("xor", "varint", "auto"):
            paths.append(f"lossless.{stage}_encode_gbps")
            paths.append(f"lossless.{stage}_decode_gbps")
            paths.append(f"lossless.{stage}_ratio")
    return paths


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        with open(argv[1]) as f:
            floors = json.load(f)
        with open(argv[2]) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        annotate("error", f"cannot read inputs: {e}")
        return 2

    regressions, soft, checked = [], [], 0
    for path in metric_paths(floors):
        floor = pick(floors, path)
        now = pick(fresh, path)
        if not isinstance(floor, (int, float)):
            soft.append(path)
            continue
        checked += 1
        if not isinstance(now, (int, float)):
            regressions.append(f"{path}: floor {floor:.0f} but no fresh value")
        elif now < floor * (1.0 - TOLERANCE):
            regressions.append(
                f"{path}: {now:.0f} < floor {floor:.0f} "
                f"(-{(1.0 - now / floor) * 100.0:.0f}%, tolerance "
                f"{TOLERANCE * 100:.0f}%)"
            )
        else:
            print(f"perf-gate: {path}: {now:.0f} >= floor {floor:.0f} ok")

    if regressions:
        for r in regressions:
            annotate("error", r)
        return 1
    if soft:
        annotate(
            "warning",
            f"SOFT PASS — {len(soft)} metric(s) have no committed floor "
            "(BENCH_hotpath.json floors are null; no authoring container "
            "has had a Rust toolchain). Commit a measured "
            "BENCH_hotpath.json to arm the gate: " + ", ".join(soft),
        )
    if checked:
        annotate("notice", f"{checked} metric(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

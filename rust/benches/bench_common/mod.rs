//! Shared bench plumbing: backend selection + run helpers.
//!
//! (Included as a module by every bench target; each uses a subset, so
//! dead-code lints are silenced below.)
//!
//! Every bench accepts `CROSSFED_BENCH_BACKEND=mock` to run against the
//! quadratic mock (fast, artifact-free, CI-friendly); the default is the
//! real PJRT runtime over `artifacts/` (tiny preset), which is what the
//! EXPERIMENTS.md numbers use.
#![allow(dead_code)]

use std::path::Path;

use crossfed::cluster::ClusterSpec;
use crossfed::config::ExperimentConfig;
use crossfed::coordinator::Coordinator;
use crossfed::metrics::RunResult;
use crossfed::model::{Manifest, ParamSet};
use crossfed::runtime::{ComputeBackend, MockRuntime, StepRuntime};

pub enum Backend {
    Real { runtime: StepRuntime, manifest: Manifest },
    Mock(MockRuntime),
}

impl Backend {
    /// Resolve from env + artifact availability.
    pub fn detect() -> Backend {
        let want_mock = std::env::var("CROSSFED_BENCH_BACKEND")
            .map(|v| v == "mock")
            .unwrap_or(false);
        let artifacts = Path::new("artifacts");
        if !want_mock && artifacts.join("manifest_tiny.json").exists() {
            let manifest =
                Manifest::load(artifacts, "tiny").expect("manifest parses");
            let runtime = StepRuntime::load(&manifest).expect("artifacts load");
            Backend::Real { runtime, manifest }
        } else {
            if !want_mock {
                eprintln!(
                    "note: artifacts/ missing — falling back to the mock \
                     backend (run `make artifacts` for the real numbers)"
                );
            }
            Backend::Mock(MockRuntime::new(0.4))
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Real { .. } => "pjrt-tiny",
            Backend::Mock(_) => "mock",
        }
    }

    /// Run one experiment on this backend with the paper's 3-cloud
    /// cluster.
    pub fn run(&self, cfg: &ExperimentConfig) -> RunResult {
        self.run_on(cfg, ClusterSpec::paper_default())
    }

    pub fn run_on(&self, cfg: &ExperimentConfig, cluster: ClusterSpec) -> RunResult {
        match self {
            Backend::Real { runtime, manifest } => {
                let init = ParamSet::init(manifest, cfg.seed);
                let mut coord = Coordinator::new(
                    cfg.clone(),
                    cluster,
                    runtime,
                    init,
                    manifest.model.batch_size,
                    manifest.model.seq_len,
                )
                .expect("coordinator");
                coord.run().expect("run")
            }
            Backend::Mock(mock) => {
                let init = ParamSet {
                    leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]],
                };
                let mut cfg = cfg.clone();
                // the mock quadratic needs bigger steps to move
                cfg.local_lr = cfg.local_lr.max(3.0);
                cfg.server_lr = cfg.server_lr.max(3.0);
                let mut coord =
                    Coordinator::new(cfg, cluster, mock, init, 4, 16)
                        .expect("coordinator");
                coord.run().expect("run")
            }
        }
    }
}

/// Convenience: `f(base_backend)` for ComputeBackend-generic helpers.
pub fn tokens_per_batch(b: &Backend) -> u32 {
    match b {
        Backend::Real { runtime, .. } => runtime.tokens_per_batch(),
        Backend::Mock(m) => m.tokens_per_batch(),
    }
}

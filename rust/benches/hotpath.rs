//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 operations
//! that run once or more per round, measured standalone so the perf
//! pass can track them.
//!
//!     cargo bench --bench hotpath
//!
//! Targets (memory-bound roofline class): ≥1 GB/s per core for the
//! f32-vector kernels (axpy / aggregate / compress-none), crypto at
//! AES-CTR software speed, PJRT step time reported for reference.
//!
//! Every kernel set runs twice — pinned to 1 thread (the serial
//! baseline) and at full `available_parallelism()` — and the
//! serial/parallel comparison is written to `BENCH_hotpath.json` at the
//! repo root (deterministic kernels make the two passes bit-comparable;
//! see `crossfed::util::par`).

mod bench_common;

use crossfed::aggregation::{Aggregator, ClientUpdate, DynamicWeighted, FedAvg};
use crossfed::compress::{Compression, Compressor};
use crossfed::crypto::{open, seal, TransportKey};
use crossfed::model::ParamSet;
use crossfed::netsim::{Link, Protocol, Wan};
use crossfed::testkit::bench_kit::{BenchResult, BenchSet};
use crossfed::util::json::Json;
use crossfed::util::par;
use crossfed::util::rng::Pcg64;

const N: usize = 1_000_000; // 4 MB of f32 — a mid-size model update

fn vecs(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 1);
    (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
}

fn params(n: usize, seed: u64) -> ParamSet {
    ParamSet { leaves: vec![vecs(n, seed)] }
}

/// One full pass over the parallelized kernel sets at a pinned thread
/// count. Returns the sets in a fixed order so two passes can be zipped.
fn kernel_pass(threads: usize) -> Vec<BenchSet> {
    par::with_threads(threads, || {
        let bytes = (N * 4) as f64;
        let mut sets = Vec::new();

        // --- ParamSet linear algebra (inner loop of every aggregator)
        let mut b = BenchSet::new(&format!("paramset ops (1M f32, {threads}T)"));
        b.measure_iters = 20;
        let mut p = params(N, 1);
        let q = params(N, 2);
        b.bench_throughput("axpy", bytes, || p.axpy(0.5, &q));
        b.bench_throughput("l2_norm", bytes, || p.l2_norm());
        b.bench_throughput("sub", bytes, || p.sub(&q));
        b.bench_throughput("to_flat", bytes, || p.to_flat());
        b.report();
        sets.push(b);

        // --- aggregation algorithms over 3 workers
        let mut b =
            BenchSet::new(&format!("aggregation (3 workers x 1M, {threads}T)"));
        b.measure_iters = 10;
        let updates: Vec<ClientUpdate> = (0..3)
            .map(|w| ClientUpdate {
                worker: w,
                n_samples: 1000 + w * 100,
                local_loss: 2.0 + w as f32 * 0.1,
                delta: params(N, w as u64 + 10),
                staleness: 0,
            })
            .collect();
        let mut global = params(N, 99);
        // aggregators hoisted out of the measured closures: the bench
        // measures aggregation, not constructor noise
        let mut fedavg = FedAvg;
        let mut dynamic = DynamicWeighted::default();
        b.bench_throughput("fedavg", 3.0 * bytes, || {
            fedavg.aggregate(&mut global, &updates)
        });
        b.bench_throughput("dynamic", 3.0 * bytes, || {
            dynamic.aggregate(&mut global, &updates)
        });
        b.report();
        sets.push(b);

        // --- compression codecs
        let mut b = BenchSet::new(&format!("compression (1M f32, {threads}T)"));
        b.measure_iters = 10;
        let xs = vecs(N, 3);
        for (name, scheme) in [
            ("none", Compression::None),
            ("fp16", Compression::Fp16),
            ("int8", Compression::Int8),
            ("topk-1%", Compression::TopK { ratio: 0.01 }),
            ("randk-1%", Compression::RandK { ratio: 0.01 }),
        ] {
            let mut c = Compressor::new(scheme, 7);
            let mut out = Vec::new();
            b.bench_throughput(name, bytes, || {
                out.clear();
                c.compress_append(&xs, &mut out)
            });
        }
        let mut c = Compressor::new(Compression::TopK { ratio: 0.01 }, 7);
        let payload = c.compress(&xs);
        b.bench_throughput("decompress topk-1%", bytes, || {
            Compressor::decompress(&payload).unwrap()
        });
        b.report();
        sets.push(b);

        // --- crypto
        let mut b = BenchSet::new(&format!("crypto (4 MB payload, {threads}T)"));
        b.measure_iters = 10;
        let plaintext = vec![0xA5u8; N * 4];
        let mut key = TransportKey::derive(b"bench", "ctx");
        b.bench_throughput("seal (aes-ctr+hmac)", bytes, || {
            seal(&mut key, &plaintext)
        });
        let sealed = seal(&mut key, &plaintext);
        b.bench_throughput("open", bytes, || open(&key, &sealed).unwrap());
        b.report();
        sets.push(b);

        sets
    })
}

fn gbps(r: &BenchResult) -> f64 {
    r.throughput().unwrap_or(0.0) / 1e9
}

fn write_json(hw: usize, serial: &[BenchSet], parallel: &[BenchSet]) {
    let mut entries = Vec::new();
    for (sb, pb) in serial.iter().zip(parallel) {
        for (sr, pr) in sb.results.iter().zip(&pb.results) {
            entries.push(Json::obj(vec![
                ("name", Json::str(sr.name.clone())),
                ("serial_gbps", Json::num((gbps(sr) * 1e3).round() / 1e3)),
                ("parallel_gbps", Json::num((gbps(pr) * 1e3).round() / 1e3)),
                (
                    "speedup",
                    Json::num(
                        ((sr.summary.mean / pr.summary.mean) * 100.0).round() / 100.0,
                    ),
                ),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("elements", Json::num(N as f64)),
        ("threads", Json::num(hw as f64)),
        ("results", Json::arr(entries)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

fn main() {
    let hw = par::current_threads();
    println!("== hotpath: serial baseline (1 thread) ==");
    let serial = kernel_pass(1);
    println!("\n== hotpath: parallel ({hw} threads) ==");
    let parallel = kernel_pass(hw);
    write_json(hw, &serial, &parallel);

    // --- netsim transfer computation (pure model, no payload copies)
    let mut b = BenchSet::new("netsim transfer ops");
    b.measure_iters = 20;
    let mut wan = Wan::uniform(3, Link::new(1e9, 0.04), 5);
    b.bench_throughput("transfer calc x1000", 1000.0, || {
        for i in 0..1000u64 {
            wan.transfer(0, 1, 1_000_000 + i, Protocol::Quic, 16);
        }
    });
    b.report();

    // --- PJRT step (reference point for the whole stack)
    let backend = bench_common::Backend::detect();
    if let bench_common::Backend::Real { runtime, manifest } = &backend {
        let mut b = BenchSet::new("pjrt train/eval step (tiny model)");
        b.measure_iters = 10;
        let init = ParamSet::init(manifest, 1);
        let mut rng = Pcg64::new(1, 2);
        let n = manifest.model.batch_size * manifest.model.seq_len;
        let batch = crossfed::runtime::Batch {
            tokens: (0..n).map(|_| rng.below(96) as i32).collect(),
            targets: (0..n).map(|_| rng.below(96) as i32).collect(),
        };
        let flops_fwd_bwd = 6.0 * manifest.model.n_params as f64 * n as f64;
        b.bench_throughput("train_step (flops)", flops_fwd_bwd, || {
            runtime.train_step(&init, &batch).unwrap()
        });
        b.bench("eval_step", || runtime.eval_step(&init, &batch).unwrap());
        b.report();
    } else {
        println!("\n(pjrt step bench skipped: artifacts not built)");
    }
}

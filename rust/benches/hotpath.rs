//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 operations
//! that run once or more per round, measured standalone so the perf
//! pass can track them.
//!
//!     cargo bench --bench hotpath
//!
//! Targets (memory-bound roofline class): ≥1 GB/s per core for the
//! f32-vector kernels (axpy / aggregate / compress-none), crypto at
//! AES-CTR software speed, PJRT step time reported for reference.

mod bench_common;

use crossfed::aggregation::{Aggregator, ClientUpdate, DynamicWeighted, FedAvg};
use crossfed::compress::{Compression, Compressor};
use crossfed::crypto::{open, seal, TransportKey};
use crossfed::model::ParamSet;
use crossfed::netsim::{Link, Protocol, Wan};
use crossfed::testkit::bench_kit::BenchSet;
use crossfed::util::rng::Pcg64;

const N: usize = 1_000_000; // 4 MB of f32 — a mid-size model update

fn vecs(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 1);
    (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
}

fn params(n: usize, seed: u64) -> ParamSet {
    ParamSet { leaves: vec![vecs(n, seed)] }
}

fn main() {
    let bytes = (N * 4) as f64;

    // --- ParamSet linear algebra (inner loop of every aggregator)
    let mut b = BenchSet::new("paramset ops (1M f32)");
    b.measure_iters = 20;
    let mut p = params(N, 1);
    let q = params(N, 2);
    b.bench_throughput("axpy", bytes, || p.axpy(0.5, &q));
    b.bench_throughput("l2_norm", bytes, || p.l2_norm());
    b.bench_throughput("sub", bytes, || p.sub(&q));
    b.bench_throughput("to_flat", bytes, || p.to_flat());
    b.report();

    // --- aggregation algorithms over 3 workers
    let mut b = BenchSet::new("aggregation (3 workers x 1M params)");
    b.measure_iters = 10;
    let updates: Vec<ClientUpdate> = (0..3)
        .map(|w| ClientUpdate {
            worker: w,
            n_samples: 1000 + w * 100,
            local_loss: 2.0 + w as f32 * 0.1,
            delta: params(N, w as u64 + 10),
            staleness: 0,
        })
        .collect();
    let mut global = params(N, 99);
    b.bench_throughput("fedavg", 3.0 * bytes, || {
        FedAvg.aggregate(&mut global, &updates)
    });
    b.bench_throughput("dynamic", 3.0 * bytes, || {
        DynamicWeighted::default().aggregate(&mut global, &updates)
    });
    b.report();

    // --- compression codecs
    let mut b = BenchSet::new("compression (1M f32)");
    b.measure_iters = 10;
    let xs = vecs(N, 3);
    for (name, scheme) in [
        ("none", Compression::None),
        ("fp16", Compression::Fp16),
        ("int8", Compression::Int8),
        ("topk-1%", Compression::TopK { ratio: 0.01 }),
        ("randk-1%", Compression::RandK { ratio: 0.01 }),
    ] {
        let mut c = Compressor::new(scheme, 7);
        b.bench_throughput(name, bytes, || c.compress(&xs));
    }
    let mut c = Compressor::new(Compression::TopK { ratio: 0.01 }, 7);
    let payload = c.compress(&xs);
    b.bench_throughput("decompress topk-1%", bytes, || {
        Compressor::decompress(&payload).unwrap()
    });
    b.report();

    // --- crypto
    let mut b = BenchSet::new("crypto (4 MB payload)");
    b.measure_iters = 10;
    let plaintext = vec![0xA5u8; N * 4];
    let mut key = TransportKey::derive(b"bench", "ctx");
    b.bench_throughput("seal (aes-ctr+hmac)", bytes, || seal(&mut key, &plaintext));
    let sealed = seal(&mut key, &plaintext);
    b.bench_throughput("open", bytes, || open(&key, &sealed).unwrap());
    b.report();

    // --- netsim transfer computation (pure model, no payload copies)
    let mut b = BenchSet::new("netsim transfer ops");
    b.measure_iters = 20;
    let mut wan = Wan::uniform(3, Link::new(1e9, 0.04), 5);
    b.bench_throughput("transfer calc x1000", 1000.0, || {
        for i in 0..1000u64 {
            wan.transfer(0, 1, 1_000_000 + i, Protocol::Quic, 16);
        }
    });
    b.report();

    // --- PJRT step (reference point for the whole stack)
    let backend = bench_common::Backend::detect();
    if let bench_common::Backend::Real { runtime, manifest } = &backend {
        let mut b = BenchSet::new("pjrt train/eval step (tiny model)");
        b.measure_iters = 10;
        let init = ParamSet::init(manifest, 1);
        let mut rng = Pcg64::new(1, 2);
        let n = manifest.model.batch_size * manifest.model.seq_len;
        let batch = crossfed::runtime::Batch {
            tokens: (0..n).map(|_| rng.below(96) as i32).collect(),
            targets: (0..n).map(|_| rng.below(96) as i32).collect(),
        };
        let flops_fwd_bwd = 6.0 * manifest.model.n_params as f64 * n as f64;
        b.bench_throughput("train_step (flops)", flops_fwd_bwd, || {
            runtime.train_step(&init, &batch).unwrap()
        });
        b.bench("eval_step", || runtime.eval_step(&init, &batch).unwrap());
        b.report();
    } else {
        println!("\n(pjrt step bench skipped: artifacts not built)");
    }
}

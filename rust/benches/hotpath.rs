//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 operations
//! that run once or more per round, measured standalone so the perf
//! pass can track them.
//!
//!     cargo bench --bench hotpath
//!
//! Targets (memory-bound roofline class): ≥1 GB/s per core for the
//! f32-vector kernels (axpy / aggregate / compress-none), crypto at
//! AES-CTR software speed, PJRT step time reported for reference.
//!
//! Every kernel set runs twice — pinned to 1 thread (the serial
//! baseline) and at full `available_parallelism()` — and the
//! serial/parallel comparison is written to `BENCH_hotpath.json` at the
//! repo root (deterministic kernels make the two passes bit-comparable;
//! see `crossfed::util::par`).

mod bench_common;

use crossfed::aggregation::{
    AggregationKind, Aggregator, ClientUpdate, DynamicWeighted, FedAvg,
};
use crossfed::cluster::ClusterSpec;
use crossfed::compress::{Compression, Compressor};
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::crypto::{open, seal, TransportKey};
use crossfed::data::CorpusConfig;
use crossfed::model::ParamSet;
use crossfed::netsim::{Link, Protocol, Wan};
use crossfed::runtime::MockRuntime;
use crossfed::testkit::bench_kit::{BenchResult, BenchSet};
use crossfed::util::json::Json;
use crossfed::util::par;
use crossfed::util::rng::Pcg64;

const N: usize = 1_000_000; // 4 MB of f32 — a mid-size model update

fn vecs(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 1);
    (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
}

fn params(n: usize, seed: u64) -> ParamSet {
    ParamSet { leaves: vec![vecs(n, seed)] }
}

/// One full pass over the parallelized kernel sets at a pinned thread
/// count. Returns the sets in a fixed order so two passes can be zipped.
fn kernel_pass(threads: usize) -> Vec<BenchSet> {
    par::with_threads(threads, || {
        let bytes = (N * 4) as f64;
        let mut sets = Vec::new();

        // --- ParamSet linear algebra (inner loop of every aggregator)
        let mut b = BenchSet::new(&format!("paramset ops (1M f32, {threads}T)"));
        b.measure_iters = 20;
        let mut p = params(N, 1);
        let q = params(N, 2);
        b.bench_throughput("axpy", bytes, || p.axpy(0.5, &q));
        b.bench_throughput("l2_norm", bytes, || p.l2_norm());
        b.bench_throughput("sub", bytes, || p.sub(&q));
        b.bench_throughput("to_flat", bytes, || p.to_flat());
        b.report();
        sets.push(b);

        // --- aggregation algorithms over 3 workers
        let mut b =
            BenchSet::new(&format!("aggregation (3 workers x 1M, {threads}T)"));
        b.measure_iters = 10;
        let updates: Vec<ClientUpdate> = (0..3)
            .map(|w| ClientUpdate {
                worker: w,
                n_samples: 1000 + w * 100,
                local_loss: 2.0 + w as f32 * 0.1,
                delta: params(N, w as u64 + 10),
                staleness: 0,
            })
            .collect();
        let mut global = params(N, 99);
        // aggregators hoisted out of the measured closures: the bench
        // measures aggregation, not constructor noise
        let mut fedavg = FedAvg;
        let mut dynamic = DynamicWeighted::default();
        b.bench_throughput("fedavg", 3.0 * bytes, || {
            fedavg.aggregate(&mut global, &updates)
        });
        b.bench_throughput("dynamic", 3.0 * bytes, || {
            dynamic.aggregate(&mut global, &updates)
        });
        b.report();
        sets.push(b);

        // --- compression codecs
        let mut b = BenchSet::new(&format!("compression (1M f32, {threads}T)"));
        b.measure_iters = 10;
        let xs = vecs(N, 3);
        for (name, scheme) in [
            ("none", Compression::None),
            ("fp16", Compression::Fp16),
            ("int8", Compression::Int8),
            ("topk-1%", Compression::TopK { ratio: 0.01 }),
            ("randk-1%", Compression::RandK { ratio: 0.01 }),
        ] {
            let mut c = Compressor::new(scheme, 7);
            let mut out = Vec::new();
            b.bench_throughput(name, bytes, || {
                out.clear();
                c.compress_append(&xs, &mut out)
            });
        }
        let mut c = Compressor::new(Compression::TopK { ratio: 0.01 }, 7);
        let payload = c.compress(&xs);
        b.bench_throughput("decompress topk-1%", bytes, || {
            Compressor::decompress(&payload).unwrap()
        });
        b.report();
        sets.push(b);

        // --- crypto
        let mut b = BenchSet::new(&format!("crypto (4 MB payload, {threads}T)"));
        b.measure_iters = 10;
        let plaintext = vec![0xA5u8; N * 4];
        let mut key = TransportKey::derive(b"bench", "ctx");
        b.bench_throughput("seal (aes-ctr+hmac)", bytes, || {
            seal(&mut key, &plaintext)
        });
        let sealed = seal(&mut key, &plaintext);
        b.bench_throughput("open", bytes, || open(&key, &sealed).unwrap());
        b.report();
        sets.push(b);

        sets
    })
}

fn gbps(r: &BenchResult) -> f64 {
    r.throughput().unwrap_or(0.0) / 1e9
}

/// Star vs hierarchical on the paper's clouds scaled to 8 nodes each:
/// per-round inter-region WAN bytes and simulated round time (mock
/// backend — the comparison is about the communication schedule, not the
/// compute).
fn hier_vs_star_entry() -> Json {
    let nodes_per_cloud = 8;
    let cluster = ClusterSpec::paper_default_scaled(nodes_per_cloud);
    let run = |hier: bool| {
        let mut cfg = preset("quick").expect("builtin");
        cfg.name = if hier { "bench-hier".into() } else { "bench-star".into() };
        cfg.hierarchical = hier;
        cfg.rounds = 2;
        cfg.eval_every = 1;
        cfg.eval_batches = 1;
        cfg.local_lr = 3.0;
        cfg.server_lr = 3.0;
        cfg.target_loss = None;
        cfg.corpus =
            CorpusConfig { n_docs: 120, doc_sentences: 2, n_topics: 6, seed: 3 };
        let backend = MockRuntime::new(0.4);
        let init = ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] };
        let mut coord =
            Coordinator::new(cfg, cluster.clone(), &backend, init, 4, 16)
                .expect("coordinator");
        let inter0 = coord.inter_region_wire_bytes();
        let sim0 = coord.sim_secs();
        let r = coord.run().expect("run");
        (
            (coord.inter_region_wire_bytes() - inter0) / 2, // per round
            (r.sim_secs - sim0) / 2.0,
        )
    };
    let (star_bytes, star_secs) = run(false);
    let (hier_bytes, hier_secs) = run(true);
    println!(
        "\n== bench: hier vs star (3 clouds x {nodes_per_cloud}) ==\n\
         inter-region bytes/round: star {star_bytes}  hier {hier_bytes}  \
         ({:.1}x less)\nsim secs/round: star {star_secs:.1}  hier {hier_secs:.1}",
        star_bytes as f64 / hier_bytes.max(1) as f64
    );
    Json::obj(vec![
        ("nodes_per_cloud", Json::num(nodes_per_cloud as f64)),
        ("star_inter_region_bytes_per_round", Json::num(star_bytes as f64)),
        ("hier_inter_region_bytes_per_round", Json::num(hier_bytes as f64)),
        (
            "inter_region_reduction",
            Json::num(
                ((star_bytes as f64 / hier_bytes.max(1) as f64) * 100.0).round()
                    / 100.0,
            ),
        ),
        ("star_sim_secs_per_round", Json::num((star_secs * 10.0).round() / 10.0)),
        ("hier_sim_secs_per_round", Json::num((hier_secs * 10.0).round() / 10.0)),
    ])
}

/// Synchronous barrier vs buffered async on the same hierarchy (3 clouds
/// x 8): per-round simulated seconds and simulator events — the price of
/// the barrier, and the event-engine throughput of the buffered path
/// (EXPERIMENTS.md §Elasticity).
fn hier_async_entry() -> Json {
    let nodes_per_cloud = 8;
    let cluster = ClusterSpec::paper_default_scaled(nodes_per_cloud);
    let run = |buffered: bool| {
        let mut cfg = preset("quick").expect("builtin");
        cfg.name =
            if buffered { "bench-hier-buf".into() } else { "bench-hier-sync".into() };
        cfg.hierarchical = true;
        if buffered {
            cfg.aggregation = AggregationKind::Async { alpha: 0.6 };
        }
        cfg.rounds = 2;
        cfg.eval_every = 1;
        cfg.eval_batches = 1;
        cfg.local_lr = 3.0;
        cfg.server_lr = 3.0;
        cfg.target_loss = None;
        cfg.corpus =
            CorpusConfig { n_docs: 120, doc_sentences: 2, n_topics: 6, seed: 3 };
        let backend = MockRuntime::new(0.4);
        let init = ParamSet { leaves: vec![vec![2.0f32; 64], vec![-1.0f32; 32]] };
        let mut coord =
            Coordinator::new(cfg, cluster.clone(), &backend, init, 4, 16)
                .expect("coordinator");
        let t0 = std::time::Instant::now();
        let r = coord.run().expect("run");
        (r.sim_secs / 2.0, coord.sim_events(), t0.elapsed().as_secs_f64())
    };
    let (sync_secs, _, _) = run(false);
    let (buf_secs, buf_events, buf_wall) = run(true);
    println!(
        "\n== bench: hier sync vs buffered async (3 clouds x \
         {nodes_per_cloud}) ==\nsim secs/round: sync {sync_secs:.1}  \
         buffered {buf_secs:.1}  ({:.2}x)\nbuffered engine: {} events, \
         {:.0} events/s",
        sync_secs / buf_secs.max(1e-9),
        buf_events,
        buf_events as f64 / buf_wall.max(1e-9)
    );
    let r1 = |x: f64| (x * 10.0).round() / 10.0;
    Json::obj(vec![
        ("nodes_per_cloud", Json::num(nodes_per_cloud as f64)),
        ("sync_sim_secs_per_round", Json::num(r1(sync_secs))),
        ("buffered_sim_secs_per_round", Json::num(r1(buf_secs))),
        (
            "barrier_cost",
            Json::num(((sync_secs / buf_secs.max(1e-9)) * 100.0).round() / 100.0),
        ),
        ("buffered_events", Json::num(buf_events as f64)),
        (
            "buffered_events_per_sec",
            Json::num((buf_events as f64 / buf_wall.max(1e-9)).round()),
        ),
    ])
}

/// Star vs hierarchy in *dollars* on the paper-default price book (same
/// scaled cluster as `hier_vs_star_entry`): per-round egress cost of the
/// training rounds, plus the auto-placement decision.
fn cost_star_vs_hier_entry() -> Json {
    use crossfed::cost::Placement;
    let nodes_per_cloud = 8;
    let cluster = ClusterSpec::paper_default_scaled(nodes_per_cloud);
    // params big enough that update traffic dwarfs the shard distribution
    let init = ParamSet {
        leaves: vec![vec![0.25f32; 8192], vec![-0.5f32; 4096]],
    };
    let run = |hier: bool, placement: Placement| {
        let mut cfg = preset("paper-hier-cost").expect("builtin");
        cfg.name = format!("bench-cost-{}", if hier { "hier" } else { "star" });
        cfg.hierarchical = hier;
        cfg.placement = placement;
        cfg.rounds = 2;
        cfg.eval_every = 1;
        cfg.eval_batches = 1;
        cfg.local_steps = 2;
        cfg.local_lr = 3.0;
        cfg.server_lr = 3.0;
        cfg.target_loss = None;
        cfg.corpus =
            CorpusConfig { n_docs: 120, doc_sentences: 2, n_topics: 6, seed: 3 };
        let backend = MockRuntime::new(0.4);
        let mut coord =
            Coordinator::new(cfg, cluster.clone(), &backend, init.clone(), 4, 16)
                .expect("coordinator");
        let leader_cloud = coord.leader_cloud();
        let r = coord.run().expect("run");
        let egress: f64 =
            r.history.iter().map(|h| h.cost.egress_total_usd()).sum();
        (egress / 2.0, leader_cloud)
    };
    let (star_usd, _) = run(false, Placement::Fixed(0));
    let (hier_usd, _) = run(true, Placement::Fixed(0));
    let (auto_usd, auto_cloud) = run(true, Placement::Auto);
    println!(
        "\n== bench: cost star vs hier (3 clouds x {nodes_per_cloud}, \
         paper-default prices) ==\negress $/round: star {star_usd:.4}  \
         hier {hier_usd:.4}  ({:.1}x less)  auto {auto_usd:.4} \
         (leader cloud {auto_cloud})",
        star_usd / hier_usd.max(1e-12)
    );
    let r4 = |x: f64| (x * 1e4).round() / 1e4;
    Json::obj(vec![
        ("nodes_per_cloud", Json::num(nodes_per_cloud as f64)),
        ("star_egress_usd_per_round", Json::num(r4(star_usd))),
        ("hier_egress_usd_per_round", Json::num(r4(hier_usd))),
        (
            "egress_saving",
            Json::num(((star_usd / hier_usd.max(1e-12)) * 100.0).round() / 100.0),
        ),
        ("auto_egress_usd_per_round", Json::num(r4(auto_usd))),
        ("auto_leader_cloud", Json::num(auto_cloud as f64)),
    ])
}

/// Simulator scale sweep: one hierarchical `par_rounds` round per cloud
/// count on heterogeneous scaled clusters (EXPERIMENTS.md §Scale),
/// measuring wall-clock throughput of the arena engine + indexed WAN
/// core in node-rounds/s and simulator events/s. Quick mode trims the
/// sweep so CI exercises the path without paying for the largest runs
/// (the 10k-node end is covered by the `planet_scale` example).
fn sim_scale_entry() -> Json {
    use crossfed::partition::PartitionStrategy;
    use crossfed::testkit::bench_kit::quick_mode;
    let clouds: &[usize] =
        if quick_mode() { &[1, 16] } else { &[1, 16, 64, 128] };
    let mut entries = Vec::new();
    println!(
        "\n== bench: sim scale (hierarchical par-rounds, {} threads) ==",
        par::current_threads()
    );
    for &nc in clouds {
        let cluster = ClusterSpec::scaled(nc, &[48, 40, 32]);
        let nodes = cluster.n();
        let mut cfg = preset("quick").expect("builtin");
        cfg.name = format!("bench-scale-{nc}");
        cfg.hierarchical = true;
        cfg.par_rounds = true;
        cfg.rounds = 1;
        cfg.eval_every = 1;
        cfg.eval_batches = 1;
        cfg.local_steps = 2;
        cfg.target_loss = None;
        // one doc per worker keeps every equal shard non-empty after the
        // 10% eval holdout
        cfg.partition = PartitionStrategy::Fixed;
        cfg.corpus = CorpusConfig {
            n_docs: nodes + nodes / 8 + 16,
            doc_sentences: 1,
            n_topics: 6,
            seed: 5,
        };
        let backend = MockRuntime::new(0.4);
        let init =
            ParamSet { leaves: vec![vec![0.5f32; 64], vec![-0.25f32; 32]] };
        let mut coord = Coordinator::new(cfg, cluster, &backend, init, 4, 16)
            .expect("coordinator");
        let t0 = std::time::Instant::now();
        coord.run().expect("run");
        let wall = t0.elapsed().as_secs_f64();
        let events = coord.sim_events();
        println!(
            "{nc:>4} clouds / {nodes:>5} nodes: wall {wall:>7.3}s  \
             {:>9.0} node-rounds/s  {:>9.0} events/s",
            nodes as f64 / wall,
            events as f64 / wall
        );
        entries.push(Json::obj(vec![
            ("clouds", Json::num(nc as f64)),
            ("nodes", Json::num(nodes as f64)),
            ("rounds", Json::num(1.0)),
            ("wall_secs", Json::num((wall * 1e3).round() / 1e3)),
            ("nodes_per_sec", Json::num((nodes as f64 / wall).round())),
            ("events_per_sec", Json::num((events as f64 / wall).round())),
        ]));
    }
    Json::arr(entries)
}

/// Serving throughput: one simulated day of cross-cloud inference on the
/// arena event engine (EXPERIMENTS.md §Serving), measuring wall-clock
/// requests/s and engine events/s. Quick mode trims the population so CI
/// exercises the path without paying for the full day.
fn serve_throughput_entry() -> Json {
    use crossfed::serve::{RoutePolicy, ServeConfig, TrafficSpec};
    use crossfed::testkit::bench_kit::quick_mode;
    let users: u64 = if quick_mode() { 50_000 } else { 500_000 };
    let cluster = ClusterSpec::scaled(6, &[1]);
    let cfg = ServeConfig {
        name: "bench-serve".into(),
        route: RoutePolicy::Blended(0.5),
        traffic: TrafficSpec { users, ..TrafficSpec::default() },
        ..ServeConfig::default()
    };
    let t0 = std::time::Instant::now();
    let r = crossfed::serve::run(&cfg, &cluster).expect("serve run");
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n== bench: serve throughput (6 clouds, {users} users, 1 day) ==\n\
         {} requests / {} events in {wall:.3}s wall: {:.0} req/s  \
         {:.0} events/s  (p50 {:.0} ms, p99 {:.0} ms, ${:.2}/M-req)",
        r.requests,
        r.events,
        r.requests as f64 / wall.max(1e-9),
        r.events as f64 / wall.max(1e-9),
        r.p50_ms,
        r.p99_ms,
        r.usd_per_million()
    );
    Json::obj(vec![
        ("users", Json::num(users as f64)),
        ("clouds", Json::num(6.0)),
        ("requests", Json::num(r.requests as f64)),
        ("events", Json::num(r.events as f64)),
        ("wall_secs", Json::num((wall * 1e3).round() / 1e3)),
        (
            "requests_per_sec",
            Json::num((r.requests as f64 / wall.max(1e-9)).round()),
        ),
        (
            "events_per_sec",
            Json::num((r.events as f64 / wall.max(1e-9)).round()),
        ),
        ("p50_ms", Json::num((r.p50_ms * 10.0).round() / 10.0)),
        ("p99_ms", Json::num((r.p99_ms * 10.0).round() / 10.0)),
        (
            "usd_per_million",
            Json::num((r.usd_per_million() * 100.0).round() / 100.0),
        ),
    ])
}

/// Lossless stage codecs on a 4 MB smooth-gradient payload
/// (EXPERIMENTS.md §Compression): encode/decode GB/s and the achieved
/// ratio per stage — the numbers the perf gate floors.
fn lossless_entry() -> Json {
    use crossfed::compress::{lossless, LosslessStage};
    let xs: Vec<f32> =
        (0..N).map(|i| ((i as f32) * 1e-4).sin() * 0.1).collect();
    let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
    let total = bytes.len() as f64;
    let mut b = BenchSet::new("lossless stage (4 MB smooth gradient)");
    b.measure_iters = 10;
    let mut ratios = Vec::new();
    for stage in [
        LosslessStage::XorFloat,
        LosslessStage::DeltaVarint,
        LosslessStage::Auto,
    ] {
        let name = stage.name();
        let mut enc = Vec::new();
        b.bench_throughput(&format!("{name} encode"), total, || {
            enc.clear();
            lossless::encode_append(stage, &bytes, &mut enc)
        });
        let mut dec = Vec::new();
        b.bench_throughput(&format!("{name} decode"), total, || {
            lossless::decode_into(&enc, &mut dec).unwrap()
        });
        assert_eq!(dec, bytes, "{name}: bench payload must roundtrip");
        ratios.push(total / enc.len() as f64);
    }
    b.report();
    let g3 = |r: &BenchResult| (gbps(r) * 1e3).round() / 1e3;
    let r3 = |x: f64| (x * 1e3).round() / 1e3;
    Json::obj(vec![
        ("payload_bytes", Json::num(total)),
        ("xor_encode_gbps", Json::num(g3(&b.results[0]))),
        ("xor_decode_gbps", Json::num(g3(&b.results[1]))),
        ("xor_ratio", Json::num(r3(ratios[0]))),
        ("varint_encode_gbps", Json::num(g3(&b.results[2]))),
        ("varint_decode_gbps", Json::num(g3(&b.results[3]))),
        ("varint_ratio", Json::num(r3(ratios[1]))),
        ("auto_encode_gbps", Json::num(g3(&b.results[4]))),
        ("auto_decode_gbps", Json::num(g3(&b.results[5]))),
        ("auto_ratio", Json::num(r3(ratios[2]))),
    ])
}

/// WAL round-record durability: CRC + write + fsync of a snapshot-sized
/// record — the per-round price of crash consistency (EXPERIMENTS.md
/// §Durability).
fn wal_append_entry() -> Json {
    use crossfed::wal::{ByteWriter, WalFile, WalHeader};
    let dir = std::env::temp_dir().join("crossfed-bench-wal");
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join("bench.wal");
    let header = WalHeader {
        experiment: "bench".into(),
        seed: 1,
        n_workers: 3,
        leaf_sizes: vec![N as u32],
    };
    let mut wal = WalFile::create(&path, &header).expect("wal create");
    // a snapshot-sized payload: 1M f32 bit patterns, as wal_state writes
    let xs = vecs(N, 11);
    let mut w = ByteWriter::new();
    for x in &xs {
        w.put_u32(x.to_bits());
    }
    let payload = w.into_bytes();
    let bytes = payload.len() as f64;
    let mut b = BenchSet::new("wal append (4 MB snapshot record, fsync)");
    b.measure_iters = 10;
    b.bench_throughput("append+fsync", bytes, || wal.append(&payload).unwrap());
    b.report();
    let r = &b.results[0];
    let entry = Json::obj(vec![
        ("record_bytes", Json::num(bytes)),
        ("append_fsync_gbps", Json::num((gbps(r) * 1e3).round() / 1e3)),
        (
            "append_fsync_ms",
            Json::num((r.summary.mean * 1e3 * 1e3).round() / 1e3),
        ),
    ]);
    drop(wal);
    std::fs::remove_dir_all(&dir).ok();
    entry
}

fn write_json(
    hw: usize,
    serial: &[BenchSet],
    parallel: &[BenchSet],
    sections: Vec<(&'static str, Json)>,
) {
    let mut entries = Vec::new();
    for (sb, pb) in serial.iter().zip(parallel) {
        for (sr, pr) in sb.results.iter().zip(&pb.results) {
            entries.push(Json::obj(vec![
                ("name", Json::str(sr.name.clone())),
                ("serial_gbps", Json::num((gbps(sr) * 1e3).round() / 1e3)),
                ("parallel_gbps", Json::num((gbps(pr) * 1e3).round() / 1e3)),
                (
                    "speedup",
                    Json::num(
                        ((sr.summary.mean / pr.summary.mean) * 100.0).round() / 100.0,
                    ),
                ),
            ]));
        }
    }
    let mut fields = vec![
        ("bench", Json::str("hotpath")),
        ("elements", Json::num(N as f64)),
        ("threads", Json::num(hw as f64)),
        ("results", Json::arr(entries)),
    ];
    fields.extend(sections);
    let doc = Json::obj(fields);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

fn main() {
    let hw = par::current_threads();
    println!("== hotpath: serial baseline (1 thread) ==");
    let serial = kernel_pass(1);
    println!("\n== hotpath: parallel ({hw} threads) ==");
    let parallel = kernel_pass(hw);
    let sections = vec![
        ("hier_vs_star", hier_vs_star_entry()),
        ("hier_async", hier_async_entry()),
        ("cost_star_vs_hier", cost_star_vs_hier_entry()),
        ("lossless", lossless_entry()),
        ("wal_append", wal_append_entry()),
        ("sim_scale", sim_scale_entry()),
        ("serve_throughput", serve_throughput_entry()),
    ];
    write_json(hw, &serial, &parallel, sections);

    // --- netsim transfer computation (pure model, no payload copies)
    let mut b = BenchSet::new("netsim transfer ops");
    b.measure_iters = 20;
    let mut wan = Wan::uniform(3, Link::new(1e9, 0.04), 5);
    b.bench_throughput("transfer calc x1000", 1000.0, || {
        for i in 0..1000u64 {
            wan.transfer(0, 1, 1_000_000 + i, Protocol::Quic, 16).unwrap();
        }
    });
    b.report();

    // --- PJRT step (reference point for the whole stack)
    let backend = bench_common::Backend::detect();
    if let bench_common::Backend::Real { runtime, manifest } = &backend {
        let mut b = BenchSet::new("pjrt train/eval step (tiny model)");
        b.measure_iters = 10;
        let init = ParamSet::init(manifest, 1);
        let mut rng = Pcg64::new(1, 2);
        let n = manifest.model.batch_size * manifest.model.seq_len;
        let batch = crossfed::runtime::Batch {
            tokens: (0..n).map(|_| rng.below(96) as i32).collect(),
            targets: (0..n).map(|_| rng.below(96) as i32).collect(),
        };
        let flops_fwd_bwd = 6.0 * manifest.model.n_params as f64 * n as f64;
        b.bench_throughput("train_step (flops)", flops_fwd_bwd, || {
            runtime.train_step(&init, &batch).unwrap()
        });
        b.bench("eval_step", || runtime.eval_step(&init, &batch).unwrap());
        b.report();
    } else {
        println!("\n(pjrt step bench skipped: artifacts not built)");
    }
}

//! Regenerates **Table 3**: convergence accuracy (%) and final loss for
//! the three aggregation algorithms under non-IID shards.
//!
//!     cargo bench --bench table3_convergence
//!
//! Paper values: FedAvg 87.5% / 0.34, Dynamic 90.2% / 0.29,
//! Gradient 91.5% / 0.27. Absolute accuracy is task-specific (the paper
//! never defines its metric's task); the reproduction target is the
//! *ordering* — gradient > dynamic > fedavg on accuracy, the reverse on
//! loss — and the rough relative gaps.

mod bench_common;

use bench_common::Backend;
use crossfed::config::preset;
use crossfed::metrics::RunResult;
use crossfed::report;

const PAPER: [(&str, f64, f64); 3] = [
    ("paper-fedavg", 87.5, 0.34),
    ("paper-dynamic", 90.2, 0.29),
    ("paper-gradient", 91.5, 0.27),
];

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let mut results: Vec<RunResult> = Vec::new();
    for (name, _, _) in PAPER {
        // Table 3 measures convergence quality at the full round budget,
        // so disable the early-stop target here.
        let mut cfg = preset(name).expect("builtin preset");
        cfg.target_loss = None;
        let r = backend.run(&cfg);
        println!(
            "{name}: acc {:.1}%, loss {:.3} ({} rounds)",
            r.acc_pct(),
            r.final_eval_loss,
            r.rounds_run
        );
        results.push(r);
    }

    let refs: Vec<&RunResult> = results.iter().collect();
    let t3 = report::table3(&refs);
    println!("\n{t3}");
    println!("paper reference:");
    for (name, acc, loss) in PAPER {
        println!("  {name:<18} {acc:>5.1} % {loss:>6.2}");
    }

    let acc: Vec<f64> = results.iter().map(|r| r.acc_pct()).collect();
    let loss: Vec<f64> =
        results.iter().map(|r| r.final_eval_loss as f64).collect();
    let ok_acc = acc[2] >= acc[1] * 0.98 && acc[1] > acc[0];
    let ok_loss = loss[2] <= loss[1] * 1.02 && loss[1] < loss[0];
    println!(
        "\nordering check: acc gradient>=dynamic>fedavg: {} | \
         loss gradient<=dynamic<fedavg: {}",
        if ok_acc { "OK" } else { "MISMATCH" },
        if ok_loss { "OK" } else { "MISMATCH" },
    );
    report::save(
        "table3.txt",
        &format!("{t3}\nordering acc={ok_acc} loss={ok_loss}\n"),
    );
}

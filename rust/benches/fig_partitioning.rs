//! Regenerates the Figure-2 cycle ablation: fixed vs dynamic data
//! partitioning under heterogeneous and *shifting* platform capacity.
//!
//!     cargo bench --bench fig_partitioning
//!
//! Scenario: a 4x compute spread plus a mid-run slowdown of the fastest
//! platform. Fixed partitioning keeps equal shards (the slow platform
//! gates every barrier); the dynamic planner re-sizes shards from the
//! load monitor's capacity estimates ("Monitor and Adjust in Real-Time").

mod bench_common;

use bench_common::Backend;
use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::report;
use crossfed::util::stats::imbalance_cv;

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let cluster = ClusterSpec::heterogeneous(3, 4.0);
    let mut rows = Vec::new();
    for name in ["fig-partition-fixed", "fig-partition-dynamic"] {
        let cfg = preset(name).expect("builtin");
        let r = backend.run_on(&cfg, cluster.clone());
        // load imbalance: CV of per-platform compute time, averaged over
        // the second half of the run (post-adaptation)
        let half = r.history.len() / 2;
        let cvs: Vec<f64> = r.history[half..]
            .iter()
            .filter(|h| !h.platform_secs.is_empty())
            .map(|h| imbalance_cv(&h.platform_secs))
            .collect();
        let mean_cv = cvs.iter().sum::<f64>() / cvs.len().max(1) as f64;
        let regens = r.history.last().map(|h| h.partition_gen).unwrap_or(0);
        println!(
            "{name:<24} sim={:.2} h  imbalance_cv={:.3}  replans={}",
            r.sim_hours(),
            mean_cv,
            regens
        );
        rows.push((name.to_string(), r, mean_cv));
    }

    let fixed = &rows[0];
    let dynamic = &rows[1];
    let speedup = fixed.1.sim_secs / dynamic.1.sim_secs;
    let ok_balance = dynamic.2 < fixed.2;
    // NOTE: with synchronized rounds the barrier still waits for the
    // slowest platform's *steps*; dynamic partitioning rebalances the
    // per-round data (and with it steady-state step time via shard-size-
    // driven local work in bigger deployments). The reproducible claims:
    // better balance, no slowdown.
    println!(
        "\ndynamic vs fixed: wall-clock speedup {speedup:.2}x, \
         imbalance {:.3} -> {:.3} ({})",
        fixed.2,
        dynamic.2,
        if ok_balance { "OK" } else { "MISMATCH" }
    );
    report::save(
        "fig_partitioning.txt",
        &format!(
            "fixed:   {:.2} h, cv {:.3}\ndynamic: {:.2} h, cv {:.3}\nspeedup {speedup:.2}x\n",
            fixed.1.sim_hours(),
            fixed.2,
            dynamic.1.sim_hours(),
            dynamic.2
        ),
    );
}

//! Regenerates **Table 2**: communication overhead (GB) and training time
//! (hours) for FedAvg / dynamic weighted / gradient aggregation.
//!
//!     cargo bench --bench table2_comm_overhead
//!
//! Paper values (testbed-specific absolutes; we reproduce the *ordering*
//! and rough factors — see EXPERIMENTS.md):
//!   FedAvg 4.5 GB / 12 h, Dynamic 3.8 GB / 10.5 h, Gradient 3.6 GB / 9.8 h

mod bench_common;

use bench_common::Backend;
use crossfed::config::preset;
use crossfed::metrics::RunResult;
use crossfed::report;

const PAPER: [(&str, f64, f64); 3] = [
    ("paper-fedavg", 4.5, 12.0),
    ("paper-dynamic", 3.8, 10.5),
    ("paper-gradient", 3.6, 9.8),
];

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let mut results: Vec<RunResult> = Vec::new();
    let mut configs = Vec::new();
    for (name, _, _) in PAPER {
        let cfg = preset(name).expect("builtin preset");
        configs.push(cfg.clone());
        let t0 = std::time::Instant::now();
        let r = backend.run(&cfg);
        println!(
            "{name}: {} rounds, {:.2} GB, {:.1} sim-h ({:.1}s host){}",
            r.rounds_run,
            r.comm_gb(),
            r.sim_hours(),
            t0.elapsed().as_secs_f64(),
            if r.reached_target { " [target reached]" } else { "" },
        );
        results.push(r);
    }

    let refs: Vec<&RunResult> = results.iter().collect();
    let crefs: Vec<&crossfed::config::ExperimentConfig> =
        configs.iter().collect();
    let t1 = report::table1(&crefs);
    let t2 = report::table2(&refs);
    println!("\n{t1}");
    println!("{t2}");
    println!("paper reference:");
    for (name, gb, h) in PAPER {
        println!("  {name:<18} {gb:>5.1} GB {h:>6.1} h");
    }

    // reproduction checks: ordering must match the paper
    let gb: Vec<f64> = results.iter().map(|r| r.comm_gb()).collect();
    let hours: Vec<f64> = results.iter().map(|r| r.sim_hours()).collect();
    let ok_comm = gb[0] >= gb[1] && gb[1] >= gb[2];
    let ok_time = hours[0] >= hours[1] && hours[1] >= hours[2];
    println!(
        "\nordering check: comm fedavg>=dynamic>=gradient: {} | \
         time fedavg>=dynamic>=gradient: {}",
        if ok_comm { "OK" } else { "MISMATCH" },
        if ok_time { "OK" } else { "MISMATCH" },
    );
    report::save(
        "table2.txt",
        &format!("{t1}\n{t2}\nordering comm={ok_comm} time={ok_time}\n"),
    );
}

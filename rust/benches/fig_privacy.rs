//! Regenerates the security/privacy ablation (paper §3.1 "Ensure Data
//! Security" + the encryption / differential-privacy discussion):
//! overhead and accuracy cost of AES transport sealing, secure
//! aggregation, DP, and the homomorphic-encryption cost model.
//!
//!     cargo bench --bench fig_privacy

mod bench_common;

use bench_common::Backend;
use crossfed::config::preset;
use crossfed::crypto::he_cost;
use crossfed::privacy::DpConfig;
use crossfed::report;

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let mut rows: Vec<(String, crossfed::metrics::RunResult)> = Vec::new();
    let mut csv = String::from("variant,comm_mb,sim_hours,eval_loss,epsilon\n");
    type Tweak = Box<dyn Fn(&mut crossfed::config::ExperimentConfig)>;
    let variants: Vec<(&str, Tweak)> = vec![
        ("plaintext", Box::new(|c| c.encrypt = false)),
        ("aes", Box::new(|c| c.encrypt = true)),
        ("aes+secureagg", Box::new(|c| {
            c.encrypt = true;
            c.secure_agg = true;
        })),
        ("aes+sa+dp(z=.02)", Box::new(|c| {
            c.encrypt = true;
            c.secure_agg = true;
            c.dp = DpConfig { clip_norm: 2.0, noise_multiplier: 0.02, delta: 1e-5 };
        })),
    ];

    for (name, tweak) in variants {
        let mut cfg = preset("privacy-off").expect("builtin");
        cfg.name = name.to_string();
        tweak(&mut cfg);
        cfg.validate().expect("valid variant");
        let r = backend.run(&cfg);
        let eps = r.history.last().map(|h| h.epsilon).unwrap_or(0.0);
        println!(
            "{name:<18} comm={:>8.2} MB  time={:.2} h  loss={:.3}  eps={}",
            r.wire_bytes as f64 / 1e6,
            r.sim_hours(),
            r.final_eval_loss,
            if eps > 0.0 { format!("{eps:.1}") } else { "-".into() }
        );
        csv.push_str(&format!(
            "{name},{:.2},{:.3},{:.4},{eps:.2}\n",
            r.wire_bytes as f64 / 1e6,
            r.sim_hours(),
            r.final_eval_loss
        ));
        rows.push((name.to_string(), r));
    }
    report::save("fig_privacy.csv", &csv);

    // the HE alternative, priced from the cost model
    let n = 109_824; // tiny-preset params (manifest value)
    let he = he_cost();
    println!(
        "\nHE (Paillier-2048) cost model on this update size: {:.1} MB/update \
         wire ({}x masking), +{:.1} min/round compute",
        he.wire_bytes(n) as f64 / 1e6,
        (he.bytes_per_elem / 4.0) as u64,
        he.round_secs(3, n) / 60.0
    );

    // checks
    let get = |n: &str| &rows.iter().find(|(m, _)| m == n).unwrap().1;
    let plain = get("plaintext");
    let aes = get("aes");
    let overhead =
        aes.wire_bytes as f64 / plain.wire_bytes as f64 - 1.0;
    println!(
        "\nchecks: AES byte overhead {:.2}% (should be <1%: {}), \
         secure-agg loss delta {:.3} (should be ~0)",
        overhead * 100.0,
        if overhead < 0.01 { "OK" } else { "MISMATCH" },
        (get("aes+secureagg").final_eval_loss - aes.final_eval_loss).abs()
    );
}

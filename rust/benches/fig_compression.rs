//! Regenerates the §3.2 gradient-compression ablation: bytes-on-wire vs
//! convergence for each codec, plus error-feedback on/off.
//!
//!     cargo bench --bench fig_compression
//!
//! Paper claim: "Compressing or sparsifying model parameters can
//! significantly reduce the volume of data that needs to be transmitted".

mod bench_common;

use bench_common::Backend;
use crossfed::compress::Compression;
use crossfed::config::preset;
use crossfed::report;

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let variants: Vec<(&str, Compression, bool)> = vec![
        ("none", Compression::None, false),
        ("fp16", Compression::Fp16, false),
        ("int8", Compression::Int8, false),
        ("topk-10% +EF", Compression::TopK { ratio: 0.10 }, true),
        ("topk-10% no-EF", Compression::TopK { ratio: 0.10 }, false),
        ("randk-10% +EF", Compression::RandK { ratio: 0.10 }, true),
    ];

    let mut rows = Vec::new();
    let mut csv = String::from("variant,comm_mb,eval_loss,acc_pct\n");
    for (name, compression, ef) in variants {
        let mut cfg = preset("paper-fedavg").expect("builtin");
        cfg.name = name.to_string();
        cfg.compression = compression;
        cfg.error_feedback = ef;
        cfg.rounds = 40;
        cfg.target_loss = None;
        let r = backend.run(&cfg);
        println!(
            "{name:<18} comm={:>8.2} MB  eval_loss={:.3}  acc={:.1}%",
            r.wire_bytes as f64 / 1e6,
            r.final_eval_loss,
            r.acc_pct()
        );
        csv.push_str(&format!(
            "{name},{:.2},{:.4},{:.2}\n",
            r.wire_bytes as f64 / 1e6,
            r.final_eval_loss,
            r.acc_pct()
        ));
        rows.push((name, r));
    }
    report::save("fig_compression.csv", &csv);

    let get = |n: &str| rows.iter().find(|(m, _)| *m == n).unwrap();
    let dense = get("none");
    let topk = get("topk-10% +EF");
    let topk_noef = get("topk-10% no-EF");
    // the run total includes the *dense* downlink broadcast plus the
    // shard distribution, so uplink top-k 10% lands the total near
    // (0.1·up + down) / (up + down) ≈ 60% — the meaningful bound is <75%
    println!(
        "\nchecks: topk total bytes {:.0}% of dense (uplink-only would be ~10%; {}), \
         EF loss {:.3} <= no-EF {:.3} ({})",
        100.0 * topk.1.wire_bytes as f64 / dense.1.wire_bytes as f64,
        if (topk.1.wire_bytes as f64) < dense.1.wire_bytes as f64 * 0.75 { "OK" } else { "MISMATCH" },
        topk.1.final_eval_loss,
        topk_noef.1.final_eval_loss,
        if topk.1.final_eval_loss <= topk_noef.1.final_eval_loss + 0.05 {
            "OK"
        } else {
            "MISMATCH"
        },
    );
}

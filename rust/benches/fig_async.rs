//! Regenerates the §3.2/§3.3 sync-vs-async comparison under stragglers
//! (paper formula 4 + "asynchronous communication allows cloud platforms
//! to transmit data and update models at different times, easing network
//! pressure").
//!
//!     cargo bench --bench fig_async
//!
//! Scenario: the paper's 3-cloud cluster with heavy transient stragglers.
//! Sync (FedAvg) pays the straggler at every barrier; async keeps fast
//! platforms busy and discounts stale updates.

mod bench_common;

use bench_common::Backend;
use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::report;

fn straggler_cluster(prob: f64, factor: f64) -> ClusterSpec {
    let mut c = ClusterSpec::paper_default();
    for p in &mut c.platforms {
        p.straggler_prob = prob;
        p.straggler_factor = factor;
    }
    c
}

fn main() {
    crossfed::util::logging::init();
    let backend = Backend::detect();
    println!("backend: {}", backend.name());

    let mut csv = String::from("straggler,mode,sim_hours,eval_loss\n");
    for &(prob, factor) in &[(0.0, 1.0), (0.1, 4.0), (0.25, 6.0)] {
        let cluster = straggler_cluster(prob, factor);
        let mut line = format!("stragglers p={prob} x{factor}: ");
        let mut times = Vec::new();
        for (mode, preset_name) in
            [("sync", "paper-fedavg"), ("async", "paper-async")]
        {
            let mut cfg = preset(preset_name).expect("builtin");
            cfg.name = format!("{mode}-p{prob}");
            cfg.rounds = 30;
            cfg.target_loss = None;
            let r = backend.run_on(&cfg, cluster.clone());
            line.push_str(&format!(
                "{mode} {:.2} h (loss {:.3})  ",
                r.sim_hours(),
                r.final_eval_loss
            ));
            csv.push_str(&format!(
                "p{prob}x{factor},{mode},{:.3},{:.4}\n",
                r.sim_hours(),
                r.final_eval_loss
            ));
            times.push(r.sim_secs);
        }
        let speedup = times[0] / times[1];
        line.push_str(&format!("async speedup {speedup:.2}x"));
        println!("{line}");
    }
    report::save("fig_async.csv", &csv);
    println!(
        "\nexpected shape: async speedup grows with straggler severity \
         while loss stays comparable (staleness discount)"
    );
}

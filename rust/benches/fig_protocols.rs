//! Regenerates the §3.2 protocol comparison (gRPC vs QUIC, TCP baseline):
//! transfer-time series across payload sizes, RTTs and loss rates, plus
//! an end-to-end federated round-time comparison.
//!
//!     cargo bench --bench fig_protocols
//!
//! Paper claim: "protocols specifically designed for distributed
//! computing, such as gRPC or QUIC, can better handle high-latency,
//! low-bandwidth network environments"; QUIC additionally avoids TCP's
//! head-of-line blocking on lossy links.

mod bench_common;

use bench_common::Backend;
use crossfed::config::preset;
use crossfed::netsim::{Link, Protocol, Wan};
use crossfed::report;

fn transfer_series() -> String {
    let mut csv = String::from("payload_mb,rtt_ms,loss_pct,protocol,secs\n");
    println!("transfer model sweep (warm connections, 16 streams):");
    println!(
        "{:<12} {:>8} {:>8} | {:>9} {:>9} {:>9}  quic/grpc",
        "payload", "rtt", "loss", "tcp", "grpc", "quic"
    );
    for &payload_mb in &[1.0f64, 16.0, 64.0] {
        for &(rtt_ms, loss) in &[(10.0, 0.0), (80.0, 0.002), (120.0, 0.01), (200.0, 0.03)] {
            let mut secs = Vec::new();
            for proto in [Protocol::Tcp, Protocol::Grpc, Protocol::Quic] {
                let link = Link {
                    bandwidth_bps: 1e9,
                    rtt_s: rtt_ms / 1e3,
                    jitter: 0.0,
                    loss_rate: loss,
                };
                let mut wan = Wan::uniform(2, link, 1);
                // warm the connection first
                wan.transfer(0, 1, 1000, proto, 16).unwrap();
                let st = wan
                    .transfer(0, 1, (payload_mb * 1e6) as u64, proto, 16)
                    .unwrap();
                csv.push_str(&format!(
                    "{payload_mb},{rtt_ms},{},{},{:.4}\n",
                    loss * 100.0,
                    proto.name(),
                    st.time_s
                ));
                secs.push(st.time_s);
            }
            println!(
                "{:<12} {:>6}ms {:>7}% | {:>8.3}s {:>8.3}s {:>8.3}s  {:>6.2}",
                format!("{payload_mb} MB"),
                rtt_ms,
                loss * 100.0,
                secs[0],
                secs[1],
                secs[2],
                secs[2] / secs[1],
            );
        }
    }
    csv
}

fn main() {
    crossfed::util::logging::init();
    let csv = transfer_series();
    report::save("fig_protocols.csv", &csv);

    // end-to-end: same experiment under each protocol preset
    let backend = Backend::detect();
    println!("\nend-to-end federated run per protocol ({}):", backend.name());
    let mut rows = Vec::new();
    for name in ["fig-protocol-tcp", "fig-protocol-grpc", "fig-protocol-quic"] {
        let mut cfg = preset(name).expect("builtin");
        // isolate communication: make the WAN the bottleneck
        cfg.base_step_secs = 1.0;
        let r = backend.run(&cfg);
        println!(
            "  {name:<22} sim={:.2} h comm={:.2} MB",
            r.sim_hours(),
            r.wire_bytes as f64 / 1e6
        );
        rows.push((name, r));
    }
    let t = |n: &str| rows.iter().find(|(m, _)| *m == n).unwrap().1.sim_secs;
    let ok = t("fig-protocol-quic") <= t("fig-protocol-grpc")
        && t("fig-protocol-grpc") <= t("fig-protocol-tcp") * 1.05;
    println!(
        "\nordering check: quic <= grpc <= ~tcp: {}",
        if ok { "OK" } else { "MISMATCH" }
    );
}

//! Integration: the Figure-2 partitioning cycle end-to-end over the mock
//! backend — monitor detects skew, planner re-partitions, load balances.

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::model::ParamSet;
use crossfed::partition::PartitionStrategy;
use crossfed::runtime::MockRuntime;
use crossfed::util::stats::imbalance_cv;

fn run(strategy: PartitionStrategy) -> crossfed::metrics::RunResult {
    let mut cfg = preset("quick").unwrap();
    cfg.name = format!("cycle-{}", strategy.name());
    cfg.partition = strategy;
    cfg.proportional_local_work = true;
    cfg.rounds = 30;
    cfg.local_steps = 4;
    cfg.local_lr = 3.0;
    let backend = MockRuntime::new(0.3);
    // 4x compute spread: the monitor must notice
    let cluster = ClusterSpec::heterogeneous(3, 4.0);
    let init = ParamSet { leaves: vec![vec![1.5; 48]] };
    let mut coord = Coordinator::new(cfg, cluster, &backend, init, 4, 16).unwrap();
    coord.run().unwrap()
}

#[test]
fn dynamic_partitioning_rebalances_load() {
    let fixed = run(PartitionStrategy::Fixed);
    let dynamic = run(PartitionStrategy::Dynamic);

    // fixed never re-plans; dynamic must have re-planned at least once
    assert_eq!(fixed.history.last().unwrap().partition_gen, 0);
    assert!(dynamic.history.last().unwrap().partition_gen >= 1);

    // post-adaptation imbalance must be lower under dynamic
    let tail_cv = |r: &crossfed::metrics::RunResult| {
        let tail = &r.history[r.history.len() / 2..];
        let cvs: Vec<f64> = tail
            .iter()
            .filter(|h| !h.platform_secs.is_empty())
            .map(|h| imbalance_cv(&h.platform_secs))
            .collect();
        cvs.iter().sum::<f64>() / cvs.len() as f64
    };
    let (cv_f, cv_d) = (tail_cv(&fixed), tail_cv(&dynamic));
    assert!(
        cv_d < cv_f * 0.8,
        "dynamic cv {cv_d:.3} not clearly below fixed cv {cv_f:.3}"
    );

    // and the wall clock improves
    assert!(
        dynamic.sim_secs < fixed.sim_secs,
        "dynamic {:.0}s !< fixed {:.0}s",
        dynamic.sim_secs,
        fixed.sim_secs
    );
}

#[test]
fn replans_pay_distribution_bytes() {
    let fixed = run(PartitionStrategy::Fixed);
    let dynamic = run(PartitionStrategy::Dynamic);
    // re-distribution is not free: the dynamic run's ledger includes the
    // extra shard transfers (visible as a byte jump at the replan round)
    let jump = dynamic
        .history
        .windows(2)
        .map(|w| w[1].wire_bytes - w[0].wire_bytes)
        .max()
        .unwrap();
    let typical = fixed
        .history
        .windows(2)
        .map(|w| w[1].wire_bytes - w[0].wire_bytes)
        .max()
        .unwrap();
    assert!(jump > typical, "no distribution cost visible: {jump} vs {typical}");
}

#[test]
fn adaptive_granularity_coarsens_when_comm_bound() {
    // make communication brutally expensive so the controller must react
    let mut cfg = preset("quick").unwrap();
    cfg.adaptive_granularity = true;
    cfg.rounds = 25;
    cfg.local_steps = 2;
    cfg.local_lr = 3.0;
    cfg.base_step_secs = 0.001; // compute ~free -> comm dominates
    let backend = MockRuntime::new(0.3);
    let cluster = ClusterSpec::paper_default();
    let init = ParamSet { leaves: vec![vec![1.0; 32]] };
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init, 4, 16).unwrap();
    let before = coord.run().unwrap();
    // comm-bound + adaptive granularity -> later rounds run longer local
    // phases; observable as fewer bytes per unit of simulated time than a
    // fixed-granularity run of the same length
    let mut cfg2 = preset("quick").unwrap();
    cfg2.adaptive_granularity = false;
    cfg2.rounds = 25;
    cfg2.local_steps = 2;
    cfg2.local_lr = 3.0;
    cfg2.base_step_secs = 0.001;
    let mut coord2 = Coordinator::new(
        cfg2,
        ClusterSpec::paper_default(),
        &backend,
        ParamSet { leaves: vec![vec![1.0; 32]] },
        4,
        16,
    )
    .unwrap();
    let fixed = coord2.run().unwrap();
    // same number of rounds, same per-round comm -> equal bytes; but the
    // adaptive run amortizes them over more local work (more steps), so
    // its *training* progressed further per byte
    assert_eq!(before.rounds_run, fixed.rounds_run);
    assert!(before.final_eval_loss <= fixed.final_eval_loss + 0.05);
}

//! `FaultPlan` spec-string surface (ISSUE 5 satellite): the parser's
//! error cases — malformed kinds, keys of the wrong kind, duplicate
//! keys — plus horizon validation and the config-JSON round-trip,
//! exercised through the same public surfaces the CLI and config files
//! use.

use crossfed::config::ExperimentConfig;
use crossfed::netsim::{FaultEvent, FaultPlan};

#[test]
fn every_kind_parses_and_round_trips_through_display() {
    let specs = [
        ("gateway-down:cloud=1,at=round3", FaultEvent::GatewayDown { cloud: 1, at: 3 }),
        ("restore:cloud=1,at=5", FaultEvent::GatewayRestore { cloud: 1, at: 5 }),
        (
            "link-degrade:src=0,dst=4,at=2,factor=0.25",
            FaultEvent::LinkDegrade { src: 0, dst: 4, at: 2, factor: 0.25 },
        ),
        (
            "node-slowdown:node=5,at=round4,factor=2",
            FaultEvent::NodeSlowdown { node: 5, at: 4, factor: 2.0 },
        ),
    ];
    for (spec, want) in specs {
        let ev = FaultEvent::parse(spec).unwrap();
        assert_eq!(ev, want, "{spec}");
        // canonical form re-parses to the same event
        assert_eq!(FaultEvent::parse(&ev.to_string()).unwrap(), ev, "{spec}");
    }
    // whitespace tolerance and `;` lists
    let plan = FaultPlan::parse(
        " gateway-down:cloud=1,at=3 ; restore:cloud=1, at=round5 ;;",
    )
    .unwrap();
    assert_eq!(plan.len(), 2);
    assert_eq!(plan.events()[1], FaultEvent::GatewayRestore { cloud: 1, at: 5 });
}

#[test]
fn malformed_kind_and_key_errors() {
    let cases: &[(&str, &str)] = &[
        // unknown kind
        ("meteor:at=1", "unknown kind"),
        ("gatewaydown:cloud=1,at=1", "unknown kind"),
        // missing ':' separator entirely
        ("gateway-down", "expected kind"),
        // missing required keys
        ("gateway-down:cloud=1", "missing at="),
        ("restore:at=2", "missing cloud="),
        ("link-degrade:src=0,dst=1,at=1", "missing factor"),
        // keys that belong to another kind
        ("gateway-down:cloud=1,at=1,factor=0.5", "not valid"),
        ("restore:cloud=1,at=1,node=2", "not valid"),
        ("node-slowdown:node=1,at=1,factor=2,dst=0", "not valid"),
        // unknown key
        ("gateway-down:cloud=1,at=1,zone=7", "not valid"),
        // malformed pair / number
        ("gateway-down:cloud,at=1", "bad pair"),
        ("gateway-down:cloud=x,at=1", "bad cloud"),
        ("link-degrade:src=0,dst=1,at=1,factor=fast", "bad factor"),
    ];
    for (spec, needle) in cases {
        let err = FaultEvent::parse(spec).expect_err(spec).to_string();
        assert!(err.contains(needle), "{spec}: {err:?} missing {needle:?}");
    }
}

#[test]
fn duplicate_keys_are_rejected() {
    for spec in [
        "gateway-down:cloud=1,cloud=2,at=1",
        "gateway-down:cloud=1,at=1,at=2",
        "restore:cloud=0,cloud=0,at=1",
        "link-degrade:src=0,dst=1,dst=2,at=1,factor=0.5",
        "node-slowdown:node=1,at=2,factor=2,factor=3",
    ] {
        let err = FaultEvent::parse(spec).expect_err(spec).to_string();
        assert!(err.contains("duplicate key"), "{spec}: {err:?}");
    }
}

#[test]
fn out_of_horizon_events_fail_config_validation() {
    // in-horizon passes
    assert!(ExperimentConfig::from_json(
        r#"{"rounds": 6, "faults": ["gateway-down:cloud=1,at=5"]}"#
    )
    .is_ok());
    // at == rounds is already out (rounds are 0-based)
    for (rounds, spec) in [
        (6, "gateway-down:cloud=1,at=6"),
        (4, "restore:cloud=0,at=9"),
        (3, "node-slowdown:node=0,at=3,factor=2"),
    ] {
        let text = format!(r#"{{"rounds": {rounds}, "faults": ["{spec}"]}}"#);
        let err = ExperimentConfig::from_json(&text)
            .expect_err(spec)
            .to_string();
        assert!(err.contains("rounds"), "{spec}: {err:?}");
    }
}

#[test]
fn faults_json_round_trip_including_restore() {
    let c = ExperimentConfig::from_json(
        r#"{"rounds": 10, "faults": [
            "gateway-down:cloud=1,at=round2",
            "restore:cloud=1,at=6",
            "link-degrade:src=0,dst=2,at=1,factor=0.5"
        ]}"#,
    )
    .unwrap();
    assert_eq!(c.faults.len(), 3);
    // the plan is sorted by round
    assert_eq!(
        c.faults.events()[0],
        FaultEvent::LinkDegrade { src: 0, dst: 2, at: 1, factor: 0.5 }
    );
    assert_eq!(c.faults.events()[2], FaultEvent::GatewayRestore { cloud: 1, at: 6 });
    // serialize → parse → identical plan
    let j = c.to_json().to_string();
    assert!(j.contains("restore:cloud=1,at=6"), "{j}");
    let back = ExperimentConfig::from_json(&j).unwrap();
    assert_eq!(back.faults, c.faults);
    // structural validation still runs through the JSON path
    assert!(ExperimentConfig::from_json(
        r#"{"rounds": 9, "faults": ["link-degrade:src=2,dst=2,at=1,factor=0.5"]}"#
    )
    .is_err());
    assert!(ExperimentConfig::from_json(
        r#"{"rounds": 9, "faults": ["node-slowdown:node=0,at=1,factor=0.5"]}"#
    )
    .is_err());
}

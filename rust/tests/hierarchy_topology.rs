//! Hierarchical aggregation end-to-end: the two-level reduce must slash
//! inter-region WAN traffic (ISSUE 2 acceptance: ≤ 1/8 of the flat star
//! at `paper_default_scaled(16)` and equal codec settings, measured by
//! the per-link `Wan` ledger) while training the same model.

use crossfed::aggregation::AggregationKind;
use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::LinkClass;
use crossfed::runtime::MockRuntime;

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.name = name.into();
    c.rounds = 2;
    c.eval_every = 1;
    c.eval_batches = 1;
    c.local_steps = 2;
    c.local_lr = 4.0; // mock quadratic: grads are (p-t)/n, need big lr
    c.server_lr = 4.0;
    c.target_loss = None;
    // enough documents that every one of 48 dirichlet shards is non-empty
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

fn init_params() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0; 64], vec![-1.0; 32]] }
}

/// Run `cfg` on `cluster`; returns (result, per-round inter-region bytes,
/// per-round total wire bytes).
fn run_measured(
    cfg: ExperimentConfig,
    cluster: ClusterSpec,
) -> (RunResult, u64, u64) {
    let backend = MockRuntime::new(0.4);
    let rounds = cfg.rounds as u64;
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init_params(), 4, 16).unwrap();
    // skip construction-time distribution traffic (identical across modes)
    let inter0 = coord.inter_region_wire_bytes();
    let total0 = coord.wire_bytes();
    let r = coord.run().unwrap();
    let inter = (coord.inter_region_wire_bytes() - inter0) / rounds;
    let total = (coord.wire_bytes() - total0) / rounds;
    (r, inter, total)
}

#[test]
fn hierarchical_cuts_inter_region_bytes_8x_at_scale_16() {
    let cluster = ClusterSpec::paper_default_scaled(16);
    assert_eq!(cluster.n(), 48);
    let (_, star_inter, star_total) =
        run_measured(base_cfg("star"), cluster.clone());
    let mut hier_cfg = base_cfg("hier");
    hier_cfg.hierarchical = true;
    let (_, hier_inter, hier_total) = run_measured(hier_cfg, cluster);

    assert!(star_inter > 0 && hier_inter > 0);
    // the acceptance bar: ≤ 1/8 inter-region bytes per round at equal
    // codec settings (expected ~1/16: 2 partials + 2 gateway broadcasts
    // vs 32 uplinks + 32 broadcasts crossing regions)
    assert!(
        hier_inter * 8 <= star_inter,
        "hier {hier_inter} !<= star {star_inter} / 8"
    );
    // total bytes also drop (intra-AZ hops are cheap but counted)
    assert!(
        hier_total < star_total,
        "hier total {hier_total} !< star {star_total}"
    );
}

#[test]
fn hierarchical_matches_star_training_with_lossless_codec() {
    // same math factored differently: with Compression::None the two
    // modes must train to (nearly fp-identical) the same model
    let cluster = ClusterSpec::paper_default_scaled(4);
    let mut star = base_cfg("star-eq");
    star.rounds = 6;
    let mut hier = base_cfg("hier-eq");
    hier.rounds = 6;
    hier.hierarchical = true;
    let (rs, _, _) = run_measured(star, cluster.clone());
    let (rh, _, _) = run_measured(hier, cluster);
    assert!(
        (rs.final_eval_loss - rh.final_eval_loss).abs() < 0.05,
        "star {} vs hier {}",
        rs.final_eval_loss,
        rh.final_eval_loss
    );
    // hierarchy must not slow simulated training down at scale — fewer
    // WAN crossings, fatter links
    assert!(rh.sim_secs <= rs.sim_secs * 1.05);
}

#[test]
fn hierarchical_runs_all_sync_aggregators() {
    let cluster = ClusterSpec::paper_default_scaled(2);
    for agg in ["fedavg", "dynamic", "gradient"] {
        let mut cfg = base_cfg(agg);
        cfg.rounds = 8;
        cfg.hierarchical = true;
        cfg.aggregation = AggregationKind::parse(agg).unwrap();
        if agg == "gradient" {
            cfg.server_opt = crossfed::optimizer::OptimizerKind::Sgd;
        }
        let (r, _, _) = run_measured(cfg, cluster.clone());
        assert_eq!(r.rounds_run, 8, "{agg}");
        let first_train = r.history[0].train_loss;
        assert!(
            r.final_eval_loss < first_train * 0.6,
            "{agg}: {} -> {}",
            first_train,
            r.final_eval_loss
        );
    }
}

#[test]
fn secure_agg_composes_with_hierarchy() {
    // pairwise masks span all workers; per-cloud partial sums stay
    // masked and cancel only in the leader's full cross-cloud sum, so
    // secure hierarchical training must track plain hierarchical fedavg
    let cluster = ClusterSpec::paper_default_scaled(3);
    let mut plain = base_cfg("hier-plain");
    plain.rounds = 6;
    plain.hierarchical = true;
    let mut sa = base_cfg("hier-secure");
    sa.rounds = 6;
    sa.hierarchical = true;
    sa.secure_agg = true;
    let (rp, _, _) = run_measured(plain, cluster.clone());
    let (rs, _, _) = run_measured(sa, cluster);
    assert!(
        (rp.final_eval_loss - rs.final_eval_loss).abs() < 0.25,
        "plain {} vs secure {}",
        rp.final_eval_loss,
        rs.final_eval_loss
    );
}

#[test]
fn dp_accounting_composes_with_hierarchy() {
    let cluster = ClusterSpec::paper_default_scaled(2);
    let mut cfg = base_cfg("hier-dp");
    cfg.rounds = 4;
    cfg.hierarchical = true;
    cfg.dp = crossfed::privacy::DpConfig {
        clip_norm: 5.0,
        noise_multiplier: 0.05,
        delta: 1e-5,
    };
    let (r, _, _) = run_measured(cfg, cluster);
    // privatization happens at the worker; the accountant ticks per round
    assert!(r.history.last().unwrap().epsilon > 0.0);
    assert!(r.final_eval_loss < r.history[0].train_loss);
}

#[test]
fn lossy_codec_applies_uniformly_in_both_modes() {
    // worker 0 (leader/gateway-colocated) must pass the codec like every
    // other worker: with a very aggressive top-k and no error feedback,
    // training still converges identically-shaped in star and hier modes
    let cluster = ClusterSpec::paper_default_scaled(2);
    for hier in [false, true] {
        let mut cfg = base_cfg(if hier { "hier-topk" } else { "star-topk" });
        cfg.rounds = 6;
        cfg.hierarchical = hier;
        cfg.compression = crossfed::compress::Compression::TopK { ratio: 0.25 };
        cfg.error_feedback = true;
        let (r, _, _) = run_measured(cfg, cluster.clone());
        assert!(
            r.final_eval_loss < r.history[0].train_loss,
            "hier={hier}: {} -> {}",
            r.history[0].train_loss,
            r.final_eval_loss
        );
    }
}

#[test]
fn hier_target_loss_respects_sparse_eval_schedule() {
    // target_loss + eval_every > 1 under --hierarchical: early stop can
    // only trigger on rounds that actually evaluate. Calibrate the
    // target from an identical no-target run so the test is robust to
    // the mock's exact loss values.
    let cluster = ClusterSpec::paper_default_scaled(2);
    let mk = |target: Option<f64>| {
        let mut c = base_cfg("hier-earlystop");
        c.rounds = 6;
        c.eval_every = 2;
        c.hierarchical = true;
        c.target_loss = target;
        // gentle steps: the loss must still be strictly descending at
        // round 4 so the calibrated target separates rounds 2 and 4
        c.local_lr = 1.0;
        c.server_lr = 1.0;
        c
    };
    let (cal, _, _) = run_measured(mk(None), cluster.clone());
    assert_eq!(cal.rounds_run, 6);
    // eval cadence: rounds 0, 2, 4 evaluate; 5 is the last round
    for r in &cal.history {
        let expect = r.round % 2 == 0 || r.round == 5;
        assert_eq!(r.eval_loss.is_some(), expect, "round {}", r.round);
        assert_eq!(r.eval_acc.is_some(), expect, "round {}", r.round);
    }
    let e2 = cal.history[2].eval_loss.unwrap() as f64;
    let e4 = cal.history[4].eval_loss.unwrap() as f64;
    assert!(e4 < e2, "mock training must descend: {e2} -> {e4}");

    // a target between the round-2 and round-4 eval losses stops the run
    // exactly at round 4 — not at round 3, whose better-than-target
    // state is invisible without an eval
    let (r, _, _) = run_measured(mk(Some((e2 + e4) / 2.0)), cluster.clone());
    assert!(r.reached_target);
    assert_eq!(r.rounds_run, 5);
    assert_eq!(r.history.last().unwrap().round, 4);
    assert!(r.history[3].eval_loss.is_none()); // round 3 never evaluated

    // an unreachable target runs the full schedule and reports failure
    let (full, _, _) = run_measured(mk(Some(1e-9)), cluster);
    assert!(!full.reached_target);
    assert_eq!(full.rounds_run, 6);
}

#[test]
fn wan_ledger_splits_by_class() {
    // in hierarchical mode the per-class ledger must show intra-AZ
    // volume dominating crossings count-wise while inter-region carries
    // only the partials
    let cluster = ClusterSpec::paper_default_scaled(8);
    let mut cfg = base_cfg("hier-classes");
    cfg.hierarchical = true;
    let backend = MockRuntime::new(0.4);
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init_params(), 4, 16).unwrap();
    // skip construction-time shard distribution: compare round traffic
    let intra0 = coord.wire_bytes_class(LinkClass::IntraAz);
    let inter0 = coord.wire_bytes_class(LinkClass::InterRegion);
    coord.run().unwrap();
    let intra = coord.wire_bytes_class(LinkClass::IntraAz) - intra0;
    let inter = coord.wire_bytes_class(LinkClass::InterRegion) - inter0;
    assert!(intra > 0 && inter > 0);
    // 21 intra-cloud member uplinks + 21 member broadcasts per round vs
    // 2 partials + 2 gateway broadcasts
    assert!(intra > inter);
    // paper_default regions are all distinct: nothing is intra-region
    assert_eq!(coord.wire_bytes_class(LinkClass::IntraRegion), 0);
}

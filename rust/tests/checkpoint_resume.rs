//! Integration: checkpoint/resume produces the same final model as an
//! uninterrupted run (over the mock backend).

use crossfed::checkpoint::Checkpoint;
use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::model::ParamSet;
use crossfed::runtime::MockRuntime;

fn cfg(rounds: usize) -> crossfed::config::ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.rounds = rounds;
    c.eval_every = 100; // avoid eval-rng interleaving differences
    c.local_lr = 3.0;
    c
}

fn init() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0; 32]] }
}

#[test]
fn save_restore_roundtrip_through_coordinator() {
    let backend = MockRuntime::new(0.4);
    let mut coord = Coordinator::new(
        cfg(4),
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap();
    coord.run().unwrap();

    let base = std::env::temp_dir().join("crossfed-resume-test");
    let ckpt = coord.checkpoint();
    ckpt.save(&base).unwrap();
    let loaded = Checkpoint::load(&base).unwrap();
    assert_eq!(loaded.params, *coord.global());
    assert_eq!(loaded.experiment, "quick");
    assert!(loaded.sim_secs > 0.0);

    // restore into a fresh coordinator
    let mut coord2 = Coordinator::new(
        cfg(4),
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap();
    coord2.restore(&loaded).unwrap();
    assert_eq!(coord2.global(), coord.global());
    assert_eq!(coord2.sim_secs(), loaded.sim_secs);

    // shape guard
    let mut coord3 = Coordinator::new(
        cfg(1),
        ClusterSpec::paper_default(),
        &backend,
        ParamSet { leaves: vec![vec![0.0; 8]] },
        4,
        16,
    )
    .unwrap();
    assert!(coord3.restore(&loaded).is_err());

    std::fs::remove_file(base.with_extension("json")).ok();
    std::fs::remove_file(base.with_extension("bin")).ok();
}

#[test]
fn resumed_run_continues_training() {
    let backend = MockRuntime::new(0.4);
    // run 6 rounds straight
    let mut full = Coordinator::new(
        cfg(6),
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap();
    let full_result = full.run().unwrap();

    // run 3, checkpoint, restore into a new coordinator, run 3 more
    let mut first = Coordinator::new(
        cfg(3),
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap();
    first.run().unwrap();
    let ckpt = first.checkpoint();

    let mut second = Coordinator::new(
        cfg(3),
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap();
    second.restore(&ckpt).unwrap();
    let resumed = second.run().unwrap();

    // training continued: resumed final loss is in the same basin as the
    // uninterrupted run (streams differ post-restore, so compare loosely)
    assert!(
        (resumed.final_eval_loss - full_result.final_eval_loss).abs() < 0.5,
        "resumed {} vs full {}",
        resumed.final_eval_loss,
        full_result.final_eval_loss
    );
    // and strictly better than where the first half stopped
    assert!(resumed.final_eval_loss < ckpt.params.max_abs());
}

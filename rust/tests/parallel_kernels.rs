//! Serial ↔ parallel equivalence for the hot-path kernels.
//!
//! The contract (see `crossfed::util::par`): work is decomposed into
//! fixed-size blocks, so results are *bit-identical* for any thread
//! count — for the ParamSet linear algebra, every codec (including the
//! RNG-consuming int8/rand-k), and the CTR keystream. Also covers the
//! scratch-reuse guarantee (`compress_append` into a shared dirty buffer
//! equals `compress`) and the full compress→encrypt→decrypt→decompress
//! pipeline.

use crossfed::compress::{Compression, Compressor, ErrorFeedback};
use crossfed::crypto::{open_in_place, seal_in_place, TransportKey};
use crossfed::model::ParamSet;
use crossfed::testkit::proptest_kit::{forall, Gen};
use crossfed::util::par;

/// Enough workers that round-robin lanes interleave blocks non-trivially.
const PAR_T: usize = 8;

const ALL_SCHEMES: [Compression; 5] = [
    Compression::None,
    Compression::Fp16,
    Compression::Int8,
    Compression::TopK { ratio: 0.02 },
    Compression::RandK { ratio: 0.013 },
];

/// Leaf structure crossing every edge: empty leaves, 1-element leaves,
/// odd tails, plus one leaf big enough to engage the thread pool.
fn gen_leaves(g: &mut Gen) -> ParamSet {
    let mut leaves = Vec::new();
    let n_leaves = g.usize_in(1..5);
    for _ in 0..n_leaves {
        let n = *g.choose(&[0usize, 1, 7, 1000, par::BLOCK - 1, par::BLOCK + 3]);
        leaves.push((0..n).map(|i| (i as f32 * 0.37).sin()).collect());
    }
    leaves.push(
        (0..par::PAR_THRESHOLD + 1234)
            .map(|_| g.f32_in(-1.0..1.0))
            .collect(),
    );
    ParamSet { leaves }
}

/// Same shapes as `ps`, different values.
fn like(ps: &ParamSet, g: &mut Gen) -> ParamSet {
    ParamSet {
        leaves: ps
            .leaves
            .iter()
            .map(|l| (0..l.len()).map(|_| g.f32_in(-2.0..2.0)).collect())
            .collect(),
    }
}

#[test]
fn paramset_kernels_bit_identical_serial_vs_parallel() {
    forall("paramset serial==parallel", 6, |g| {
        let a = gen_leaves(g);
        let b = like(&a, g);
        let alpha = g.f32_in(-2.0..2.0);

        let run = |threads: usize| {
            par::with_threads(threads, || {
                let mut ax = a.clone();
                ax.axpy(alpha, &b);
                let mut sc = a.clone();
                sc.scale(alpha);
                (ax, sc, a.sub(&b), a.l2_norm(), a.to_flat())
            })
        };
        let s = run(1);
        let p = run(PAR_T);
        assert_eq!(s.0, p.0, "axpy");
        assert_eq!(s.1, p.1, "scale");
        assert_eq!(s.2, p.2, "sub");
        assert!(s.3 == p.3, "l2_norm: {} vs {}", s.3, p.3);
        assert_eq!(s.4, p.4, "to_flat");
    });
}

#[test]
fn axpy_many_bitwise_matches_sequential_axpy() {
    forall("axpy_many == axpy sequence", 6, |g| {
        let base = gen_leaves(g);
        let us: Vec<ParamSet> = (0..3).map(|_| like(&base, g)).collect();
        let alphas: Vec<f32> = (0..3).map(|_| g.f32_in(-1.0..1.0)).collect();
        let mut seq = base.clone();
        for (a, u) in alphas.iter().zip(&us) {
            seq.axpy(*a, u);
        }
        let terms: Vec<(f32, &ParamSet)> =
            alphas.iter().zip(&us).map(|(&a, u)| (a, u)).collect();
        let mut fused = base.clone();
        par::with_threads(PAR_T, || fused.axpy_many(&terms));
        assert_eq!(seq, fused);
    });
}

#[test]
fn codecs_bit_identical_serial_vs_parallel() {
    // sizes cross int8 chunk boundaries and the parallel threshold
    for &n in &[0usize, 1, 5, 4095, 4096, 4097, 100_003] {
        let xs: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.013).sin() * 3.0).collect();
        for &scheme in &ALL_SCHEMES {
            let enc = |threads: usize| {
                par::with_threads(threads, || {
                    Compressor::new(scheme, 42).compress(&xs)
                })
            };
            let ps = enc(1);
            let pp = enc(PAR_T);
            assert_eq!(ps.data, pp.data, "{scheme:?} n={n} encode");
            let dec = |threads: usize| {
                par::with_threads(threads, || Compressor::decompress(&ps).unwrap())
            };
            let ds = dec(1);
            let dp = dec(PAR_T);
            assert_eq!(ds, dp, "{scheme:?} n={n} decode");
            assert_eq!(ds.len(), n);
        }
    }
}

#[test]
fn lossless_stages_bit_identical_serial_vs_parallel() {
    use crossfed::compress::{lossless, LosslessStage};
    for &n in &[0usize, 1, 5, 4095, 4096, 4097, 100_003] {
        let xs: Vec<f32> =
            (0..n).map(|i| ((i as f32) * 0.013).sin() * 3.0).collect();
        let mut bytes: Vec<u8> =
            xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        // odd tail so the word view is misaligned with the byte length
        bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF][..n.min(3)]);
        for stage in LosslessStage::ALL {
            let enc = |threads: usize| {
                par::with_threads(threads, || {
                    let mut out = Vec::new();
                    lossless::encode_append(stage, &bytes, &mut out);
                    out
                })
            };
            let es = enc(1);
            let ep = enc(PAR_T);
            assert_eq!(es, ep, "{stage:?} n={n} encode");
            let dec = |threads: usize| {
                par::with_threads(threads, || {
                    let mut out = Vec::new();
                    lossless::decode_into(&es, &mut out).unwrap();
                    out
                })
            };
            let ds = dec(1);
            let dp = dec(PAR_T);
            assert_eq!(ds, dp, "{stage:?} n={n} decode");
            assert_eq!(ds, bytes, "{stage:?} n={n} roundtrip");
        }
    }
}

#[test]
fn error_feedback_residual_identical_across_thread_counts() {
    let n = 50_000;
    let xs: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.11).cos()).collect();
    let run = |threads: usize| {
        par::with_threads(threads, || {
            let mut ef = ErrorFeedback::new(n, true);
            let mut c = Compressor::new(Compression::TopK { ratio: 0.05 }, 3);
            let mut out = Vec::new();
            for _ in 0..3 {
                ef.compress_append(&xs, &mut c, &mut out).unwrap();
            }
            (out, ef.residual_norm())
        })
    };
    let (bytes_s, res_s) = run(1);
    let (bytes_p, res_p) = run(PAR_T);
    assert_eq!(bytes_s, bytes_p);
    assert!(res_s == res_p, "{res_s} vs {res_p}");
}

#[test]
fn compress_encrypt_decrypt_decompress_roundtrip() {
    forall("pipeline roundtrip", 6, |g| {
        let n = g.usize_in(1..50_000);
        let xs: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0..1.0)).collect();
        for &scheme in &ALL_SCHEMES {
            // reference: plain codec roundtrip
            let mut c_ref = Compressor::new(scheme, 77);
            let reference = Compressor::decompress(&c_ref.compress(&xs)).unwrap();

            // pipeline: append into a frame, seal in place, open in place,
            // decompress from the borrowed frame slice
            let mut c = Compressor::new(scheme, 77);
            let mut frame = vec![0xEEu8; 16]; // fake metadata header
            c.compress_append(&xs, &mut frame);
            let mut tx = TransportKey::derive(b"pipeline", "w->l");
            let rx = TransportKey::derive(b"pipeline", "w->l");
            let (nonce, tag) = seal_in_place(&mut tx, &mut frame);
            assert_ne!(&frame[..16], &[0xEEu8; 16][..], "not encrypted");
            open_in_place(&rx, &nonce, &tag, &mut frame).unwrap();
            assert_eq!(&frame[..16], &[0xEEu8; 16][..], "header corrupted");
            let mut out = vec![0.0f32; n];
            Compressor::decompress_into(scheme, &frame[16..], &mut out).unwrap();

            assert_eq!(out, reference, "{scheme:?} n={n}");
            if scheme == Compression::None {
                assert_eq!(out, xs); // dense path is lossless end-to-end
            }
        }
    });
}

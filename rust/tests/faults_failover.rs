//! Fault injection + gateway failover end-to-end (ISSUE 3).
//!
//! The acceptance bar: a hierarchical run that loses a gateway mid-run
//! must complete every round, re-elect the standby deterministically,
//! and keep its inter-region WAN savings (≤ 1/4 of the flat star at
//! `paper_default_scaled(16)`). Also pins the two async accounting
//! fixes that ride along: the model downlink is part of simulated time,
//! and pseudo-rounds record per-worker compute seconds.

use crossfed::aggregation::AggregationKind;
use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::{FaultEvent, FaultPlan};
use crossfed::runtime::MockRuntime;

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.name = name.into();
    c.rounds = 4;
    c.eval_every = 1;
    c.eval_batches = 1;
    c.local_steps = 2;
    c.local_lr = 4.0; // mock quadratic: grads are (p-t)/n, need big lr
    c.server_lr = 4.0;
    c.target_loss = None;
    // enough documents that every one of 48 dirichlet shards is non-empty
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

fn init_params() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0; 64], vec![-1.0; 32]] }
}

fn run_coord(
    cfg: ExperimentConfig,
    cluster: ClusterSpec,
) -> (RunResult, Coordinator<'static, MockRuntime>) {
    // leak the backend so the coordinator can outlive this helper; the
    // few bytes per test are irrelevant
    let backend: &'static MockRuntime = Box::leak(Box::new(MockRuntime::new(0.4)));
    let mut coord =
        Coordinator::new(cfg, cluster, backend, init_params(), 4, 16).unwrap();
    let r = coord.run().unwrap();
    (r, coord)
}

/// Per-round inter-region bytes, net of construction-time distribution.
fn inter_per_round(cfg: ExperimentConfig, cluster: ClusterSpec) -> (RunResult, u64) {
    let rounds = cfg.rounds as u64;
    let backend = MockRuntime::new(0.4);
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init_params(), 4, 16).unwrap();
    let inter0 = coord.inter_region_wire_bytes();
    let r = coord.run().unwrap();
    let inter = (coord.inter_region_wire_bytes() - inter0) / rounds;
    (r, inter)
}

#[test]
fn faulty_hier_completes_and_keeps_savings_at_scale_16() {
    let cluster = ClusterSpec::paper_default_scaled(16);
    // clean flat star as the reference
    let (star, star_inter) = inter_per_round(base_cfg("star"), cluster.clone());
    assert_eq!(star.rounds_run, 4);

    // hierarchical run that loses cloud 1's gateway before round 1's
    // reduce — detected at reduce time, standby re-elected mid-round
    let mut faulty = base_cfg("hier-faulty");
    faulty.hierarchical = true;
    faulty.faults =
        FaultPlan::new(vec![FaultEvent::GatewayDown { cloud: 1, at: 1 }]);
    let backend = MockRuntime::new(0.4);
    let mut coord = Coordinator::new(
        faulty,
        cluster.clone(),
        &backend,
        init_params(),
        4,
        16,
    )
    .unwrap();
    let inter0 = coord.inter_region_wire_bytes();
    let r = coord.run().unwrap();
    let hier_inter = (coord.inter_region_wire_bytes() - inter0) / 4;

    // every round completed despite the mid-run failover
    assert_eq!(r.rounds_run, 4);
    assert!(r.history.iter().all(|h| h.eval_loss.is_some()));
    // deterministic re-election: cloud 1 = {16..31}, next member by id
    assert_eq!(coord.cluster.gateway(1), 17);
    assert!(!coord.cluster.egress_ok(16));
    // the training still made progress
    assert!(r.final_eval_loss < r.history[0].train_loss);
    // acceptance: inter-region savings retained, ≤ 1/4 of the star
    assert!(
        hier_inter * 4 <= star_inter,
        "faulty hier {hier_inter} !<= star {star_inter} / 4"
    );
}

#[test]
fn faulty_runs_are_bit_identical() {
    let cluster = ClusterSpec::paper_default_scaled(4);
    let mk = || {
        let mut c = base_cfg("hier-faulty-det");
        c.hierarchical = true;
        c.faults = FaultPlan::new(vec![
            FaultEvent::GatewayDown { cloud: 2, at: 1 },
            FaultEvent::LinkDegrade { src: 0, dst: 4, at: 2, factor: 0.5 },
            FaultEvent::NodeSlowdown { node: 5, at: 2, factor: 2.0 },
        ]);
        c
    };
    let (a, ca) = run_coord(mk(), cluster.clone());
    let (b, cb) = run_coord(mk(), cluster);
    assert_eq!(a.wire_bytes, b.wire_bytes);
    assert_eq!(a.sim_secs.to_bits(), b.sim_secs.to_bits());
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
    assert_eq!(ca.cluster.gateway(2), cb.cluster.gateway(2));
    // cloud 2 = {8..11}: gateway 8 died, 9 took over
    assert_eq!(ca.cluster.gateway(2), 9);
}

#[test]
fn secure_agg_survives_failover() {
    // pairwise masks span all workers; the failover must keep every
    // member update flowing into the reduce exactly once or the leader's
    // coverage assert (and the mask cancellation) would blow up
    let cluster = ClusterSpec::paper_default_scaled(3);
    let mut sa = base_cfg("hier-secure-faulty");
    sa.rounds = 5;
    sa.hierarchical = true;
    sa.secure_agg = true;
    sa.faults = FaultPlan::new(vec![FaultEvent::GatewayDown { cloud: 1, at: 2 }]);
    let mut plain = base_cfg("hier-plain-faulty");
    plain.rounds = 5;
    plain.hierarchical = true;
    plain.faults =
        FaultPlan::new(vec![FaultEvent::GatewayDown { cloud: 1, at: 2 }]);
    let (rs, cs) = run_coord(sa, cluster.clone());
    let (rp, _) = run_coord(plain, cluster);
    assert_eq!(rs.rounds_run, 5);
    assert_eq!(cs.cluster.gateway(1), 4); // {3,4,5}: 3 died, 4 took over
    // masked failover training tracks the plain failover run
    assert!(
        (rs.final_eval_loss - rp.final_eval_loss).abs() < 0.25,
        "secure {} vs plain {}",
        rs.final_eval_loss,
        rp.final_eval_loss
    );
}

#[test]
fn leader_cloud_gateway_failure_is_survivable() {
    // killing cloud 0's gateway fails the *leader's own* egress: the
    // leader detects it locally, a standby relays its WAN traffic, and
    // remote partials route gw -> relay -> leader over the AZ fabric
    let cluster = ClusterSpec::paper_default_scaled(2);
    let mut c = base_cfg("hier-leader-faulty");
    c.hierarchical = true;
    c.faults = FaultPlan::new(vec![FaultEvent::GatewayDown { cloud: 0, at: 1 }]);
    let (r, coord) = run_coord(c, cluster);
    assert_eq!(r.rounds_run, 4);
    assert_eq!(coord.cluster.gateway(0), 1);
    assert!(r.final_eval_loss.is_finite());
}

#[test]
fn flat_schedulers_survive_gateway_down() {
    // star and async have no reduce step: the gateway is repaired the
    // moment the fault strikes, and routed uplinks follow the standby
    let cluster = ClusterSpec::paper_default_scaled(2);
    for agg in ["fedavg", "async"] {
        let mut c = base_cfg(agg);
        c.aggregation = AggregationKind::parse(agg).unwrap();
        c.faults =
            FaultPlan::new(vec![FaultEvent::GatewayDown { cloud: 1, at: 1 }]);
        let (r, coord) = run_coord(c, cluster.clone());
        assert_eq!(r.rounds_run, 4, "{agg}");
        assert_eq!(coord.cluster.gateway(1), 3, "{agg}"); // {2,3}: 2 -> 3
        assert!(r.final_eval_loss.is_finite(), "{agg}");
    }
}

#[test]
fn node_slowdown_shows_in_platform_secs() {
    // homogeneous cluster, no stragglers: compute seconds are exact
    let cluster = ClusterSpec::homogeneous(3);
    let mut c = base_cfg("slowdown");
    c.rounds = 2;
    c.local_steps = 2;
    c.base_step_secs = 1.0;
    c.faults = FaultPlan::new(vec![FaultEvent::NodeSlowdown {
        node: 2,
        at: 1,
        factor: 4.0,
    }]);
    let (r, _) = run_coord(c, cluster);
    let before = &r.history[0].platform_secs;
    let after = &r.history[1].platform_secs;
    assert!((before[2] - 2.0).abs() < 1e-9, "round 0: {before:?}");
    assert!((after[2] - 8.0).abs() < 1e-9, "round 1: {after:?}");
    assert!((after[0] - 2.0).abs() < 1e-9, "healthy node slowed: {after:?}");
}

#[test]
fn async_sim_time_includes_the_final_downlink() {
    // regression for the async time-accounting bug: the model downlink
    // was priced into the worker's restart but never into sim_secs, so a
    // one-round run's reported time excluded every final downlink leg.
    // Degrading only the leader->worker link must therefore show up in
    // sim_secs even though no later uplink ever rides it.
    let mk = |faults: FaultPlan, name: &str| {
        let mut c = base_cfg(name);
        c.aggregation = AggregationKind::Async { alpha: 0.6 };
        c.rounds = 1; // 2 aggregations: each worker exactly once
        c.local_steps = 1;
        c.base_step_secs = 1.0;
        c.corpus = CorpusConfig { n_docs: 60, doc_sentences: 3, n_topics: 6, seed: 3 };
        c.faults = faults;
        c
    };
    let big_model = ParamSet { leaves: vec![vec![0.5; 100_000]] };
    let run = |cfg: ExperimentConfig| {
        let backend = MockRuntime::new(0.4);
        let mut coord = Coordinator::new(
            cfg,
            ClusterSpec::homogeneous(2),
            &backend,
            big_model.clone(),
            4,
            16,
        )
        .unwrap();
        coord.run().unwrap()
    };
    let clean = run(mk(FaultPlan::default(), "async-clean"));
    // downlink 0->1 at 1/10000th bandwidth: ~6s serialization for the
    // 400 KB dense model, invisible to every uplink
    let slow_down = run(mk(
        FaultPlan::new(vec![FaultEvent::LinkDegrade {
            src: 0,
            dst: 1,
            at: 0,
            factor: 1e-4,
        }]),
        "async-slow-downlink",
    ));
    assert!(
        slow_down.sim_secs > clean.sim_secs + 3.0,
        "downlink not accounted: clean {} vs degraded {}",
        clean.sim_secs,
        slow_down.sim_secs
    );
    // per-pseudo-round records see it too, and platform_secs is no
    // longer empty: both workers' applied updates cost exactly one
    // 1-second local step
    let rec = slow_down.history.last().unwrap();
    assert_eq!(rec.platform_secs.len(), 2);
    assert!((rec.platform_secs[0] - 1.0).abs() < 1e-9, "{:?}", rec.platform_secs);
    assert!((rec.platform_secs[1] - 1.0).abs() < 1e-9, "{:?}", rec.platform_secs);
}

#[test]
fn random_chaos_plan_runs_to_completion() {
    // seed-driven plans are reproducible and survivable by construction
    let cluster = ClusterSpec::paper_default_scaled(2);
    let plan = FaultPlan::random(11, 5, 4, &cluster);
    assert_eq!(plan, FaultPlan::random(11, 5, 4, &cluster));
    let mut c = base_cfg("chaos");
    c.hierarchical = true;
    c.faults = plan;
    let (r, _) = run_coord(c, cluster);
    assert_eq!(r.rounds_run, 4);
    assert!(r.final_eval_loss.is_finite());
}

#[test]
fn restore_fails_back_to_the_original_gateway() {
    // transient outage: cloud 1's gateway (node 2 at scaled(2)) dies at
    // round 1 — the standby (node 3) takes over — and its egress returns
    // at round 3, so the gateway role must fail back to node 2
    let cluster = ClusterSpec::paper_default_scaled(2);
    let mk = || {
        let mut c = base_cfg("hier-restore");
        c.rounds = 5;
        c.hierarchical = true;
        c.faults = FaultPlan::new(vec![
            FaultEvent::GatewayDown { cloud: 1, at: 1 },
            FaultEvent::GatewayRestore { cloud: 1, at: 3 },
        ]);
        c
    };
    let (r, coord) = run_coord(mk(), cluster.clone());
    assert_eq!(r.rounds_run, 5);
    // failed back: the original gateway serves again and is eligible
    assert_eq!(coord.cluster.gateway(1), 2);
    assert!(coord.cluster.egress_ok(2));
    assert!(r.final_eval_loss < r.history[0].train_loss);
    // a transient outage is exactly as reproducible as a clean run
    let (r2, c2) = run_coord(mk(), cluster.clone());
    assert_eq!(c2.cluster.gateway(1), 2);
    assert_eq!(r.wire_bytes, r2.wire_bytes);
    assert_eq!(r.sim_secs.to_bits(), r2.sim_secs.to_bits());
    assert_eq!(r.final_eval_loss.to_bits(), r2.final_eval_loss.to_bits());

    // after the fail-back the cloud can survive a *second* outage —
    // the standby budget was handed back (kill → restore → kill)
    let mut again = base_cfg("hier-restore-rekill");
    again.rounds = 5;
    again.hierarchical = true;
    again.faults = FaultPlan::new(vec![
        FaultEvent::GatewayDown { cloud: 1, at: 1 },
        FaultEvent::GatewayRestore { cloud: 1, at: 2 },
        FaultEvent::GatewayDown { cloud: 1, at: 3 },
    ]);
    let (r3, c3) = run_coord(again, cluster.clone());
    assert_eq!(r3.rounds_run, 5);
    assert_eq!(c3.cluster.gateway(1), 3); // 2 died again, 3 re-elected

    // flat schedulers fail back too (repair is eager at the boundary)
    let mut flat = base_cfg("star-restore");
    flat.rounds = 5;
    flat.faults = FaultPlan::new(vec![
        FaultEvent::GatewayDown { cloud: 1, at: 1 },
        FaultEvent::GatewayRestore { cloud: 1, at: 3 },
    ]);
    let (rf, cf) = run_coord(flat, cluster);
    assert_eq!(rf.rounds_run, 5);
    assert_eq!(cf.cluster.gateway(1), 2);

    // a restore with no prior gateway-down is rejected at build
    let mut bad = base_cfg("restore-without-down");
    bad.rounds = 5;
    bad.hierarchical = true;
    bad.faults =
        FaultPlan::new(vec![FaultEvent::GatewayRestore { cloud: 1, at: 2 }]);
    let backend = MockRuntime::new(0.4);
    assert!(Coordinator::new(
        bad,
        ClusterSpec::paper_default_scaled(2),
        &backend,
        init_params(),
        4,
        16
    )
    .is_err());
}

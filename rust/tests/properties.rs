//! Property-based tests over the coordinator's invariants (routing,
//! batching, state) using the in-repo mini-proptest (`testkit`).

use crossfed::aggregation::{
    Aggregator, AsyncAgg, ClientUpdate, DynamicWeighted, FedAvg,
};
use crossfed::compress::{Compression, Compressor, ErrorFeedback};
use crossfed::crypto::{open, seal, SecureAggregator, TransportKey};
use crossfed::data::{dirichlet_shards, CorpusConfig, SyntheticCorpus};
use crossfed::model::ParamSet;
use crossfed::netsim::{Link, Protocol, Wan};
use crossfed::privacy::clip_update;
use crossfed::testkit::proptest_kit::{forall, Gen};
use crossfed::util::json::Json;

fn gen_updates(g: &mut Gen, n_workers: usize, dim: usize) -> Vec<ClientUpdate> {
    (0..n_workers)
        .map(|w| ClientUpdate {
            worker: w,
            n_samples: g.usize_in(1..10_000),
            local_loss: g.f32_in(0.01..10.0),
            delta: ParamSet { leaves: vec![g.vec_f32_edgy(dim..dim + 1, -5.0..5.0)] },
            staleness: g.usize_in(0..5) as u64,
        })
        .collect()
}

#[test]
fn prop_fedavg_convexity() {
    // FedAvg output lies inside the convex hull of per-coordinate deltas
    forall("fedavg convexity", 200, |g| {
        let n = g.usize_in(1..6);
        let dim = g.usize_in(1..32);
        let updates = gen_updates(g, n, dim);
        let mut global = ParamSet { leaves: vec![vec![0.0; dim]] };
        FedAvg.aggregate(&mut global, &updates);
        for j in 0..dim {
            let lo = updates
                .iter()
                .map(|u| u.delta.leaves[0][j])
                .fold(f32::INFINITY, f32::min);
            let hi = updates
                .iter()
                .map(|u| u.delta.leaves[0][j])
                .fold(f32::NEG_INFINITY, f32::max);
            let x = global.leaves[0][j];
            assert!(
                x >= lo - 1e-4 && x <= hi + 1e-4,
                "coord {j}: {x} outside [{lo}, {hi}]"
            );
        }
    });
}

#[test]
fn prop_dynamic_weights_simplex() {
    forall("dynamic weights on the simplex", 300, |g| {
        let n = g.usize_in(1..8);
        let losses: Vec<f32> =
            (0..n).map(|_| g.f32_in(0.0..50.0)).collect();
        let dw = DynamicWeighted { temperature: g.f32_in(0.05..5.0) };
        let w = dw.weights(&losses);
        assert_eq!(w.len(), n);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(w.iter().all(|&x| (0.0..=1.0).contains(&x) && x.is_finite()));
        // monotone: lower loss never gets lower weight
        for i in 0..n {
            for j in 0..n {
                if losses[i] < losses[j] {
                    assert!(w[i] >= w[j] - 1e-5);
                }
            }
        }
    });
}

#[test]
fn prop_async_is_contraction_toward_update() {
    // after apply_one, each coordinate moves toward (global + delta) by
    // exactly alpha/(1+staleness)
    forall("async mixing", 200, |g| {
        let dim = g.usize_in(1..16);
        let mut global =
            ParamSet { leaves: vec![g.vec_f32(dim..dim + 1, -3.0..3.0)] };
        let before = global.clone();
        let delta = g.vec_f32(dim..dim + 1, -3.0..3.0);
        let staleness = g.usize_in(0..10) as u64;
        let alpha0 = g.f32_in(0.05..1.0);
        let mut agg = AsyncAgg { alpha0 };
        let u = ClientUpdate {
            worker: 0,
            n_samples: 1,
            local_loss: 1.0,
            delta: ParamSet { leaves: vec![delta.clone()] },
            staleness,
        };
        agg.apply_one(&mut global, &u);
        let rate = alpha0 / (1.0 + staleness as f32);
        for j in 0..dim {
            let expect = before.leaves[0][j] + rate * delta[j];
            assert!((global.leaves[0][j] - expect).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_compression_roundtrip_shape_and_bounds() {
    forall("compression roundtrip", 150, |g| {
        let xs = g.vec_f32_edgy(1..4000, -10.0..10.0);
        let scheme = *g.choose(&[
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.1 },
            Compression::RandK { ratio: 0.1 },
        ]);
        let mut c = Compressor::new(scheme, g.u64());
        let payload = c.compress(&xs);
        let ys = Compressor::decompress(&payload).unwrap();
        assert_eq!(ys.len(), xs.len());
        assert!(ys.iter().all(|y| y.is_finite()));
        match scheme {
            Compression::None => assert_eq!(xs, ys),
            Compression::Int8 => {
                // bounded per-chunk error
                let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let step = (hi - lo).max(1e-12) / 255.0;
                for (x, y) in xs.iter().zip(&ys) {
                    assert!((x - y).abs() <= step * 1.01 + 1e-6);
                }
            }
            Compression::TopK { .. } | Compression::RandK { .. } => {
                // sparse outputs: supported coords only
                let nz = ys.iter().filter(|&&y| y != 0.0).count();
                assert!(nz <= xs.len());
            }
            Compression::Fp16 => {
                for (x, y) in xs.iter().zip(&ys) {
                    assert!((x - y).abs() <= x.abs() * 2e-3 + 1e-3);
                }
            }
        }
    });
}

#[test]
fn prop_error_feedback_conserves_mass() {
    // sent_t + residual_t == update_t + residual_{t-1}, every round
    forall("error feedback conservation", 80, |g| {
        let n = g.usize_in(8..512);
        let mut ef = ErrorFeedback::new(n, true);
        let mut c =
            Compressor::new(Compression::TopK { ratio: 0.1 }, g.u64());
        let mut carried = vec![0.0f32; n];
        for _ in 0..4 {
            let update = g.vec_f32(n..n + 1, -1.0..1.0);
            let payload = ef.compress(&update, &mut c).unwrap();
            let sent = Compressor::decompress(&payload).unwrap();
            // reconstruct the residual implied by conservation
            for j in 0..n {
                carried[j] = carried[j] + update[j] - sent[j];
            }
        }
        // the implied residual's norm matches the EF's internal one
        let implied: f64 = carried
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        assert!(
            (implied - ef.residual_norm()).abs() < 1e-3 * (1.0 + implied),
            "implied {implied} vs internal {}",
            ef.residual_norm()
        );
    });
}

#[test]
fn prop_secure_agg_sum_exact_any_n() {
    forall("secure agg sum", 60, |g| {
        let n = g.usize_in(1..7);
        let dim = g.usize_in(1..128);
        let agg = SecureAggregator::new(n, b"prop");
        let raw: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_f32(dim..dim + 1, -2.0..2.0)).collect();
        let round = g.u64() % 1000;
        let masked: Vec<_> =
            (0..n).map(|w| agg.mask(w, round, &raw[w])).collect();
        let sum = agg.unmask_sum(&masked);
        for j in 0..dim {
            let want: f32 = raw.iter().map(|u| u[j]).sum();
            assert!((sum[j] - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    });
}

#[test]
fn prop_seal_open_roundtrip_any_payload() {
    forall("seal/open", 100, |g| {
        let len = g.usize_in(0..5000);
        let payload: Vec<u8> =
            (0..len).map(|_| (g.u64() & 0xff) as u8).collect();
        let mut k = TransportKey::derive(b"prop-secret", "a->b");
        let sealed = seal(&mut k, &payload);
        assert_eq!(open(&k, &sealed).unwrap(), payload);
        // tamper one random byte (if any) -> must fail
        if !sealed.ciphertext.is_empty() {
            let mut bad = sealed.clone();
            let i = g.usize_in(0..bad.ciphertext.len());
            bad.ciphertext[i] ^= 0x40;
            assert!(open(&k, &bad).is_err());
        }
    });
}

#[test]
fn prop_clip_never_increases_norm() {
    forall("clip contraction", 200, |g| {
        let mut p = ParamSet {
            leaves: vec![g.vec_f32_edgy(1..256, -100.0..100.0)],
        };
        let bound = g.f64_in(0.001..50.0);
        let pre = p.l2_norm();
        clip_update(&mut p, bound);
        assert!(p.l2_norm() <= bound.max(pre) + 1e-4);
        assert!(p.l2_norm() <= bound * (1.0 + 1e-5) || pre <= bound);
    });
}

#[test]
fn prop_wan_transfer_monotone_in_payload() {
    forall("wan monotonicity", 100, |g| {
        let link = Link {
            bandwidth_bps: g.f64_in(1e6..1e10),
            rtt_s: g.f64_in(0.001..0.3),
            jitter: 0.0,
            loss_rate: g.f64_in(0.0..0.05),
        };
        let proto =
            *g.choose(&[Protocol::Tcp, Protocol::Grpc, Protocol::Quic]);
        let mut wan = Wan::uniform(2, link, g.u64());
        let small = g.usize_in(1..1_000_000) as u64;
        let big = small * 2 + g.usize_in(1..1_000_000) as u64;
        wan.transfer(0, 1, 1, proto, 4).unwrap(); // warm
        let t_small = wan.transfer(0, 1, small, proto, 4).unwrap();
        let t_big = wan.transfer(0, 1, big, proto, 4).unwrap();
        assert!(t_big.time_s >= t_small.time_s * 0.999);
        assert!(t_big.wire_bytes > t_small.wire_bytes);
    });
}

#[test]
fn prop_dirichlet_partition_is_exact_cover() {
    forall("partition exact cover", 40, |g| {
        let n_docs = g.usize_in(10..200);
        let corpus = SyntheticCorpus::generate(&CorpusConfig {
            n_docs,
            doc_sentences: 2,
            n_topics: 1 + n_docs % 6,
            seed: g.u64(),
        });
        let n = g.usize_in(1..8);
        let shards = dirichlet_shards(&corpus, n, g.f64_in(0.05..10.0), g.u64());
        assert_eq!(shards.len(), n);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.doc_ids.clone()).collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..n_docs).collect();
        assert_eq!(all, expect, "docs must be covered exactly once");
        assert!(shards.iter().all(|s| !s.doc_ids.is_empty()));
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0..4) } else { g.usize_in(0..6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e9..1e9) * 100.0).round() / 100.0),
            3 => {
                let len = g.usize_in(0..12);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            *g.choose(&[
                                'a', 'b', '"', '\\', '\n', 'é', '中', '😀',
                                ' ', '\t',
                            ])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..g.usize_in(0..5))
                    .map(|_| gen_json(g, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..g.usize_in(0..5))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 300, |g| {
        let v = gen_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| {
            panic!("reparse failed for {text:?}: {e}")
        });
        assert_eq!(v, back, "roundtrip mismatch for {text}");
        // pretty form too
        let back2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back2);
    });
}

#[test]
fn prop_paramset_flat_roundtrip() {
    forall("paramset flatten", 150, |g| {
        let n_leaves = g.usize_in(1..8);
        let p = ParamSet {
            leaves: (0..n_leaves)
                .map(|_| g.vec_f32(1..64, -1e3..1e3))
                .collect(),
        };
        let flat = p.to_flat();
        assert_eq!(flat.len(), p.numel());
        let q = ParamSet::from_flat(&flat, &p).unwrap();
        assert_eq!(p, q);
    });
}

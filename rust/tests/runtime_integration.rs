//! Integration: rust PJRT runtime executes the AOT JAX+Pallas artifacts.
//! Requires `make artifacts` (tiny preset). Skips if artifacts are absent.

use std::path::Path;

use crossfed::model::{Manifest, ParamSet};
use crossfed::runtime::{Batch, StepRuntime};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest_tiny.json").exists() {
        Some(Box::leak(p.into_boxed_path()))
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

fn rand_batch(m: &Manifest, seed: u64) -> Batch {
    let mut rng = crossfed::util::rng::Pcg64::new(seed, 7);
    let n = m.model.batch_size * m.model.seq_len;
    Batch {
        tokens: (0..n).map(|_| rng.below(m.model.vocab_size as u64) as i32).collect(),
        targets: (0..n).map(|_| rng.below(m.model.vocab_size as u64) as i32).collect(),
    }
}

#[test]
fn train_step_runs_and_loss_decreases() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = StepRuntime::load_preset(dir, "tiny").unwrap();
    let m = rt.manifest().clone();
    let mut params = ParamSet::init(&m, 42);
    let batch = rand_batch(&m, 1);

    // initial loss ~ ln(vocab)
    let out0 = rt.train_step(&params, &batch).unwrap();
    let ln_v = (m.model.vocab_size as f32).ln();
    assert!((out0.loss - ln_v).abs() < 0.5, "loss0={} lnV={}", out0.loss, ln_v);
    assert_eq!(out0.grads.n_leaves(), m.params.len());
    assert!(!out0.grads.has_non_finite());
    assert!(out0.grads.l2_norm() > 0.0);

    // 30 SGD steps on one batch must overfit it
    let mut loss = out0.loss;
    for _ in 0..30 {
        let out = rt.train_step(&params, &batch).unwrap();
        params.axpy(-0.5, &out.grads);
        loss = out.loss;
    }
    assert!(loss < out0.loss - 0.5, "no progress: {} -> {}", out0.loss, loss);

    // eval agrees with train loss on the same batch
    let ev = rt.eval_step(&params, &batch).unwrap();
    assert!((ev.loss - loss).abs() < 0.5);
    assert!(ev.n_total == rt.tokens_per_batch());
}

#[test]
fn eval_counts_are_bounded() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = StepRuntime::load_preset(dir, "tiny").unwrap();
    let m = rt.manifest().clone();
    let params = ParamSet::init(&m, 7);
    let ev = rt.eval_step(&params, &rand_batch(&m, 2)).unwrap();
    assert!(ev.n_correct <= ev.n_total);
    assert!(ev.loss.is_finite());
}

#[test]
fn full_stack_federated_round_real_runtime() {
    // Coordinator over the real PJRT backend: 6 rounds, gradient
    // aggregation with compression + encryption + DP, loss must drop.
    let Some(dir) = artifacts_dir() else { return };
    let rt = StepRuntime::load_preset(dir, "tiny").unwrap();
    let m = rt.manifest().clone();

    let mut cfg = crossfed::config::preset("quick").unwrap();
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg.aggregation = crossfed::aggregation::AggregationKind::GradientAgg;
    cfg.compression = crossfed::compress::Compression::TopK { ratio: 0.5 };
    cfg.error_feedback = true;
    cfg.encrypt = true;

    let cluster = crossfed::cluster::ClusterSpec::paper_default();
    let init = ParamSet::init(&m, cfg.seed);
    let mut coord = crossfed::coordinator::Coordinator::new(
        cfg,
        cluster,
        &rt,
        init,
        m.model.batch_size,
        m.model.seq_len,
    )
    .unwrap();
    let r = coord.run().unwrap();
    assert_eq!(r.rounds_run, 6);
    let first = r.history[0].train_loss;
    assert!(
        r.final_eval_loss < first,
        "no progress: {} -> {}",
        first,
        r.final_eval_loss
    );
    assert!(r.wire_bytes > 100_000); // compressed but nonzero traffic
    assert!(!coord.global().has_non_finite());
}

#[test]
fn secure_agg_over_real_runtime_matches_plain() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = StepRuntime::load_preset(dir, "tiny").unwrap();
    let m = rt.manifest().clone();
    let cluster = crossfed::cluster::ClusterSpec::paper_default();

    let run = |secure: bool| {
        let mut cfg = crossfed::config::preset("quick").unwrap();
        cfg.rounds = 4;
        cfg.secure_agg = secure;
        let init = ParamSet::init(&m, cfg.seed);
        let mut coord = crossfed::coordinator::Coordinator::new(
            cfg,
            cluster.clone(),
            &rt,
            init,
            m.model.batch_size,
            m.model.seq_len,
        )
        .unwrap();
        coord.run().unwrap()
    };
    let plain = run(false);
    let masked = run(true);
    // pairwise masks cancel: training trajectories should agree closely
    assert!(
        (plain.final_eval_loss - masked.final_eval_loss).abs() < 0.15,
        "{} vs {}",
        plain.final_eval_loss,
        masked.final_eval_loss
    );
}

//! Cloud-economics acceptance (ISSUE 5): deterministic pricing, exact
//! dollar decomposition, the hierarchy's egress-dollar saving, and
//! cost-aware leader placement.
//!
//! The acceptance bar:
//! (a) pricing a run twice — or on a different thread count — is
//!     bit-identical;
//! (b) ledger dollars decompose exactly: the total is the sum of the
//!     per-cloud, per-class entries;
//! (c) with `PriceBook::paper_default()` at `paper_default_scaled(16)`,
//!     hierarchical egress dollars are ≤ 1/4 of the flat star's;
//! (d) `placement: auto` picks the argmin leader cloud on an asymmetric
//!     price book and matches `fixed:c` for that cloud bit-for-bit —
//!     placement changes routing and dollars, never training math.

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::cost::{EgressRate, Placement, PriceBook};
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::LinkClass;
use crossfed::runtime::MockRuntime;
use crossfed::util::par;

/// Params big enough that update traffic dwarfs the one-off shard
/// distribution (the cost comparison is about the training schedule).
fn init_params() -> ParamSet {
    let a: Vec<f32> = (0..8192).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let b: Vec<f32> = (0..4096).map(|i| ((i % 89) as f32) * -0.01 + 0.4).collect();
    ParamSet { leaves: vec![a, b] }
}

fn base_cfg(name: &str, hier: bool) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.name = name.into();
    c.rounds = 3;
    c.eval_every = 1;
    c.eval_batches = 1;
    c.local_steps = 2;
    c.local_lr = 3.0;
    c.server_lr = 3.0;
    c.target_loss = None;
    c.hierarchical = hier;
    // enough documents that every one of 48 dirichlet shards is non-empty
    c.corpus = CorpusConfig { n_docs: 240, doc_sentences: 2, n_topics: 6, seed: 5 };
    c
}

fn run_coord(
    cfg: ExperimentConfig,
    cluster: ClusterSpec,
) -> (RunResult, Coordinator<'static, MockRuntime>) {
    let backend: &'static MockRuntime = Box::leak(Box::new(MockRuntime::new(0.4)));
    let mut coord =
        Coordinator::new(cfg, cluster, backend, init_params(), 4, 16).unwrap();
    let r = coord.run().unwrap();
    (r, coord)
}

/// Egress dollars the training rounds billed (setup distribution is
/// billed before round 0 and excluded from round records).
fn round_egress_usd(r: &RunResult) -> f64 {
    r.history.iter().map(|h| h.cost.egress_total_usd()).sum()
}

// ------------------------------------------------------------------
// (a) pricing is deterministic across repeats and thread counts
// ------------------------------------------------------------------

fn assert_costs_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.history.len(), b.history.len(), "{ctx}: rounds");
    for (ra, rb) in a.history.iter().zip(&b.history) {
        assert_eq!(
            ra.cum_cost_usd.to_bits(),
            rb.cum_cost_usd.to_bits(),
            "{ctx}: round {} cumulative dollars",
            ra.round
        );
        assert_eq!(ra.cost.n_clouds(), rb.cost.n_clouds());
        for c in 0..ra.cost.n_clouds() {
            assert_eq!(
                ra.cost.compute_usd[c].to_bits(),
                rb.cost.compute_usd[c].to_bits(),
                "{ctx}: round {} compute cloud {c}",
                ra.round
            );
            for k in 0..3 {
                assert_eq!(
                    ra.cost.egress_usd[c][k].to_bits(),
                    rb.cost.egress_usd[c][k].to_bits(),
                    "{ctx}: round {} egress cloud {c} class {k}",
                    ra.round
                );
            }
        }
    }
    assert_eq!(
        a.cost.total_usd().to_bits(),
        b.cost.total_usd().to_bits(),
        "{ctx}: run total"
    );
    assert_eq!(a.wire_bytes_class, b.wire_bytes_class, "{ctx}: class split");
}

#[test]
fn pricing_is_bit_identical_across_repeats_and_threads() {
    let run = || {
        run_coord(
            base_cfg("cost-det", true),
            ClusterSpec::paper_default_scaled(2),
        )
        .0
    };
    let a = run();
    let b = run();
    assert_costs_identical(&a, &b, "repeat");
    for threads in [1usize, 3] {
        let t = par::with_threads(threads, run);
        assert_costs_identical(&a, &t, &format!("{threads} threads"));
    }
}

// ------------------------------------------------------------------
// (b) dollars decompose exactly
// ------------------------------------------------------------------

#[test]
fn ledger_dollars_decompose_exactly() {
    let (r, coord) = run_coord(
        base_cfg("cost-decompose", true),
        ClusterSpec::paper_default_scaled(4),
    );
    assert!(r.cost.total_usd() > 0.0, "run billed nothing");
    // total == sum of per-cloud, per-class entries, in the ledger's own
    // summation order — bit-exact, not approximately
    let mut manual = 0.0f64;
    for c in 0..r.cost.n_clouds() {
        manual += r.cost.compute_usd[c];
        for e in &r.cost.egress_usd[c] {
            manual += e;
        }
    }
    assert_eq!(manual.to_bits(), r.cost.total_usd().to_bits());
    // every round record decomposes the same way
    for h in &r.history {
        let mut m = 0.0f64;
        for c in 0..h.cost.n_clouds() {
            m += h.cost.compute_usd[c];
            for e in &h.cost.egress_usd[c] {
                m += e;
            }
        }
        assert_eq!(m.to_bits(), h.cost.total_usd().to_bits());
    }
    // the coordinator's cumulative ledger is what the result carries
    assert_eq!(
        coord.run_cost().total_usd().to_bits(),
        r.cost.total_usd().to_bits()
    );
    // and the per-class byte split on the result matches the WAN ledger
    for class in LinkClass::ALL {
        assert_eq!(r.wire_bytes_of(class), coord.wire_bytes_class(class));
    }
    assert!(r.wire_bytes_of(LinkClass::InterRegion) > 0);
}

// ------------------------------------------------------------------
// (c) hierarchy's egress dollars at scale
// ------------------------------------------------------------------

#[test]
fn hier_egress_dollars_quarter_of_star_at_scaled_16() {
    let cluster = ClusterSpec::paper_default_scaled(16);
    let (star, _) = run_coord(base_cfg("cost-star", false), cluster.clone());
    let (hier, _) = run_coord(base_cfg("cost-hier", true), cluster);
    let (star_usd, hier_usd) = (round_egress_usd(&star), round_egress_usd(&hier));
    assert!(star_usd > 0.0 && hier_usd > 0.0);
    assert!(
        hier_usd * 4.0 <= star_usd,
        "hierarchy lost its dollar advantage: star ${star_usd:.4} vs \
         hier ${hier_usd:.4}"
    );
    // compute dollars are schedule-independent: both modes train the
    // same local steps on the same platforms
    let star_compute: f64 =
        star.history.iter().map(|h| h.cost.compute_total_usd()).sum();
    let hier_compute: f64 =
        hier.history.iter().map(|h| h.cost.compute_total_usd()).sum();
    assert!((star_compute - hier_compute).abs() < 1e-9 * star_compute.max(1.0));
}

// ------------------------------------------------------------------
// (d) cost-aware placement
// ------------------------------------------------------------------

/// Pinned fixture: inter-region egress $0.20 / $0.15 / $0.05 per GB for
/// clouds 0/1/2 — the leader should land on cloud 2, the cheapest
/// sender (the leader ships the broadcasts).
fn asym_book() -> PriceBook {
    let mut book = PriceBook::uniform(3.0, 0.0);
    book.name = "asym".into();
    book.egress = [
        EgressRate::flat(0.001),
        EgressRate::flat(0.09),
        EgressRate::flat(0.09),
    ];
    book.overrides = vec![
        (0, LinkClass::InterRegion, EgressRate::flat(0.20)),
        (1, LinkClass::InterRegion, EgressRate::flat(0.15)),
        (2, LinkClass::InterRegion, EgressRate::flat(0.05)),
    ];
    book
}

fn placement_cfg(name: &str, placement: Placement) -> ExperimentConfig {
    let mut c = base_cfg(name, true);
    c.placement = placement;
    c.price_book = asym_book();
    c
}

#[test]
fn auto_placement_selects_argmin_and_preserves_training_math() {
    let cluster = ClusterSpec::paper_default_scaled(4);
    let (auto, auto_coord) =
        run_coord(placement_cfg("place-auto", Placement::Auto), cluster.clone());
    // the argmin on the pinned fixture is cloud 2, leader = its gateway
    assert_eq!(auto_coord.leader_cloud(), 2);
    assert_eq!(auto_coord.leader(), cluster.gateway(2));

    // auto is exactly fixed:2 — same leader, same everything
    let (fixed2, f2_coord) =
        run_coord(placement_cfg("place-auto", Placement::Fixed(2)), cluster.clone());
    assert_eq!(f2_coord.leader(), auto_coord.leader());
    assert_costs_identical(&auto, &fixed2, "auto vs fixed:2");
    assert_eq!(auto.wire_bytes, fixed2.wire_bytes);
    assert_eq!(auto.sim_secs.to_bits(), fixed2.sim_secs.to_bits());

    // placement must not change training math: a different leader gives
    // the identical loss history — only routing, time and dollars move
    let (fixed0, f0_coord) =
        run_coord(placement_cfg("place-fix0", Placement::Fixed(0)), cluster);
    assert_eq!(f0_coord.leader_cloud(), 0);
    assert_eq!(auto.history.len(), fixed0.history.len());
    for (ra, rf) in auto.history.iter().zip(&fixed0.history) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rf.train_loss.to_bits(),
            "round {} train loss",
            ra.round
        );
        assert_eq!(
            ra.eval_loss.map(f32::to_bits),
            rf.eval_loss.map(f32::to_bits),
            "round {} eval loss",
            ra.round
        );
        assert_eq!(ra.eval_acc, rf.eval_acc, "round {} eval acc", ra.round);
    }
    assert_eq!(
        auto.final_eval_loss.to_bits(),
        fixed0.final_eval_loss.to_bits()
    );
    // ...and on this fixture the auto leader is strictly cheaper on
    // egress than the expensive fixed:0 choice
    assert!(
        round_egress_usd(&auto) < round_egress_usd(&fixed0),
        "auto ${:.4} should beat fixed:0 ${:.4}",
        round_egress_usd(&auto),
        round_egress_usd(&fixed0)
    );
}

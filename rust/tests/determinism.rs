//! End-to-end determinism: same seed + config ⇒ bit-identical
//! `RunResult` history across runs AND across thread counts, for the
//! sync (star), async and hierarchical schedulers. This lifts the
//! kernel-level guarantee of `parallel_kernels.rs` (fixed-block
//! parallelism is bit-identical for any thread count) to the
//! coordinator level: simulated times, wire bytes, losses and epsilons
//! are pure functions of the experiment seed.

use crossfed::aggregation::AggregationKind;
use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::runtime::MockRuntime;
use crossfed::util::par;

/// Params large enough (> par::PAR_THRESHOLD elements) that the
/// block-parallel kernel paths actually engage.
fn init_params() -> ParamSet {
    let a: Vec<f32> = (0..40_000).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let b: Vec<f32> = (0..40_000).map(|i| ((i % 89) as f32) * -0.01 + 0.4).collect();
    ParamSet { leaves: vec![a, b] }
}

fn cfg(mode: &str) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.name = mode.into();
    c.rounds = 2;
    c.eval_every = 1;
    c.eval_batches = 2;
    c.local_steps = 2;
    c.local_lr = 2.0;
    c.server_lr = 2.0;
    c.target_loss = None;
    c.corpus = CorpusConfig { n_docs: 90, doc_sentences: 3, n_topics: 6, seed: 7 };
    match mode {
        "sync" => {}
        "lossless" => c.lossless = crossfed::compress::LosslessStage::Auto,
        "async" => c.aggregation = AggregationKind::Async { alpha: 0.6 },
        "hier" => c.hierarchical = true,
        "hier-par" => {
            // per-cloud parallel rounds: results must not depend on how
            // many host threads execute the clouds
            c.hierarchical = true;
            c.par_rounds = true;
        }
        "hier-faulty" => {
            // a mid-run gateway death + link degrade must stay exactly as
            // reproducible as a clean run: failover is deterministic
            c.hierarchical = true;
            c.faults = crossfed::netsim::FaultPlan::new(vec![
                crossfed::netsim::FaultEvent::GatewayDown { cloud: 1, at: 1 },
                crossfed::netsim::FaultEvent::LinkDegrade {
                    src: 0,
                    dst: 1,
                    at: 1,
                    factor: 0.5,
                },
            ]);
        }
        "hier-async-spot" => {
            // the buffered asynchronous hierarchy under membership churn:
            // gateway buffers, per-cloud secure re-keying and spot billing
            // must all be pure functions of the seed
            c.hierarchical = true;
            c.aggregation = AggregationKind::Async { alpha: 0.6 };
            c.secure_agg = true;
            c.spot = true;
            c.rounds = 4;
            c.faults = crossfed::netsim::FaultPlan::new(vec![
                crossfed::netsim::FaultEvent::WorkerLeave { node: 1, at: 1 },
                crossfed::netsim::FaultEvent::WorkerJoin { node: 1, at: 3 },
            ]);
        }
        other => panic!("unknown mode {other}"),
    }
    c
}

fn run(mode: &str) -> RunResult {
    let backend = MockRuntime::new(0.4);
    let cluster = ClusterSpec::paper_default_scaled(2);
    let mut coord =
        Coordinator::new(cfg(mode), cluster, &backend, init_params(), 4, 16)
            .unwrap();
    coord.run().unwrap()
}

/// Bit-level equality of everything simulated (host profiling excluded).
fn assert_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.rounds_run, b.rounds_run, "{ctx}: rounds");
    assert_eq!(a.wire_bytes, b.wire_bytes, "{ctx}: wire bytes");
    assert_eq!(
        a.sim_secs.to_bits(),
        b.sim_secs.to_bits(),
        "{ctx}: sim secs {} vs {}",
        a.sim_secs,
        b.sim_secs
    );
    assert_eq!(
        a.final_eval_loss.to_bits(),
        b.final_eval_loss.to_bits(),
        "{ctx}: final eval loss"
    );
    assert_eq!(a.final_eval_acc.to_bits(), b.final_eval_acc.to_bits(), "{ctx}");
    assert_eq!(a.history.len(), b.history.len(), "{ctx}: history len");
    for (ra, rb) in a.history.iter().zip(&b.history) {
        let r = ra.round;
        assert_eq!(ra.round, rb.round, "{ctx}");
        assert_eq!(ra.wire_bytes, rb.wire_bytes, "{ctx} round {r}: wire");
        assert_eq!(
            ra.sim_secs.to_bits(),
            rb.sim_secs.to_bits(),
            "{ctx} round {r}: sim"
        );
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{ctx} round {r}: train loss"
        );
        assert_eq!(
            ra.eval_loss.map(f32::to_bits),
            rb.eval_loss.map(f32::to_bits),
            "{ctx} round {r}: eval loss"
        );
        assert_eq!(
            ra.eval_acc.map(f64::to_bits),
            rb.eval_acc.map(f64::to_bits),
            "{ctx} round {r}: eval acc"
        );
        assert_eq!(
            ra.epsilon.to_bits(),
            rb.epsilon.to_bits(),
            "{ctx} round {r}: epsilon"
        );
        assert_eq!(ra.partition_gen, rb.partition_gen, "{ctx} round {r}");
        assert_eq!(
            ra.active_members, rb.active_members,
            "{ctx} round {r}: active members"
        );
        let pa: Vec<u64> = ra.platform_secs.iter().map(|x| x.to_bits()).collect();
        let pb: Vec<u64> = rb.platform_secs.iter().map(|x| x.to_bits()).collect();
        assert_eq!(pa, pb, "{ctx} round {r}: platform secs");
    }
}

#[test]
fn repeat_runs_are_bit_identical() {
    for mode in [
        "sync",
        "lossless",
        "async",
        "hier",
        "hier-par",
        "hier-faulty",
        "hier-async-spot",
    ] {
        let a = run(mode);
        let b = run(mode);
        assert_identical(&a, &b, mode);
    }
}

#[test]
fn thread_count_does_not_change_results() {
    for mode in [
        "sync",
        "lossless",
        "async",
        "hier",
        "hier-par",
        "hier-faulty",
        "hier-async-spot",
    ] {
        let serial = par::with_threads(1, || run(mode));
        let par4 = par::with_threads(4, || run(mode));
        assert_identical(&serial, &par4, &format!("{mode} 1T vs 4T"));
    }
}

#[test]
fn lossless_stage_never_perturbs_losses() {
    // the lossless stage is pure wire pricing: every loss / eval /
    // epsilon in the history is bit-identical to the unstaged run,
    // while the staged run ships strictly fewer bytes
    let base = run("sync");
    let staged = run("lossless");
    assert_eq!(base.history.len(), staged.history.len());
    for (a, b) in base.history.iter().zip(&staged.history) {
        let r = a.round;
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "round {r}: train loss"
        );
        assert_eq!(
            a.eval_loss.map(f32::to_bits),
            b.eval_loss.map(f32::to_bits),
            "round {r}: eval loss"
        );
        assert_eq!(
            a.eval_acc.map(f64::to_bits),
            b.eval_acc.map(f64::to_bits),
            "round {r}: eval acc"
        );
        assert_eq!(a.epsilon.to_bits(), b.epsilon.to_bits(), "round {r}");
    }
    assert_eq!(
        base.final_eval_loss.to_bits(),
        staged.final_eval_loss.to_bits()
    );
    assert!(
        staged.wire_bytes < base.wire_bytes,
        "staged {} vs plain {}",
        staged.wire_bytes,
        base.wire_bytes
    );
}

#[test]
fn different_seeds_differ() {
    // guard against the comparisons above passing vacuously
    let a = run("sync");
    let backend = MockRuntime::new(0.4);
    let mut c = cfg("sync");
    c.seed = 777;
    let mut coord = Coordinator::new(
        c,
        ClusterSpec::paper_default_scaled(2),
        &backend,
        init_params(),
        4,
        16,
    )
    .unwrap();
    let b = coord.run().unwrap();
    assert_ne!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
}

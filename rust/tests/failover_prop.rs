//! Property test for the gateway failover / fail-back state machine.
//!
//! Random interleavings of egress kills, egress restores, roster leaves
//! and rejoins are driven against [`ClusterSpec`] exactly the way the
//! coordinator drives it (an election runs when the sitting gateway
//! loses eligibility, and on every egress restore — the fail-back), and
//! the elected gateway is compared after every step against a tiny
//! reference model: *the lowest-id member of the cloud that is both on
//! the roster and has working egress*. Killing the last eligible member
//! of a cloud must be a clean election error that leaves the state
//! machine usable (the op is rolled back and the sequence continues).

use crossfed::cluster::ClusterSpec;
use crossfed::testkit::proptest_kit::{forall, Gen};

/// The reference spec, small enough to be obviously correct.
struct RefModel {
    cloud_of: Vec<usize>,
    active: Vec<bool>,
    egress_ok: Vec<bool>,
    gateway: Vec<usize>,
}

impl RefModel {
    fn new(cluster: &ClusterSpec) -> RefModel {
        let n = cluster.n();
        let n_clouds = cluster.n_clouds();
        RefModel {
            cloud_of: (0..n).map(|i| cluster.cloud_of(i)).collect(),
            active: vec![true; n],
            egress_ok: vec![true; n],
            gateway: (0..n_clouds).map(|c| cluster.gateway(c)).collect(),
        }
    }

    fn eligible(&self, node: usize) -> bool {
        self.active[node] && self.egress_ok[node]
    }

    /// Lowest-id eligible member of cloud `c`, if any.
    fn elect(&self, c: usize) -> Option<usize> {
        (0..self.cloud_of.len())
            .find(|&m| self.cloud_of[m] == c && self.eligible(m))
    }
}

#[derive(Clone, Copy, Debug)]
enum Op {
    KillEgress,
    RestoreEgress,
    Leave,
    Join,
}

#[test]
fn prop_failover_matches_reference_spec() {
    forall("gateway failover vs reference spec", 300, |g: &mut Gen| {
        let npc = g.usize_in(2..5);
        let mut cluster = ClusterSpec::paper_default_scaled(npc);
        let mut model = RefModel::new(&cluster);
        let n = cluster.n();
        let n_clouds = cluster.n_clouds();

        // seed gateways must agree before any fault
        for c in 0..n_clouds {
            assert_eq!(cluster.gateway(c), model.gateway[c], "initial gw");
        }

        let n_ops = g.usize_in(1..30);
        for step in 0..n_ops {
            let node = g.usize_in(0..n);
            let c = model.cloud_of[node];
            let op = *g.choose(&[
                Op::KillEgress,
                Op::RestoreEgress,
                Op::Leave,
                Op::Join,
            ]);
            match op {
                Op::KillEgress => {
                    model.egress_ok[node] = false;
                    cluster.mark_egress_failed(node);
                    if node == model.gateway[c] {
                        match model.elect(c) {
                            Some(expect) => {
                                model.gateway[c] = expect;
                                let got = cluster.reelect_gateway(c).unwrap();
                                assert_eq!(got, expect, "step {step}: failover");
                            }
                            None => {
                                // killing the last eligible member is a
                                // clean error; roll back and continue
                                assert!(
                                    cluster.reelect_gateway(c).is_err(),
                                    "step {step}: election must fail"
                                );
                                model.egress_ok[node] = true;
                                cluster.mark_egress_restored(node);
                            }
                        }
                    }
                }
                Op::RestoreEgress => {
                    model.egress_ok[node] = true;
                    cluster.mark_egress_restored(node);
                    // fail-back: the coordinator re-runs the election on
                    // every restore, so the lowest-id eligible member
                    // (often the restored node itself) takes the role back
                    let expect =
                        model.elect(c).expect("restored node is eligible");
                    model.gateway[c] = expect;
                    let got = cluster.reelect_gateway(c).unwrap();
                    assert_eq!(got, expect, "step {step}: fail-back");
                }
                Op::Leave => {
                    model.active[node] = false;
                    cluster.deactivate(node);
                    if node == model.gateway[c] {
                        match model.elect(c) {
                            Some(expect) => {
                                model.gateway[c] = expect;
                                let got = cluster.reelect_gateway(c).unwrap();
                                assert_eq!(got, expect, "step {step}: leave");
                            }
                            None => {
                                assert!(
                                    cluster.reelect_gateway(c).is_err(),
                                    "step {step}: election must fail"
                                );
                                model.active[node] = true;
                                cluster.activate(node);
                            }
                        }
                    }
                }
                Op::Join => {
                    // rejoins never trigger an election: the sitting
                    // gateway keeps the role even if a lower-id member
                    // comes back (only an egress restore fails back)
                    model.active[node] = true;
                    cluster.activate(node);
                }
            }

            // global invariants after every step
            for cl in 0..n_clouds {
                assert_eq!(
                    cluster.gateway(cl),
                    model.gateway[cl],
                    "step {step}: cloud {cl} gateway diverged"
                );
                let gw = cluster.gateway(cl);
                assert_eq!(cluster.cloud_of(gw), cl, "gateway in its cloud");
                // a sitting gateway is always eligible: every op that
                // could invalidate it ran an election above
                assert!(
                    model.eligible(gw),
                    "step {step}: cloud {cl} gateway {gw} ineligible"
                );
            }
            assert_eq!(
                cluster.n_active(),
                model.active.iter().filter(|&&a| a).count(),
                "step {step}: roster size"
            );
        }
    });
}

/// Kill → restore → re-kill on one cloud: the exact scripted sequence
/// the paper's transient-outage scenario uses, pinned step by step.
#[test]
fn scripted_kill_restore_rekill() {
    let mut cluster = ClusterSpec::paper_default_scaled(3);
    let c = 1;
    let members = cluster.cloud_members(c);
    assert_eq!(cluster.gateway(c), members[0]);

    // kill: the next member takes over
    cluster.mark_egress_failed(members[0]);
    assert_eq!(cluster.reelect_gateway(c).unwrap(), members[1]);

    // restore: the original (lowest-id) member fails back
    cluster.mark_egress_restored(members[0]);
    assert_eq!(cluster.reelect_gateway(c).unwrap(), members[0]);

    // re-kill while the second member is also off the roster: the third
    // member is the only eligible standby left
    cluster.deactivate(members[1]);
    cluster.mark_egress_failed(members[0]);
    assert_eq!(cluster.reelect_gateway(c).unwrap(), members[2]);

    // drop the last eligible member: election errors but the state
    // machine survives — rejoining the second member elects it again
    cluster.deactivate(members[2]);
    assert!(cluster.reelect_gateway(c).is_err());
    cluster.activate(members[1]);
    assert_eq!(cluster.reelect_gateway(c).unwrap(), members[1]);
}

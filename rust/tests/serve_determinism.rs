//! Serving-subsystem determinism: a serving day is a pure function of
//! its seed. Same seed + config ⇒ bit-identical `ServeResult` across
//! repeat runs AND across host thread counts (the serving simulator is
//! a single event stream; nothing may read the thread pool). Also pins
//! the economics the router exists for: on an asymmetric price book the
//! latency-optimal placement differs from the cost-optimal one.

use crossfed::cluster::ClusterSpec;
use crossfed::cost::PriceBook;
use crossfed::serve::{self, RoutePolicy, ServeConfig, ServeResult, TrafficSpec};
use crossfed::util::par;

/// Small enough for a debug-build test, large enough that every replica
/// sees traffic and batches actually form (~10k requests over 6 hours).
fn cfg(route: RoutePolicy, seed: u64) -> ServeConfig {
    ServeConfig {
        name: format!("det-{}", route.name()),
        seed,
        route,
        traffic: TrafficSpec { users: 20_000, ..TrafficSpec::default() },
        duration_secs: 6.0 * 3600.0,
        refresh_period_secs: 2.0 * 3600.0,
        ..ServeConfig::default()
    }
}

/// Asymmetric book: cloud 2 is ~8x cheaper than everyone else, so the
/// cost argmin leaves the fast clouds; latency routing never volunteers
/// for cloud 2 (it runs the slowest accelerator profile).
fn asymmetric_book() -> PriceBook {
    let mut book = PriceBook::uniform(4.0, 0.09);
    book.name = "det-asym".into();
    book.compute_per_node_hour = vec![5.0, 4.0, 0.5, 4.5];
    book
}

fn cluster() -> ClusterSpec {
    ClusterSpec::scaled(4, &[1])
}

fn run(route: RoutePolicy, seed: u64) -> ServeResult {
    let mut c = cfg(route, seed);
    c.price_book = asymmetric_book();
    serve::run(&c, &cluster()).expect("serve run")
}

/// Every observable field, floats as raw bits, in fixed order.
fn fingerprint(r: &ServeResult) -> Vec<u64> {
    let mut fp = vec![
        r.requests,
        r.events,
        r.refreshes,
        r.wire_bytes,
        r.max_queue_depth as u64,
        r.sim_secs.to_bits(),
        r.p50_ms.to_bits(),
        r.p99_ms.to_bits(),
        r.mean_ms.to_bits(),
        r.max_ms.to_bits(),
        r.mean_queue_depth.to_bits(),
        r.staleness_mean_secs.to_bits(),
        r.cost.total_usd().to_bits(),
        r.cost.egress_total_usd().to_bits(),
        r.cost.compute_total_usd().to_bits(),
    ];
    fp.extend_from_slice(&r.wire_bytes_class);
    fp.extend_from_slice(&r.requests_by_replica);
    fp
}

#[test]
fn repeat_runs_are_bit_identical() {
    for route in [RoutePolicy::Latency, RoutePolicy::Cost, RoutePolicy::Blended(0.5)] {
        let a = run(route, 42);
        let b = run(route, 42);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "repeat run diverged under {} routing",
            a.policy
        );
        assert!(a.requests > 1_000, "population too small to mean anything");
    }
}

#[test]
fn thread_count_does_not_change_results() {
    let narrow = par::with_threads(1, || run(RoutePolicy::Blended(0.5), 42));
    let wide = par::with_threads(4, || run(RoutePolicy::Blended(0.5), 42));
    assert_eq!(
        fingerprint(&narrow),
        fingerprint(&wide),
        "serving results depend on the host thread count"
    );
}

#[test]
fn different_seeds_diverge() {
    let a = run(RoutePolicy::Latency, 42);
    let b = run(RoutePolicy::Latency, 43);
    assert_ne!(
        fingerprint(&a),
        fingerprint(&b),
        "different seeds must produce different serving days"
    );
}

#[test]
fn latency_optimal_differs_from_cost_optimal() {
    let lat = run(RoutePolicy::Latency, 42);
    let cost = run(RoutePolicy::Cost, 42);
    assert_eq!(cost.busiest_replica(), 2, "cloud 2 is priced to win every cost argmin");
    assert_ne!(
        lat.busiest_replica(),
        cost.busiest_replica(),
        "latency routing must not converge to the same placement as \
         cost routing on an asymmetric book"
    );
    assert!(
        cost.usd_per_million() < lat.usd_per_million(),
        "cost routing must actually be cheaper: ${:.2}/M vs ${:.2}/M",
        cost.usd_per_million(),
        lat.usd_per_million()
    );
}

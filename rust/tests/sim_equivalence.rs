//! Equivalence proofs for the planet-scale simulator core.
//!
//! The CSR-indexed [`Wan`] replaced per-pair hash tables; this suite
//! pins its observable semantics to an embedded reference
//! implementation that still uses the old storage (one `HashMap` per
//! ledger) while sharing the public [`Link::transfer`] hop math and the
//! same noise-RNG stream. Every transfer, error, warmth transition,
//! gateway failover and ledger query must agree bit-for-bit — the
//! refactor is allowed to change cache behaviour, not results.
//!
//! The coordinator-level tests then check the two new run-loop knobs on
//! top: `history_every` thinning streams the same records an unthinned
//! run keeps, and `par_rounds` is invariant to the host thread count.

use std::collections::HashMap;

use crossfed::cluster::ClusterSpec;
use crossfed::config::preset;
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::{Link, LinkClass, NetError, Protocol, TransferStats, Wan};
use crossfed::partition::PartitionStrategy;
use crossfed::runtime::MockRuntime;
use crossfed::util::par;
use crossfed::util::rng::Pcg64;

/// The WAN noise stream id (`netsim::topology::WAN_STREAM`) — the
/// reference must draw jitter from the very same stream to stay
/// bit-comparable.
const WAN_STREAM: u64 = 0x57414e;

/// Pre-CSR reference WAN: hash-table storage, same routing rules, same
/// per-hop [`Link::transfer`] math, same RNG stream. Deliberately naive
/// — correctness is obvious from the code, so any divergence indicts
/// the indexed implementation.
struct RefWan {
    cloud_of: Vec<usize>,
    /// region name per cloud (class is derived by string compare, the
    /// pre-interning semantics)
    region_of: Vec<String>,
    gateways: Vec<usize>,
    down: Vec<bool>,
    links: HashMap<(usize, usize), Link>,
    bytes: HashMap<(usize, usize), u64>,
    /// warm-protocol bitmask per directed pair
    warm: HashMap<(usize, usize), u8>,
    by_cloud_class: Vec<[u64; 3]>,
    /// pristine construction-time link spec per link class (what a
    /// re-elected gateway's fresh mesh links are built from)
    exemplar: HashMap<usize, Link>,
    rng: Pcg64,
}

impl RefWan {
    /// Mirror `wan`'s freshly-built topology (same cluster, same seed).
    fn new(cluster: &ClusterSpec, wan: &Wan, seed: u64) -> RefWan {
        let n = cluster.n();
        let n_clouds = cluster.n_clouds();
        let cloud_of: Vec<usize> = (0..n).map(|i| cluster.cloud_of(i)).collect();
        let gateways: Vec<usize> =
            (0..n_clouds).map(|c| cluster.gateway(c)).collect();
        let region_of: Vec<String> = (0..n_clouds)
            .map(|c| cluster.platforms[gateways[c]].region.clone())
            .collect();
        let mut links = HashMap::new();
        let mut exemplar: HashMap<usize, Link> = HashMap::new();
        for s in 0..n {
            for d in 0..n {
                if let Some(l) = wan.link(s, d) {
                    let class = wan.link_class(s, d).expect("link has a class");
                    exemplar.entry(class.index()).or_insert_with(|| l.clone());
                    links.insert((s, d), l.clone());
                }
            }
        }
        RefWan {
            cloud_of,
            region_of,
            gateways,
            down: vec![false; n],
            links,
            bytes: HashMap::new(),
            warm: HashMap::new(),
            by_cloud_class: vec![[0u64; 3]; n_clouds],
            exemplar,
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    fn class(&self, s: usize, d: usize) -> LinkClass {
        let (cs, cd) = (self.cloud_of[s], self.cloud_of[d]);
        if cs == cd {
            LinkClass::IntraAz
        } else if self.region_of[cs] == self.region_of[cd] {
            LinkClass::IntraRegion
        } else {
            LinkClass::InterRegion
        }
    }

    fn link_up(&self, s: usize, d: usize) -> bool {
        if !self.links.contains_key(&(s, d)) {
            return false;
        }
        self.class(s, d) == LinkClass::IntraAz || (!self.down[s] && !self.down[d])
    }

    fn route(&self, src: usize, dst: usize) -> Result<Vec<(usize, usize)>, NetError> {
        assert!(src != dst);
        if self.link_up(src, dst) {
            return Ok(vec![(src, dst)]);
        }
        let gs = self.gateways[self.cloud_of[src]];
        let gd = self.gateways[self.cloud_of[dst]];
        let mut hops = Vec::new();
        if src != gs {
            hops.push((src, gs));
        }
        if gs != gd {
            hops.push((gs, gd));
        }
        if gd != dst {
            hops.push((gd, dst));
        }
        for &(a, b) in &hops {
            if !self.links.contains_key(&(a, b)) {
                return Err(NetError::MissingLink { src, dst, a, b });
            }
            if !self.link_up(a, b) {
                let node = if self.down[a] { a } else { b };
                return Err(NetError::NodeDown { node });
            }
        }
        Ok(hops)
    }

    fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        payload: u64,
        protocol: Protocol,
        streams: usize,
    ) -> Result<TransferStats, NetError> {
        let hops = self.route(src, dst)?;
        let mut total = TransferStats { time_s: 0.0, wire_bytes: 0, handshake_s: 0.0 };
        let bit = 1u8 << protocol.index();
        for (s, d) in hops {
            let warm = self.warm.get(&(s, d)).copied().unwrap_or(0) & bit != 0;
            let st = self.links[&(s, d)]
                .transfer(payload, protocol, warm, streams, &mut self.rng);
            *self.warm.entry((s, d)).or_insert(0) |= bit;
            *self.bytes.entry((s, d)).or_insert(0) += st.wire_bytes;
            self.by_cloud_class[self.cloud_of[s]][self.class(s, d).index()] +=
                st.wire_bytes;
            total.time_s += st.time_s;
            total.wire_bytes += st.wire_bytes;
            total.handshake_s += st.handshake_s;
        }
        Ok(total)
    }

    /// WAN egress failure: every warm connection touching the node drops.
    fn fail_node(&mut self, node: usize) {
        self.down[node] = true;
        self.warm.retain(|&(s, d), _| s != node && d != node);
    }

    fn restore_node(&mut self, node: usize) {
        self.down[node] = false;
    }

    /// Tear down the old gateway's mesh, build the new one cold, drop
    /// all warmth. Ledgered bytes stay where they are — per-pair and
    /// per-class queries keep counting traffic over torn-down links.
    fn reelect_gateway(&mut self, cloud: usize, new_gw: usize) {
        let old = self.gateways[cloud];
        for c in 0..self.gateways.len() {
            if c == cloud {
                continue;
            }
            let g = self.gateways[c];
            self.links.remove(&(old, g));
            self.links.remove(&(g, old));
            let class = self.class(new_gw, g);
            let l = self.exemplar[&class.index()].clone();
            self.links.insert((new_gw, g), l.clone());
            self.links.insert((g, new_gw), l);
        }
        self.warm.clear();
        self.gateways[cloud] = new_gw;
    }

    fn class_total(&self, class: LinkClass) -> u64 {
        self.by_cloud_class.iter().map(|row| row[class.index()]).sum()
    }
}

const PROTOCOLS: [Protocol; 3] = [Protocol::Grpc, Protocol::Quic, Protocol::Tcp];

/// 400 scripted operations — random routed transfers interleaved with a
/// gateway death, a re-election, a restore, a degradation and a
/// connection reset — produce bit-identical stats, errors and ledgers
/// on the indexed WAN and the hash-table reference.
#[test]
fn indexed_wan_matches_hashmap_reference() {
    // 6 clouds x sizes (3,2,...) = 15 nodes over 2 regions: all three
    // link classes and multi-hop routes exist
    let cluster = ClusterSpec::scaled(6, &[3, 2]);
    let seed = 77;
    let mut wan = Wan::from_cluster(&cluster, seed);
    let mut reference = RefWan::new(&cluster, &wan, seed);
    let n = wan.n();
    assert_eq!(n, 15);
    let (g1, alt1) = (cluster.gateway(1), cluster.gateway(1) + 1);
    let (g0, g4) = (cluster.gateway(0), cluster.gateway(4));

    let mut script = Pcg64::new(5150, 0xB0B);
    for step in 0..400 {
        match step {
            // cloud 1's gateway dies: WAN routes through it must error
            120 => {
                wan.fail_node(g1);
                reference.fail_node(g1);
                continue;
            }
            // failover to its AZ peer: fresh cold mesh links
            180 => {
                wan.reelect_gateway(1, alt1);
                reference.reelect_gateway(1, alt1);
                continue;
            }
            240 => {
                wan.restore_node(g1);
                reference.restore_node(g1);
                continue;
            }
            // degrade an inter-region gateway link 4x
            300 => {
                wan.degrade_link(g0, g4, 0.25).expect("live link");
                reference.links.get_mut(&(g0, g4)).expect("live link").bandwidth_bps *=
                    0.25;
                continue;
            }
            330 => {
                wan.reset_connections();
                reference.warm.clear();
                continue;
            }
            _ => {}
        }
        let src = script.below_usize(n);
        let mut dst = script.below_usize(n);
        if dst == src {
            dst = (dst + 1) % n;
        }
        let payload = 1_000 + script.below(2_000_000);
        let protocol = PROTOCOLS[script.below_usize(3)];
        let streams = 1 + script.below_usize(8);
        let got = wan.transfer(src, dst, payload, protocol, streams);
        let want = reference.transfer(src, dst, payload, protocol, streams);
        assert_eq!(got, want, "step {step}: {src}->{dst} {payload}B {protocol:?}");
    }

    // every ledger view agrees, including bytes over torn-down links
    for s in 0..n {
        for d in 0..n {
            assert_eq!(
                wan.wire_bytes(s, d),
                reference.bytes.get(&(s, d)).copied().unwrap_or(0),
                "pair ({s},{d})"
            );
        }
    }
    for class in LinkClass::ALL {
        assert_eq!(
            wan.wire_bytes_class(class),
            reference.class_total(class),
            "{}",
            class.name()
        );
    }
    let ref_total: u64 = reference.by_cloud_class.iter().flatten().sum();
    assert_eq!(wan.total_wire_bytes(), ref_total);
    assert_eq!(wan.wire_bytes_by_cloud_class(), reference.by_cloud_class);
    assert_eq!(wan.gateway(1), alt1);
}

fn scaled_coord_run(
    history_every: usize,
    history_csv: Option<String>,
    par_rounds: bool,
) -> RunResult {
    let mut cfg = preset("quick").expect("builtin preset");
    cfg.name = format!("equiv-h{history_every}-p{par_rounds}");
    cfg.hierarchical = true;
    cfg.par_rounds = par_rounds;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.eval_batches = 1;
    cfg.local_steps = 2;
    cfg.target_loss = None;
    cfg.history_every = history_every;
    cfg.history_csv = history_csv;
    cfg.partition = PartitionStrategy::Fixed;
    cfg.corpus = CorpusConfig { n_docs: 60, doc_sentences: 2, n_topics: 6, seed: 9 };
    // 16 clouds x sizes (3,2,...) = 40 nodes
    let cluster = ClusterSpec::scaled(16, &[3, 2]);
    let backend = MockRuntime::new(0.4);
    let init = ParamSet { leaves: vec![vec![1.0f32; 64], vec![-0.5f32; 32]] };
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init, 4, 16).expect("coordinator");
    coord.run().expect("run")
}

/// `history_every` only thins what is *kept*: the thinned history is
/// exactly the unthinned one filtered to round % N == 0, the streamed
/// CSV carries every round, and the final-round metrics still come from
/// the true last round.
#[test]
fn history_thinning_streams_the_same_records() {
    let csv_path = std::env::temp_dir()
        .join(format!("crossfed-equiv-hist-{}.csv", std::process::id()));
    let full = scaled_coord_run(1, None, false);
    let thinned = scaled_coord_run(
        2,
        Some(csv_path.to_string_lossy().into_owned()),
        false,
    );

    assert_eq!(full.history.len(), 4);
    let kept: Vec<_> = full.history.iter().filter(|r| r.round % 2 == 0).collect();
    assert_eq!(thinned.history.len(), kept.len());
    for (t, k) in thinned.history.iter().zip(&kept) {
        assert_eq!(t.round, k.round);
        assert_eq!(t.wire_bytes, k.wire_bytes);
        assert_eq!(t.sim_secs.to_bits(), k.sim_secs.to_bits());
        assert_eq!(t.train_loss.to_bits(), k.train_loss.to_bits());
    }
    // the dropped records still shaped the run: totals and final-round
    // metrics match the unthinned run bit for bit
    assert_eq!(thinned.rounds_run, full.rounds_run);
    assert_eq!(thinned.wire_bytes, full.wire_bytes);
    assert_eq!(thinned.sim_secs.to_bits(), full.sim_secs.to_bits());
    assert_eq!(
        thinned.final_train_loss.to_bits(),
        full.final_train_loss.to_bits()
    );
    // the CSV streamed all four rounds plus the header
    let csv = std::fs::read_to_string(&csv_path).expect("history CSV written");
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 5, "header + 4 rows");
    assert!(lines[0].starts_with("round,"));
    for (i, row) in lines[1..].iter().enumerate() {
        assert!(row.starts_with(&format!("{i},")), "row {i}: {row}");
    }
    std::fs::remove_file(&csv_path).ok();
}

/// The per-cloud parallel hierarchical round is a pure function of the
/// seed: any host thread count produces the same bits.
#[test]
fn par_rounds_thread_count_invariant_at_16_clouds() {
    let serial = par::with_threads(1, || scaled_coord_run(1, None, true));
    let par4 = par::with_threads(4, || scaled_coord_run(1, None, true));
    let par9 = par::with_threads(9, || scaled_coord_run(1, None, true));
    for (a, b, ctx) in [(&serial, &par4, "1T vs 4T"), (&serial, &par9, "1T vs 9T")] {
        assert_eq!(a.rounds_run, b.rounds_run, "{ctx}");
        assert_eq!(a.wire_bytes, b.wire_bytes, "{ctx}");
        assert_eq!(a.sim_secs.to_bits(), b.sim_secs.to_bits(), "{ctx}");
        assert_eq!(
            a.final_eval_loss.to_bits(),
            b.final_eval_loss.to_bits(),
            "{ctx}"
        );
        assert_eq!(a.history.len(), b.history.len(), "{ctx}");
        for (ra, rb) in a.history.iter().zip(&b.history) {
            assert_eq!(ra.wire_bytes, rb.wire_bytes, "{ctx} round {}", ra.round);
            assert_eq!(
                ra.sim_secs.to_bits(),
                rb.sim_secs.to_bits(),
                "{ctx} round {}",
                ra.round
            );
            assert_eq!(
                ra.train_loss.to_bits(),
                rb.train_loss.to_bits(),
                "{ctx} round {}",
                ra.round
            );
            let pa: Vec<u64> = ra.platform_secs.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u64> = rb.platform_secs.iter().map(|x| x.to_bits()).collect();
            assert_eq!(pa, pb, "{ctx} round {}", ra.round);
        }
    }
}

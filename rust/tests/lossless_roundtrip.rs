//! Property tests for the lossless wire-compression stage.
//!
//! Every frame must roundtrip byte-exact — `to_bits`-exact for float
//! payloads, including NaN payload bits, ±infinity, denormals, ±0.0 and
//! alternating signs — at any input length and alignment. The Auto
//! stage must pick the smallest of {xor, varint, raw} every time, and
//! truncated frames must fail cleanly rather than decode garbage.

use crossfed::compress::{lossless, Compression, Compressor, LosslessStage};
use crossfed::testkit::proptest_kit::{forall, Gen};

/// Encode under `stage`, decode, demand byte equality; returns the
/// encoded size.
fn roundtrip(stage: LosslessStage, bytes: &[u8]) -> usize {
    let mut enc = Vec::new();
    lossless::encode_append(stage, bytes, &mut enc);
    let mut dec = Vec::new();
    lossless::decode_into(&enc, &mut dec).unwrap();
    assert_eq!(dec, bytes, "{stage:?} {} bytes", bytes.len());
    enc.len()
}

fn specials() -> Vec<u32> {
    vec![
        f32::NAN.to_bits(),
        0x7FC0_0001, // NaN with payload bits
        0xFF80_0001, // negative NaN variant
        f32::INFINITY.to_bits(),
        f32::NEG_INFINITY.to_bits(),
        1,           // smallest positive denormal
        0x8000_0001, // smallest negative denormal
        0,           // +0.0
        0x8000_0000, // -0.0
        f32::MAX.to_bits(),
        f32::MIN.to_bits(),
        f32::MIN_POSITIVE.to_bits(),
    ]
}

#[test]
fn random_walk_floats_roundtrip_exact() {
    forall("lossless random walk", 24, |g: &mut Gen| {
        let n = g.usize_in(0..20_000);
        let mut x = g.f32_in(-10.0..10.0);
        let mut bytes = Vec::with_capacity(n * 4 + 3);
        for _ in 0..n {
            x += g.f32_in(-0.01..0.01);
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        // sometimes leave an unaligned tail behind the word view
        for _ in 0..g.usize_in(0..4) {
            bytes.push(0x5A);
        }
        for stage in LosslessStage::ALL {
            roundtrip(stage, &bytes);
        }
        // a smooth walk shares exponents and high mantissa bits — the
        // float stage must actually win on it (not just roundtrip)
        if n >= 4096 {
            let xor = roundtrip(LosslessStage::XorFloat, &bytes);
            assert!(xor < bytes.len(), "xor {xor} >= raw {}", bytes.len());
        }
    });
}

#[test]
fn adversarial_floats_roundtrip_bit_exact() {
    let specials = specials();
    forall("lossless adversarial", 24, |g: &mut Gen| {
        let n = g.usize_in(1..5_000);
        let kind = g.usize_in(0..4);
        let mut bytes = Vec::with_capacity(n * 4);
        for i in 0..n {
            let w = match kind {
                // pure special-value soup
                0 => *g.choose(&specials),
                // constant stream
                1 => 0x3FC0_0000,
                // alternating sign, same magnitude
                2 => 2.5f32.to_bits() | ((i as u32 & 1) << 31),
                // smooth ramp with specials sprinkled in
                _ => {
                    if i % 97 == 0 {
                        *g.choose(&specials)
                    } else {
                        ((i as f32) * 0.001).to_bits()
                    }
                }
            };
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        for stage in LosslessStage::ALL {
            let n_enc = roundtrip(stage, &bytes);
            if stage == LosslessStage::Auto {
                // Auto never expands past the raw-frame overhead
                assert!(n_enc <= bytes.len() + lossless::RAW_FRAME_OVERHEAD);
            }
        }
    });
}

#[test]
fn auto_never_loses_to_either_stage_or_raw() {
    forall("auto minimality", 16, |g: &mut Gen| {
        let n = g.usize_in(0..8_192);
        let bytes: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
        let best = [LosslessStage::XorFloat, LosslessStage::DeltaVarint]
            .iter()
            .map(|&s| {
                let mut e = Vec::new();
                lossless::encode_append(s, &bytes, &mut e);
                e.len()
            })
            .chain([bytes.len() + lossless::RAW_FRAME_OVERHEAD])
            .min()
            .unwrap();
        let mut auto = Vec::new();
        lossless::encode_append(LosslessStage::Auto, &bytes, &mut auto);
        assert_eq!(auto.len(), best, "n={n}");
        let mut dec = Vec::new();
        lossless::decode_into(&auto, &mut dec).unwrap();
        assert_eq!(dec, bytes);
    });
}

#[test]
fn staged_codec_decodes_identically_to_unstaged() {
    // the stage wraps the lossy codec transparently: what the receiver
    // decodes is bit-identical with and without it, for every scheme
    forall("staged codec roundtrip", 8, |g: &mut Gen| {
        let n = g.usize_in(1..10_000);
        let xs: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0..1.0)).collect();
        for &scheme in &[
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.05 },
            Compression::RandK { ratio: 0.02 },
        ] {
            let mut plain = Compressor::new(scheme, 9);
            let want = Compressor::decompress(&plain.compress(&xs)).unwrap();
            let want: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            for stage in LosslessStage::ALL {
                let mut c = Compressor::new(scheme, 9).with_lossless(stage);
                let mut frame = Vec::new();
                c.compress_append(&xs, &mut frame);
                let mut scratch = Vec::new();
                let mut out = vec![0.0f32; n];
                Compressor::decompress_staged_into(
                    scheme,
                    stage,
                    &frame,
                    &mut scratch,
                    &mut out,
                )
                .unwrap();
                let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{scheme:?} {stage:?} n={n}");
            }
        }
    });
}

#[test]
fn truncated_frames_error_cleanly() {
    forall("truncated frames", 12, |g: &mut Gen| {
        let n = g.usize_in(1..2_000);
        let bytes: Vec<u8> = (0..n * 4).map(|i| (i % 251) as u8).collect();
        for stage in [LosslessStage::XorFloat, LosslessStage::DeltaVarint] {
            let mut enc = Vec::new();
            lossless::encode_append(stage, &bytes, &mut enc);
            let cut = g.usize_in(0..enc.len());
            let mut dec = Vec::new();
            assert!(
                lossless::decode_into(&enc[..cut], &mut dec).is_err(),
                "{stage:?} cut={cut} of {}",
                enc.len()
            );
        }
    });
}

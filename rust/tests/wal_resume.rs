//! Crash-consistent resume: a run killed by an injected
//! `coordinator-crash` at EVERY possible round, then resumed from its
//! write-ahead log, must match the uninterrupted run bit-for-bit —
//! losses, simulated time, wire bytes per link class and the dollar
//! bill. Exercised across all three schedulers (sync star, async,
//! hierarchical) with active fault plans, plus the WAL error taxonomy
//! at the coordinator level.

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::{Coordinator, CoordinatorCrashed};
use crossfed::metrics::RunResult;
use crossfed::model::ParamSet;
use crossfed::netsim::FaultPlan;
use crossfed::runtime::MockRuntime;
use crossfed::wal::{wal_path, WalFile, WalHeader};

const ROUNDS: usize = 5;

fn init() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0; 48], vec![-1.0; 16]] }
}

fn base_cfg(base_faults: &str) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.rounds = ROUNDS;
    // mixed eval / non-eval rounds so the eval sampler's RNG position
    // is part of what resume must restore
    c.eval_every = 2;
    c.local_lr = 3.0;
    c.faults = FaultPlan::parse(base_faults).unwrap();
    c
}

/// Bit-level equality of everything the paper's tables read.
fn assert_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.history.len(), b.history.len(), "{ctx}: round count");
    for (ra, rb) in a.history.iter().zip(&b.history) {
        let r = ra.round;
        assert_eq!(ra.round, rb.round, "{ctx}: round index");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{ctx}: train_loss r{r}"
        );
        assert_eq!(
            ra.eval_loss.map(f32::to_bits),
            rb.eval_loss.map(f32::to_bits),
            "{ctx}: eval_loss r{r}"
        );
        assert_eq!(
            ra.eval_acc.map(f64::to_bits),
            rb.eval_acc.map(f64::to_bits),
            "{ctx}: eval_acc r{r}"
        );
        assert_eq!(
            ra.sim_secs.to_bits(),
            rb.sim_secs.to_bits(),
            "{ctx}: sim_secs r{r}"
        );
        assert_eq!(ra.wire_bytes, rb.wire_bytes, "{ctx}: wire_bytes r{r}");
        assert_eq!(
            ra.epsilon.to_bits(),
            rb.epsilon.to_bits(),
            "{ctx}: epsilon r{r}"
        );
        assert_eq!(
            ra.partition_gen, rb.partition_gen,
            "{ctx}: partition_gen r{r}"
        );
        assert_eq!(
            ra.cum_cost_usd.to_bits(),
            rb.cum_cost_usd.to_bits(),
            "{ctx}: cum_cost_usd r{r}"
        );
        for (sa, sb) in ra.platform_secs.iter().zip(&rb.platform_secs) {
            assert_eq!(
                sa.to_bits(),
                sb.to_bits(),
                "{ctx}: platform_secs r{r}"
            );
        }
    }
    assert_eq!(a.rounds_run, b.rounds_run, "{ctx}: rounds_run");
    assert_eq!(a.wire_bytes, b.wire_bytes, "{ctx}: wire_bytes");
    assert_eq!(
        a.wire_bytes_class, b.wire_bytes_class,
        "{ctx}: wire_bytes_class"
    );
    assert_eq!(
        a.sim_secs.to_bits(),
        b.sim_secs.to_bits(),
        "{ctx}: sim_secs"
    );
    assert_eq!(
        a.final_train_loss.to_bits(),
        b.final_train_loss.to_bits(),
        "{ctx}: final_train_loss"
    );
    assert_eq!(
        a.final_eval_loss.to_bits(),
        b.final_eval_loss.to_bits(),
        "{ctx}: final_eval_loss"
    );
    assert_eq!(
        a.cost.total_usd().to_bits(),
        b.cost.total_usd().to_bits(),
        "{ctx}: total cost"
    );
}

/// Kill the run at every round boundary in turn and resume it; every
/// resumed run must be indistinguishable from the uninterrupted one.
fn crash_resume_matches(
    tag: &str,
    cluster: fn() -> ClusterSpec,
    base_faults: &str,
    tweak: fn(&mut ExperimentConfig),
) {
    let backend = MockRuntime::new(0.4);
    let mut cfg = base_cfg(base_faults);
    tweak(&mut cfg);
    // uninterrupted baseline, no WAL attached — also proves attaching a
    // WAL never perturbs a run
    let baseline = Coordinator::new(cfg.clone(), cluster(), &backend, init(), 4, 16)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(baseline.rounds_run, ROUNDS, "{tag}: baseline ran fully");

    for crash_at in 1..ROUNDS {
        let dir = std::env::temp_dir()
            .join(format!("crossfed-walres-{tag}-{crash_at}"));
        std::fs::remove_dir_all(&dir).ok();
        let mut c = cfg.clone();
        c.faults = FaultPlan::parse(&format!(
            "{base_faults};coordinator-crash:at={crash_at}"
        ))
        .unwrap();
        c.wal_dir = Some(dir.to_string_lossy().into_owned());

        let mut coord =
            Coordinator::new(c.clone(), cluster(), &backend, init(), 4, 16)
                .unwrap();
        let err = coord.run().unwrap_err();
        let crash = err
            .downcast_ref::<CoordinatorCrashed>()
            .unwrap_or_else(|| {
                panic!("{tag}@{crash_at}: expected a crash, got {err:#}")
            });
        assert_eq!(crash.round, crash_at, "{tag}: crash round");
        drop(coord); // the coordinator "process" dies here

        let resumed =
            Coordinator::resume(c, cluster(), &backend, init(), 4, 16)
                .unwrap()
                .run()
                .unwrap();
        assert_identical(
            &baseline,
            &resumed,
            &format!("{tag} crash@{crash_at}"),
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn sync_star_kill_at_every_round() {
    crash_resume_matches(
        "sync",
        ClusterSpec::paper_default,
        "node-slowdown:node=1,at=2,factor=2;\
         link-degrade:src=0,dst=1,at=3,factor=1.5",
        |c| {
            c.partition =
                crossfed::partition::PartitionStrategy::parse("dynamic")
                    .unwrap();
        },
    );
}

#[test]
fn async_kill_at_every_pseudo_round() {
    crash_resume_matches(
        "async",
        ClusterSpec::paper_default,
        "node-slowdown:node=2,at=1,factor=3;\
         link-degrade:src=0,dst=2,at=3,factor=2",
        |c| {
            c.aggregation =
                crossfed::aggregation::AggregationKind::parse("async")
                    .unwrap();
        },
    );
}

#[test]
fn hier_kill_at_every_round_with_failover() {
    crash_resume_matches(
        "hier",
        || ClusterSpec::paper_default_scaled(2),
        "gateway-down:cloud=1,at=1;restore:cloud=1,at=3",
        |c| {
            c.hierarchical = true;
        },
    );
}

#[test]
fn hier_async_spot_kill_at_every_pseudo_round() {
    // the buffered asynchronous hierarchy under membership churn: the
    // WAL must capture gateway buffers, stalled stashes, both
    // gateway↔leader queues and the roster epoch so that a kill at any
    // pseudo-round resumes bit-identically — including the secure
    // re-keying over the survivor set and the spot billing
    crash_resume_matches(
        "hier-async-spot",
        || ClusterSpec::paper_default_scaled(2),
        "worker-leave:node=1,at=1;worker-join:node=1,at=3",
        |c| {
            c.hierarchical = true;
            c.aggregation =
                crossfed::aggregation::AggregationKind::parse("async")
                    .unwrap();
            c.secure_agg = true;
            c.spot = true;
        },
    );
}

/// A bad checksum on the *last* record is a torn tail: the WAL truncates
/// it on open and the run resumes from one round earlier — and still
/// ends bit-identical, because the re-run round is deterministic.
#[test]
fn corrupt_tail_resumes_from_previous_round() {
    let backend = MockRuntime::new(0.4);
    let base_faults = "node-slowdown:node=1,at=2,factor=2";
    let cfg = base_cfg(base_faults);
    let baseline =
        Coordinator::new(cfg.clone(), ClusterSpec::paper_default(), &backend, init(), 4, 16)
            .unwrap()
            .run()
            .unwrap();

    let dir = std::env::temp_dir().join("crossfed-walres-torn");
    std::fs::remove_dir_all(&dir).ok();
    let mut c = cfg.clone();
    c.faults =
        FaultPlan::parse(&format!("{base_faults};coordinator-crash:at=3"))
            .unwrap();
    c.wal_dir = Some(dir.to_string_lossy().into_owned());
    let mut coord =
        Coordinator::new(c.clone(), ClusterSpec::paper_default(), &backend, init(), 4, 16)
            .unwrap();
    coord.run().unwrap_err();
    drop(coord);

    // flip a byte inside the last record's payload
    let path = wal_path(std::path::Path::new(dir.to_str().unwrap()), &c.name);
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    // resume without the crash event (only 2 rounds are now on record,
    // so a crash at round 3 would legitimately fire again)
    let mut c2 = cfg.clone();
    c2.wal_dir = c.wal_dir.clone();
    let resumed = Coordinator::resume(
        c2,
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_identical(&baseline, &resumed, "torn tail");
    std::fs::remove_dir_all(&dir).ok();
}

/// Write a WAL under `dir` by running 2 rounds of the quick preset.
fn write_small_wal(dir: &std::path::Path) -> ExperimentConfig {
    let backend = MockRuntime::new(0.4);
    let mut c = base_cfg("node-slowdown:node=1,at=1,factor=2");
    c.rounds = 2;
    c.wal_dir = Some(dir.to_string_lossy().into_owned());
    Coordinator::new(c.clone(), ClusterSpec::paper_default(), &backend, init(), 4, 16)
        .unwrap()
        .run()
        .unwrap();
    c
}

#[test]
fn resume_rejects_wrong_experiment_seed_and_shape() {
    let backend = MockRuntime::new(0.4);
    let dir = std::env::temp_dir().join("crossfed-walres-taxonomy");
    std::fs::remove_dir_all(&dir).ok();
    let c = write_small_wal(&dir);

    // cross-experiment restore is refused by name...
    let mut other = c.clone();
    other.name = "other-experiment".to_string();
    let err = Coordinator::resume(
        other,
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap_err();
    assert!(err.to_string().contains("belongs to experiment"), "{err:#}");

    // ...by seed...
    let mut reseeded = c.clone();
    reseeded.seed ^= 1;
    let err = Coordinator::resume(
        reseeded,
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap_err();
    assert!(err.to_string().contains("seed"), "{err:#}");

    // ...and by model shape
    let err = Coordinator::resume(
        c.clone(),
        ClusterSpec::paper_default(),
        &backend,
        ParamSet { leaves: vec![vec![0.0; 8]] },
        4,
        16,
    )
    .unwrap_err();
    assert!(err.to_string().contains("model shape"), "{err:#}");

    // a healthy resume of a *finished* run is still well-formed: all
    // rounds are on record, so run() has nothing left to do
    let again = Coordinator::resume(
        c,
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(again.rounds_run, 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_from_header_only_wal_errors() {
    let backend = MockRuntime::new(0.4);
    let dir = std::env::temp_dir().join("crossfed-walres-empty");
    std::fs::remove_dir_all(&dir).ok();
    let mut c = base_cfg("node-slowdown:node=1,at=1,factor=2");
    c.wal_dir = Some(dir.to_string_lossy().into_owned());
    // a crash before the first round boundary leaves a header-only WAL
    let header = WalHeader {
        experiment: c.name.clone(),
        seed: c.seed,
        n_workers: 3,
        leaf_sizes: vec![48, 16],
    };
    let path = wal_path(std::path::Path::new(dir.to_str().unwrap()), &c.name);
    WalFile::create(&path, &header).unwrap();
    let err = Coordinator::resume(
        c,
        ClusterSpec::paper_default(),
        &backend,
        init(),
        4,
        16,
    )
    .unwrap_err();
    assert!(err.to_string().contains("nothing to resume"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

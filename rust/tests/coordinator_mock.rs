//! Coordinator integration over the MockRuntime (no artifacts needed):
//! end-to-end federated convergence for every aggregation algorithm,
//! plus byte/time accounting invariants.

use crossfed::cluster::ClusterSpec;
use crossfed::config::{preset, ExperimentConfig};
use crossfed::coordinator::Coordinator;
use crossfed::data::CorpusConfig;
use crossfed::model::ParamSet;
use crossfed::runtime::MockRuntime;

fn quick_cfg(name: &str) -> ExperimentConfig {
    let mut c = preset("quick").unwrap();
    c.name = name.into();
    c.rounds = 12;
    c.eval_every = 3;
    c.local_steps = 3;
    c.local_lr = 4.0; // mock quadratic: grads are (p-t)/n, need big lr
    c.server_lr = 4.0;
    c.corpus = CorpusConfig { n_docs: 60, doc_sentences: 3, n_topics: 6, seed: 3 };
    c
}

fn init_params() -> ParamSet {
    ParamSet { leaves: vec![vec![2.0; 64], vec![-1.0; 32]] }
}

fn run(mut cfg: ExperimentConfig, agg: &str) -> crossfed::metrics::RunResult {
    cfg.aggregation = crossfed::aggregation::AggregationKind::parse(agg).unwrap();
    if agg == "gradient" {
        cfg.server_opt = crossfed::optimizer::OptimizerKind::Sgd;
    }
    let backend = MockRuntime::new(0.4);
    let cluster = ClusterSpec::paper_default();
    let mut coord =
        Coordinator::new(cfg, cluster, &backend, init_params(), 4, 16).unwrap();
    coord.run().unwrap()
}

#[test]
fn all_aggregators_converge_on_mock() {
    for agg in ["fedavg", "dynamic", "gradient", "async"] {
        let r = run(quick_cfg(agg), agg);
        assert!(r.rounds_run > 0, "{agg}");
        let first_train = r.history[0].train_loss;
        assert!(
            r.final_eval_loss < first_train * 0.5,
            "{agg}: {} -> {}",
            first_train,
            r.final_eval_loss
        );
        assert!(r.final_eval_acc > 0.0 && r.final_eval_acc <= 1.0);
        assert!(r.wire_bytes > 0);
        assert!(r.sim_secs > 0.0);
    }
}

#[test]
fn history_is_monotone_in_time_and_bytes() {
    let r = run(quick_cfg("mono"), "fedavg");
    for w in r.history.windows(2) {
        assert!(w[1].sim_secs >= w[0].sim_secs);
        assert!(w[1].wire_bytes >= w[0].wire_bytes);
        assert_eq!(w[1].round, w[0].round + 1);
    }
}

#[test]
fn deterministic_given_seed() {
    let a = run(quick_cfg("det"), "fedavg");
    let b = run(quick_cfg("det"), "fedavg");
    assert_eq!(a.wire_bytes, b.wire_bytes);
    assert_eq!(a.final_eval_loss, b.final_eval_loss);
    assert_eq!(a.history.len(), b.history.len());
    let mut c = quick_cfg("det2");
    c.seed = 777;
    c.aggregation = crossfed::aggregation::AggregationKind::FedAvg;
    let backend = MockRuntime::new(0.4);
    let mut coord = Coordinator::new(
        c, ClusterSpec::paper_default(), &backend, init_params(), 4, 16,
    )
    .unwrap();
    let d = coord.run().unwrap();
    assert_ne!(a.final_eval_loss, d.final_eval_loss);
}

#[test]
fn compression_reduces_wire_bytes() {
    let mut dense = quick_cfg("dense");
    dense.compression = crossfed::compress::Compression::None;
    let mut sparse = quick_cfg("sparse");
    sparse.compression = crossfed::compress::Compression::TopK { ratio: 0.05 };
    sparse.error_feedback = true;
    let rd = run(dense, "fedavg");
    let rs = run(sparse, "fedavg");
    assert!(
        rs.wire_bytes < rd.wire_bytes,
        "sparse {} !< dense {}",
        rs.wire_bytes,
        rd.wire_bytes
    );
    // and still converges thanks to error feedback
    assert!(rs.final_eval_loss < rs.history[0].train_loss * 0.6);
}

#[test]
fn encryption_costs_bytes_but_not_accuracy() {
    let mut enc = quick_cfg("enc");
    enc.encrypt = true;
    let mut plain = quick_cfg("plain");
    plain.encrypt = false;
    let re = run(enc, "fedavg");
    let rp = run(plain, "fedavg");
    assert!(re.wire_bytes > rp.wire_bytes);
    assert!((re.final_eval_loss - rp.final_eval_loss).abs() < 0.3);
}

#[test]
fn dp_noise_hurts_but_bounded() {
    let mut dp = quick_cfg("dp");
    dp.dp = crossfed::privacy::DpConfig {
        clip_norm: 5.0,
        noise_multiplier: 0.05,
        delta: 1e-5,
    };
    let r = run(dp, "fedavg");
    assert!(r.history.last().unwrap().epsilon > 0.0);
    // still converges with mild noise
    assert!(r.final_eval_loss < r.history[0].train_loss);
}

#[test]
fn secure_agg_matches_plain_fedavg_closely() {
    let mut sa = quick_cfg("sa");
    sa.secure_agg = true;
    let plain = quick_cfg("plain-ref");
    let r1 = run(sa, "fedavg");
    let r2 = run(plain, "fedavg");
    // masking cancels in the sum; training should track closely
    assert!(
        (r1.final_eval_loss - r2.final_eval_loss).abs() < 0.25,
        "{} vs {}",
        r1.final_eval_loss,
        r2.final_eval_loss
    );
}

#[test]
fn async_advances_time_without_global_barrier() {
    let r = run(quick_cfg("async"), "async");
    assert!(r.rounds_run > 0);
    assert!(r.sim_secs > 0.0);
    // async time must be below a sync barrier schedule of the same rounds:
    // compare against fedavg (same compute, barrier per round)
    let rf = run(quick_cfg("fedavg-time"), "fedavg");
    assert!(
        r.sim_secs < rf.sim_secs * 1.2,
        "async {} vs sync {}",
        r.sim_secs,
        rf.sim_secs
    );
}

#[test]
fn target_loss_stops_early() {
    let mut c = quick_cfg("early");
    c.rounds = 50;
    c.eval_every = 1;
    c.target_loss = Some(1.0);
    let r = run(c, "fedavg");
    assert!(r.reached_target);
    assert!(r.rounds_run < 50);
}

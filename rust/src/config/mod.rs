//! Typed experiment configuration + JSON loading + paper presets.

mod presets;

pub use presets::{preset, preset_names};

use anyhow::{bail, Context, Result};

use crate::aggregation::AggregationKind;
use crate::compress::{Compression, LosslessStage};
use crate::cost::{Placement, PriceBook};
use crate::data::CorpusConfig;
use crate::netsim::{FaultPlan, Protocol};
use crate::optimizer::OptimizerKind;
use crate::partition::PartitionStrategy;
use crate::privacy::DpConfig;
use crate::util::json::Json;

/// Full experiment configuration. Everything a run needs, in one place;
/// JSON-loadable so experiments are reproducible artifacts.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// maximum aggregation rounds
    pub rounds: usize,
    /// stop early once eval loss <= target (Table 2's "training time to
    /// convergence" semantics)
    pub target_loss: Option<f64>,
    /// stop early once the cumulative dollar bill crosses this budget
    /// (budget-constrained training; mirrors `target_loss`)
    pub target_cost: Option<f64>,
    pub eval_every: usize,
    /// eval batches per evaluation
    pub eval_batches: usize,

    pub aggregation: AggregationKind,
    /// two-level aggregation: reduce inside each cloud at its gateway,
    /// exchange one partial aggregate per cloud over the WAN. With a
    /// synchronous algorithm this is a barrier reduce; combined with
    /// `aggregation = async` it becomes the buffered (FedBuff-style)
    /// hierarchy: gateways mix member updates as they arrive and the
    /// leader consumes cloud-level buffered aggregates.
    pub hierarchical: bool,
    pub partition: PartitionStrategy,
    pub protocol: Protocol,
    pub streams: usize,
    pub compression: Compression,
    /// lossless byte stage applied after the lossy codec on every
    /// transport frame (exact; does not change what the receiver
    /// decodes, only the bytes priced on the wire)
    pub lossless: LosslessStage,
    pub error_feedback: bool,
    pub encrypt: bool,
    pub secure_agg: bool,
    pub dp: DpConfig,
    /// bill compute at the price book's preemptible (spot) rates instead
    /// of on-demand (see [`crate::cost::PriceBook::spot_rate`]); pair
    /// with a preemption fault plan
    /// ([`crate::netsim::FaultPlan::spot_preemptions`]) for the
    /// spot-market scenario. JSON `"spot"`; CLI `--spot`.
    pub spot: bool,

    /// local SGD steps per round (the granularity knob)
    pub local_steps: usize,
    /// scale each platform's local steps by its shard share (one "local
    /// epoch over the partition" semantics — what makes capacity-
    /// weighted partitioning balance round times). Off by default so
    /// the aggregation comparisons run at exactly equal step counts.
    pub proportional_local_work: bool,
    pub adaptive_granularity: bool,
    pub local_lr: f32,
    pub server_opt: OptimizerKind,
    pub server_lr: f32,

    pub corpus: CorpusConfig,
    /// simulated seconds per local step on a speed-1.0 platform (scales
    /// the compute half of Table 2's training-time column)
    pub base_step_secs: f64,
    /// deterministic fault schedule replayed at round boundaries (JSON:
    /// `"faults": ["gateway-down:cloud=1,at=round3", ...]`; CLI:
    /// `--fault`; see [`crate::netsim::faults`])
    pub faults: FaultPlan,
    /// which cloud hosts the aggregation leader: `fixed:N` pins it
    /// (seed behaviour: `fixed:0`), `auto` takes the price-book argmin
    /// (JSON `"placement"`; CLI `--placement`; see
    /// [`crate::cost::placement`])
    pub placement: Placement,
    /// prices for the run's dollar ledger and the auto placement (JSON
    /// `"price_book"` object; CLI `--price-book FILE`; see
    /// [`crate::cost::PriceBook`])
    pub price_book: PriceBook,
    /// directory for the write-ahead log of round-boundary state (JSON
    /// `"wal_dir"`; CLI `--wal DIR`). When set, every round is durably
    /// logged before it is acknowledged and the run can be resumed
    /// bit-identically after a crash (see [`crate::wal`]); required by
    /// the `coordinator-crash` fault.
    pub wal_dir: Option<String>,
    /// simulate independent clouds' intra-round legs (training uplinks,
    /// gateway broadcasts) on separate threads — the planet-scale path.
    /// Requires `hierarchical`; per-cloud WAN noise comes from dedicated
    /// RNG streams, so results are deterministic and identical at any
    /// thread count (but not bit-identical to the serial event-engine
    /// schedule, which interleaves one shared noise stream). JSON
    /// `"par_rounds"`; CLI `--par-rounds`.
    pub par_rounds: bool,
    /// keep every Nth round's [`crate::metrics::RoundRecord`] in the
    /// in-memory history (1 = keep all, the default). Planet-scale runs
    /// set N high and stream rounds to `history_csv` instead of holding
    /// O(rounds × clouds) in memory. JSON `"history_every"`; CLI
    /// `--history-every N`.
    pub history_every: usize,
    /// stream every round's curve-CSV row to this file as it completes
    /// (the streaming metrics sink; rows match
    /// [`crate::metrics::RunResult::curve_csv`] exactly). JSON
    /// `"history_csv"`; CLI `--history-csv FILE`.
    pub history_csv: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 42,
            rounds: 100,
            target_loss: None,
            target_cost: None,
            eval_every: 5,
            eval_batches: 4,
            aggregation: AggregationKind::FedAvg,
            hierarchical: false,
            partition: PartitionStrategy::DirichletSkew { alpha: 0.3 },
            protocol: Protocol::Grpc,
            streams: 16,
            compression: Compression::None,
            lossless: LosslessStage::None,
            error_feedback: false,
            encrypt: true,
            secure_agg: false,
            dp: DpConfig::disabled(),
            spot: false,
            local_steps: 4,
            proportional_local_work: false,
            adaptive_granularity: false,
            local_lr: 0.3,
            server_opt: OptimizerKind::Momentum { beta: 0.9 },
            server_lr: 0.3,
            corpus: CorpusConfig::default(),
            base_step_secs: 18.0,
            faults: FaultPlan::default(),
            placement: Placement::Fixed(0),
            price_book: PriceBook::paper_default(),
            wal_dir: None,
            par_rounds: false,
            history_every: 1,
            history_csv: None,
        }
    }
}

impl ExperimentConfig {
    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 {
            bail!("rounds must be >= 1");
        }
        if self.local_steps == 0 {
            bail!("local_steps must be >= 1");
        }
        if !(self.local_lr > 0.0) || !(self.server_lr > 0.0) {
            bail!("learning rates must be positive");
        }
        if self.streams == 0 {
            bail!("streams must be >= 1");
        }
        if self.secure_agg {
            // masked sums are only compatible with fixed pre-scaling:
            // FedAvg / gradient mean, not loss-dependent dynamic weights
            if matches!(self.aggregation, AggregationKind::DynamicWeighted { .. })
            {
                bail!(
                    "secure aggregation hides individual updates, so \
                     loss-weighted (dynamic) aggregation cannot be applied \
                     server-side; use fedavg or gradient"
                );
            }
            if matches!(self.aggregation, AggregationKind::Async { .. })
                && !self.hierarchical
            {
                bail!(
                    "flat async applies each worker's update alone, so \
                     pairwise masks never cancel; secure aggregation with \
                     async needs the buffered hierarchy (set hierarchical) \
                     where gateways sum a full cloud buffer per cycle"
                );
            }
            if !matches!(self.compression, Compression::None) {
                bail!(
                    "secure aggregation masks updates with dense noise; \
                     compression must be 'none'"
                );
            }
        }
        if self.dp.enabled() && self.dp.clip_norm <= 0.0 {
            bail!("DP requires clip_norm > 0");
        }
        if self.history_every == 0 {
            bail!("history_every must be >= 1");
        }
        if self.par_rounds {
            if !self.hierarchical {
                bail!(
                    "par_rounds parallelizes independent clouds' intra-round \
                     legs, which only exist under hierarchical aggregation \
                     — set hierarchical too"
                );
            }
            if self.secure_agg {
                bail!(
                    "par_rounds does not yet support secure aggregation's \
                     pairwise masking order; drop secure_agg or par_rounds"
                );
            }
            if matches!(self.aggregation, AggregationKind::Async { .. }) {
                bail!(
                    "par_rounds parallelizes a synchronous barrier round; \
                     async/buffered schedules run on the serial event \
                     engine — drop par_rounds or use a sync aggregation"
                );
            }
            if !self.faults.events().is_empty() {
                bail!(
                    "par_rounds does not yet support mid-round fault \
                     injection/failover; drop the fault plan or par_rounds"
                );
            }
        }
        if let Some(t) = self.target_loss {
            if !(t > 0.0) {
                bail!("target_loss must be positive");
            }
        }
        if let Some(t) = self.target_cost {
            if !(t > 0.0) {
                bail!("target_cost must be positive");
            }
        }
        self.price_book.validate().context("price_book")?;
        for ev in self.faults.events() {
            ev.validate()?;
            if ev.at() >= self.rounds {
                bail!(
                    "fault {ev} fires at round {} but the run has only {} \
                     rounds",
                    ev.at(),
                    self.rounds
                );
            }
            if matches!(ev, crate::netsim::FaultEvent::CoordinatorCrash { .. })
                && self.wal_dir.is_none()
            {
                bail!(
                    "fault {ev} kills the coordinator, but no WAL is \
                     configured to resume from — set wal_dir (CLI --wal DIR)"
                );
            }
        }
        Ok(())
    }

    /// Parse from JSON (fields default to `ExperimentConfig::default()`).
    pub fn from_json(text: &str) -> Result<ExperimentConfig> {
        let v = Json::parse(text).context("config JSON")?;
        let mut c = ExperimentConfig::default();
        if let Some(s) = v.get("name").and_then(Json::as_str) {
            c.name = s.to_string();
        }
        c.seed = v.opt_usize("seed", c.seed as usize) as u64;
        c.rounds = v.opt_usize("rounds", c.rounds);
        if let Some(t) = v.get("target_loss").and_then(Json::as_f64) {
            c.target_loss = Some(t);
        }
        if let Some(t) = v.get("target_cost").and_then(Json::as_f64) {
            c.target_cost = Some(t);
        }
        if let Some(d) = v.get("wal_dir").and_then(Json::as_str) {
            c.wal_dir = Some(d.to_string());
        }
        c.par_rounds = v.opt_bool("par_rounds", c.par_rounds);
        c.history_every = v.opt_usize("history_every", c.history_every);
        if let Some(p) = v.get("history_csv").and_then(Json::as_str) {
            c.history_csv = Some(p.to_string());
        }
        c.eval_every = v.opt_usize("eval_every", c.eval_every);
        c.eval_batches = v.opt_usize("eval_batches", c.eval_batches);
        if let Some(s) = v.get("aggregation").and_then(Json::as_str) {
            c.aggregation = AggregationKind::parse(s)
                .with_context(|| format!("unknown aggregation {s:?}"))?;
        }
        c.hierarchical = v.opt_bool("hierarchical", c.hierarchical);
        if let Some(s) = v.get("partition").and_then(Json::as_str) {
            c.partition = PartitionStrategy::parse(s)
                .with_context(|| format!("unknown partition {s:?}"))?;
        }
        if let Some(s) = v.get("protocol").and_then(Json::as_str) {
            c.protocol = Protocol::parse(s)
                .with_context(|| format!("unknown protocol {s:?}"))?;
        }
        c.streams = v.opt_usize("streams", c.streams);
        if let Some(s) = v.get("compression").and_then(Json::as_str) {
            c.compression = Compression::parse(s)
                .with_context(|| format!("unknown compression {s:?}"))?;
        }
        if let Some(s) = v.get("lossless").and_then(Json::as_str) {
            c.lossless = LosslessStage::parse(s)
                .with_context(|| format!("unknown lossless stage {s:?}"))?;
        }
        c.error_feedback = v.opt_bool("error_feedback", c.error_feedback);
        c.encrypt = v.opt_bool("encrypt", c.encrypt);
        c.secure_agg = v.opt_bool("secure_agg", c.secure_agg);
        c.spot = v.opt_bool("spot", c.spot);
        if let Some(dp) = v.get("dp") {
            c.dp = DpConfig {
                clip_norm: dp.opt_f64("clip_norm", 1.0),
                noise_multiplier: dp.opt_f64("noise_multiplier", 0.0),
                delta: dp.opt_f64("delta", 1e-5),
            };
        }
        c.local_steps = v.opt_usize("local_steps", c.local_steps);
        c.proportional_local_work =
            v.opt_bool("proportional_local_work", c.proportional_local_work);
        c.adaptive_granularity =
            v.opt_bool("adaptive_granularity", c.adaptive_granularity);
        c.local_lr = v.opt_f64("local_lr", c.local_lr as f64) as f32;
        if let Some(s) = v.get("server_opt").and_then(Json::as_str) {
            c.server_opt = OptimizerKind::parse(s)
                .with_context(|| format!("unknown optimizer {s:?}"))?;
        }
        c.server_lr = v.opt_f64("server_lr", c.server_lr as f64) as f32;
        if let Some(co) = v.get("corpus") {
            c.corpus = CorpusConfig {
                n_docs: co.opt_usize("n_docs", c.corpus.n_docs),
                doc_sentences: co.opt_usize("doc_sentences", c.corpus.doc_sentences),
                n_topics: co.opt_usize("n_topics", c.corpus.n_topics),
                seed: co.opt_usize("seed", c.corpus.seed as usize) as u64,
            };
        }
        c.base_step_secs = v.opt_f64("base_step_secs", c.base_step_secs);
        if let Some(s) = v.get("placement").and_then(Json::as_str) {
            c.placement = Placement::parse(s)?;
        }
        if let Some(pb) = v.get("price_book") {
            c.price_book = PriceBook::from_json(pb).context("price_book")?;
        }
        if let Some(f) = v.get("faults") {
            let fs = f
                .as_arr()
                .context("\"faults\" must be an array of spec strings")?;
            let mut events = Vec::with_capacity(fs.len());
            for f in fs {
                let spec = f
                    .as_str()
                    .context("faults entries must be spec strings")?;
                events.extend(FaultPlan::parse(spec)?.events().to_vec());
            }
            c.faults = FaultPlan::new(events);
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize to JSON (the run header recorded with every result).
    pub fn to_json(&self) -> Json {
        let dp = Json::obj(vec![
            ("clip_norm", Json::num(self.dp.clip_norm)),
            ("noise_multiplier", Json::num(self.dp.noise_multiplier)),
            ("delta", Json::num(self.dp.delta)),
        ]);
        let compression = match self.compression {
            Compression::TopK { ratio } => format!("topk:{ratio}"),
            Compression::RandK { ratio } => format!("randk:{ratio}"),
            other => other.name().to_string(),
        };
        let partition = match self.partition {
            PartitionStrategy::DirichletSkew { alpha } => {
                format!("dirichlet:{alpha}")
            }
            other => other.name().to_string(),
        };
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            (
                "target_loss",
                self.target_loss.map_or(Json::Null, Json::num),
            ),
            (
                "target_cost",
                self.target_cost.map_or(Json::Null, Json::num),
            ),
            (
                "wal_dir",
                self.wal_dir
                    .as_ref()
                    .map_or(Json::Null, |d| Json::str(d.clone())),
            ),
            ("par_rounds", Json::Bool(self.par_rounds)),
            ("history_every", Json::num(self.history_every as f64)),
            (
                "history_csv",
                self.history_csv
                    .as_ref()
                    .map_or(Json::Null, |p| Json::str(p.clone())),
            ),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("aggregation", Json::str(self.aggregation.name())),
            ("hierarchical", Json::Bool(self.hierarchical)),
            ("partition", Json::str(partition)),
            ("protocol", Json::str(self.protocol.name())),
            ("streams", Json::num(self.streams as f64)),
            ("compression", Json::str(compression)),
            ("lossless", Json::str(self.lossless.name())),
            ("error_feedback", Json::Bool(self.error_feedback)),
            ("encrypt", Json::Bool(self.encrypt)),
            ("secure_agg", Json::Bool(self.secure_agg)),
            ("spot", Json::Bool(self.spot)),
            ("dp", dp),
            ("local_steps", Json::num(self.local_steps as f64)),
            (
                "proportional_local_work",
                Json::Bool(self.proportional_local_work),
            ),
            ("adaptive_granularity", Json::Bool(self.adaptive_granularity)),
            ("local_lr", Json::num(self.local_lr as f64)),
            ("server_opt", Json::str(self.server_opt.name())),
            ("server_lr", Json::num(self.server_lr as f64)),
            ("base_step_secs", Json::num(self.base_step_secs)),
            ("placement", Json::str(self.placement.name())),
            ("price_book", self.price_book.to_json()),
            (
                "faults",
                Json::arr(
                    self.faults
                        .events()
                        .iter()
                        .map(|e| Json::str(e.to_string())),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let text = r#"{
            "name": "t2", "rounds": 50, "aggregation": "gradient",
            "partition": "dirichlet:0.3", "protocol": "quic",
            "compression": "topk:0.05", "lossless": "auto",
            "error_feedback": true,
            "local_steps": 8, "target_loss": 2.5,
            "dp": {"clip_norm": 1.0, "noise_multiplier": 0.5}
        }"#;
        let c = ExperimentConfig::from_json(text).unwrap();
        assert_eq!(c.name, "t2");
        assert_eq!(c.rounds, 50);
        assert_eq!(c.aggregation, AggregationKind::GradientAgg);
        assert_eq!(c.protocol, Protocol::Quic);
        assert!(matches!(c.compression, Compression::TopK { ratio } if (ratio - 0.05).abs() < 1e-9));
        assert_eq!(c.lossless, LosslessStage::Auto);
        assert!(c.error_feedback);
        assert_eq!(c.target_loss, Some(2.5));
        assert!(c.dp.enabled());
        // serialize contains the same fields
        let j = c.to_json().to_string();
        assert!(j.contains("\"aggregation\":\"gradient\""));
        assert!(j.contains("\"protocol\":\"quic\""));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_json(r#"{"rounds": 0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"aggregation": "x"}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"protocol": "smtp"}"#).is_err());
        assert!(ExperimentConfig::from_json("{").is_err());
    }

    #[test]
    fn hierarchical_constraints() {
        // hierarchical + async is the buffered (FedBuff-style) schedule
        let c = ExperimentConfig::from_json(
            r#"{"hierarchical": true, "aggregation": "async"}"#,
        )
        .unwrap();
        assert!(c.hierarchical);
        assert!(matches!(c.aggregation, AggregationKind::Async { .. }));
        let c = ExperimentConfig::from_json(
            r#"{"hierarchical": true, "aggregation": "dynamic"}"#,
        )
        .unwrap();
        assert!(c.hierarchical);
        assert!(c.to_json().to_string().contains("\"hierarchical\":true"));
    }

    #[test]
    fn spot_round_trips() {
        let c = ExperimentConfig::from_json(r#"{"spot": true}"#).unwrap();
        assert!(c.spot);
        let j = c.to_json().to_string();
        assert!(j.contains("\"spot\":true"), "{j}");
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert!(back.spot);
        assert!(!ExperimentConfig::default().spot);
    }

    #[test]
    fn faults_json_round_trip() {
        let c = ExperimentConfig::from_json(
            r#"{"rounds": 10, "faults": [
                "gateway-down:cloud=1,at=round3",
                "link-degrade:src=0,dst=2,at=1,factor=0.5; node-slowdown:node=2,at=4,factor=2"
            ]}"#,
        )
        .unwrap();
        assert_eq!(c.faults.len(), 3);
        assert_eq!(
            c.faults.events()[2],
            crate::netsim::FaultEvent::NodeSlowdown { node: 2, at: 4, factor: 2.0 }
        );
        let j = c.to_json().to_string();
        assert!(j.contains("gateway-down:cloud=1,at=3"), "{j}");
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        // a fault beyond the horizon is rejected
        assert!(ExperimentConfig::from_json(
            r#"{"rounds": 2, "faults": ["gateway-down:cloud=0,at=5"]}"#
        )
        .is_err());
        // malformed specs are rejected
        assert!(ExperimentConfig::from_json(
            r#"{"rounds": 9, "faults": ["meteor:at=1"]}"#
        )
        .is_err());
        // a non-array value is a hard error, not a silently-empty plan
        assert!(ExperimentConfig::from_json(
            r#"{"rounds": 9, "faults": "gateway-down:cloud=1,at=3"}"#
        )
        .is_err());
    }

    #[test]
    fn placement_and_price_book_round_trip() {
        let c = ExperimentConfig::from_json(
            r#"{"placement": "auto",
                "price_book": {"name": "pb",
                               "egress": {"inter-region": [{"usd_per_gb": 0.2}]}}}"#,
        )
        .unwrap();
        assert_eq!(c.placement, Placement::Auto);
        assert_eq!(c.price_book.name, "pb");
        let j = c.to_json().to_string();
        assert!(j.contains("\"placement\":\"auto\""), "{j}");
        assert!(j.contains("\"price_book\""), "{j}");
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.placement, c.placement);
        assert_eq!(back.price_book, c.price_book);
        // defaults: fixed:0 + the paper book
        let d = ExperimentConfig::default();
        assert_eq!(d.placement, Placement::Fixed(0));
        assert_eq!(d.price_book, PriceBook::paper_default());
        // fixed:N round-trips; bad values are rejected
        let f = ExperimentConfig::from_json(r#"{"placement": "fixed:2"}"#).unwrap();
        assert_eq!(f.placement, Placement::Fixed(2));
        assert!(ExperimentConfig::from_json(r#"{"placement": "west"}"#).is_err());
        assert!(ExperimentConfig::from_json(
            r#"{"price_book": {"egress": {"intra-az": []}}}"#
        )
        .is_err());
    }

    #[test]
    fn target_cost_and_wal_dir_round_trip() {
        let c = ExperimentConfig::from_json(
            r#"{"target_cost": 125.5, "wal_dir": "/tmp/wals"}"#,
        )
        .unwrap();
        assert_eq!(c.target_cost, Some(125.5));
        assert_eq!(c.wal_dir.as_deref(), Some("/tmp/wals"));
        let j = c.to_json().to_string();
        assert!(j.contains("\"target_cost\":125.5"), "{j}");
        assert!(j.contains("\"wal_dir\":\"/tmp/wals\""), "{j}");
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.target_cost, c.target_cost);
        assert_eq!(back.wal_dir, c.wal_dir);
        // defaults: both off, serialized as null
        let d = ExperimentConfig::default();
        assert_eq!(d.target_cost, None);
        assert_eq!(d.wal_dir, None);
        assert!(ExperimentConfig::from_json(r#"{"target_cost": 0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"target_cost": -3}"#).is_err());
    }

    #[test]
    fn coordinator_crash_requires_wal() {
        // a crash fault without a WAL would be unrecoverable — reject it
        let bad = ExperimentConfig::from_json(
            r#"{"rounds": 10, "faults": ["coordinator-crash:at=3"]}"#,
        );
        assert!(bad.unwrap_err().to_string().contains("wal"), "needs wal_dir");
        let ok = ExperimentConfig::from_json(
            r#"{"rounds": 10, "wal_dir": "/tmp/w",
                "faults": ["coordinator-crash:at=3"]}"#,
        )
        .unwrap();
        assert_eq!(ok.faults.len(), 1);
        // crash at round 0 is structurally invalid (empty WAL)
        assert!(ExperimentConfig::from_json(
            r#"{"rounds": 10, "wal_dir": "/tmp/w",
                "faults": ["coordinator-crash:at=0"]}"#
        )
        .is_err());
    }

    #[test]
    fn par_rounds_and_history_knobs_round_trip() {
        let c = ExperimentConfig::from_json(
            r#"{"hierarchical": true, "par_rounds": true,
                "history_every": 10, "history_csv": "/tmp/curve.csv"}"#,
        )
        .unwrap();
        assert!(c.par_rounds);
        assert_eq!(c.history_every, 10);
        assert_eq!(c.history_csv.as_deref(), Some("/tmp/curve.csv"));
        let j = c.to_json().to_string();
        assert!(j.contains("\"par_rounds\":true"), "{j}");
        assert!(j.contains("\"history_every\":10"), "{j}");
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.par_rounds, c.par_rounds);
        assert_eq!(back.history_every, c.history_every);
        assert_eq!(back.history_csv, c.history_csv);
        // par_rounds requires the hierarchical topology
        assert!(ExperimentConfig::from_json(r#"{"par_rounds": true}"#).is_err());
        // ...and rejects the not-yet-supported combinations
        assert!(ExperimentConfig::from_json(
            r#"{"hierarchical": true, "par_rounds": true, "secure_agg": true}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            r#"{"hierarchical": true, "par_rounds": true, "rounds": 10,
                "faults": ["gateway-down:cloud=1,at=round3"]}"#
        )
        .is_err());
        // async/buffered schedules run serially — par_rounds is rejected
        let e = ExperimentConfig::from_json(
            r#"{"hierarchical": true, "par_rounds": true,
                "aggregation": "async"}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("par_rounds"), "{e}");
        assert!(ExperimentConfig::from_json(r#"{"history_every": 0}"#).is_err());
    }

    #[test]
    fn secure_agg_constraints() {
        let c = ExperimentConfig::from_json(
            r#"{"secure_agg": true, "aggregation": "dynamic"}"#,
        );
        assert!(c.is_err());
        let c = ExperimentConfig::from_json(
            r#"{"secure_agg": true, "compression": "topk:0.1"}"#,
        );
        assert!(c.is_err());
        let c = ExperimentConfig::from_json(
            r#"{"secure_agg": true, "aggregation": "fedavg"}"#,
        );
        assert!(c.is_ok());
        // flat async never forms a maskable sum...
        assert!(ExperimentConfig::from_json(
            r#"{"secure_agg": true, "aggregation": "async"}"#
        )
        .is_err());
        // ...but the buffered hierarchy sums full cloud buffers
        assert!(ExperimentConfig::from_json(
            r#"{"secure_agg": true, "aggregation": "async",
                "hierarchical": true}"#
        )
        .is_ok());
    }
}

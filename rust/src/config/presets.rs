//! Named experiment presets — one per paper table/figure (DESIGN.md
//! experiment index). Benches and the CLI resolve these by name so every
//! reported number has a reproducible config.

use crate::aggregation::AggregationKind;
use crate::compress::Compression;
use crate::config::ExperimentConfig;
use crate::data::CorpusConfig;
use crate::netsim::{FaultEvent, FaultPlan, Protocol};
use crate::optimizer::OptimizerKind;
use crate::partition::PartitionStrategy;
use crate::privacy::DpConfig;

/// All preset names (CLI help / sweep enumeration).
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "paper-fedavg",
        "paper-dynamic",
        "paper-gradient",
        "paper-async",
        "paper-hier",
        "paper-hier-faulty",
        "paper-hier-cost",
        "paper-hier-async-spot",
        "paper-serve",
        "hier-gradient",
        "fig-partition-fixed",
        "fig-partition-dynamic",
        "fig-protocol-grpc",
        "fig-protocol-quic",
        "fig-protocol-tcp",
        "privacy-off",
        "privacy-dp",
        "privacy-secureagg",
        "quick",
    ]
}

/// Resolve a preset by name.
pub fn preset(name: &str) -> Option<ExperimentConfig> {
    // The paper's Table 1 setup: 3 platforms, 100 rounds, non-IID shards.
    // `target_loss` gives Table 2 its "time to convergence" semantics:
    // algorithms that converge in fewer rounds transfer fewer bytes.
    let paper_base = ExperimentConfig {
        name: name.to_string(),
        seed: 42,
        rounds: 100,
        target_loss: Some(2.25),
        eval_every: 5,
        eval_batches: 4,
        partition: PartitionStrategy::DirichletSkew { alpha: 0.3 },
        protocol: Protocol::Grpc,
        streams: 16,
        local_steps: 4,
        local_lr: 0.3,
        server_opt: OptimizerKind::Momentum { beta: 0.9 },
        server_lr: 0.3,
        corpus: CorpusConfig { n_docs: 360, doc_sentences: 10, n_topics: 6, seed: 1234 },
        // a "pre-trained large-scale LM" step on the paper's clouds is
        // tens of seconds; 63.5 s/step lands FedAvg's 100 rounds at the
        // paper's 12 h (calibration: EXPERIMENTS.md §Calibration)
        base_step_secs: 63.5,
        ..ExperimentConfig::default()
    };

    let cfg = match name {
        // ------------- Tables 2 & 3: the three aggregation algorithms
        "paper-fedavg" => ExperimentConfig {
            aggregation: AggregationKind::FedAvg,
            compression: Compression::None,
            ..paper_base
        },
        "paper-dynamic" => ExperimentConfig {
            aggregation: AggregationKind::DynamicWeighted { temperature: 1.0 },
            compression: Compression::None,
            ..paper_base
        },
        "paper-gradient" => ExperimentConfig {
            aggregation: AggregationKind::GradientAgg,
            // gradients sparsify well; top-k + error feedback is the
            // paper's "smaller data volume during aggregation" (0.6 keeps
            // the per-round byte ratio at the paper's ~0.8 incl. the
            // dense downlink broadcast)
            compression: Compression::TopK { ratio: 0.6 },
            error_feedback: true,
            server_opt: OptimizerKind::Momentum { beta: 0.9 },
            ..paper_base
        },
        "paper-async" => ExperimentConfig {
            aggregation: AggregationKind::Async { alpha: 0.6 },
            ..paper_base
        },

        // ------------- hierarchical two-level aggregation (run with a
        // scaled cluster, e.g. ClusterSpec::paper_default_scaled(16) or
        // the CLI's --nodes-per-cloud; with single-node clouds it
        // degenerates to the star)
        "paper-hier" => ExperimentConfig {
            aggregation: AggregationKind::FedAvg,
            hierarchical: true,
            compression: Compression::None,
            ..paper_base
        },
        // the robustness scenario: cloud 1's WAN gateway dies mid-run
        // (round 3) and one AZ node turns into a persistent straggler;
        // training must fail over to the standby gateway and finish.
        // Needs a standby, i.e. --nodes-per-cloud >= 2.
        "paper-hier-faulty" => ExperimentConfig {
            aggregation: AggregationKind::FedAvg,
            hierarchical: true,
            compression: Compression::None,
            faults: FaultPlan::new(vec![
                FaultEvent::GatewayDown { cloud: 1, at: 3 },
                FaultEvent::NodeSlowdown { node: 1, at: 5, factor: 2.0 },
            ]),
            ..paper_base
        },
        // the cost story: two-level reduce + cost-aware leader placement
        // against the paper-default price book — the preset behind the
        // Table-C dollar breakdown and `examples/cost_report.rs`.
        // Run with --nodes-per-cloud >= 4 so hierarchy has bytes to save.
        "paper-hier-cost" => ExperimentConfig {
            aggregation: AggregationKind::FedAvg,
            hierarchical: true,
            compression: Compression::None,
            placement: crate::cost::Placement::Auto,
            price_book: crate::cost::PriceBook::paper_default(),
            ..paper_base
        },
        // the spot-market scenario: buffered (FedBuff-style) hierarchy on
        // preemptible capacity billed at spot rates. Gateways mix member
        // updates as they arrive; the leader consumes cloud-level buffered
        // aggregates; secure aggregation re-keys over the survivor set on
        // every roster change. The embedded churn plan preempts the second
        // member of each paper cloud and brings two of them back, so it is
        // valid for any --nodes-per-cloud >= 2 (each cloud's first member
        // never leaves). `examples/spot_market.rs` swaps in a seeded
        // `FaultPlan::spot_preemptions` plan for the cost comparison.
        "paper-hier-async-spot" => ExperimentConfig {
            aggregation: AggregationKind::Async { alpha: 0.6 },
            hierarchical: true,
            secure_agg: true,
            encrypt: true,
            compression: Compression::None,
            spot: true,
            faults: FaultPlan::new(vec![
                FaultEvent::WorkerLeave { node: 1, at: 2 },
                FaultEvent::WorkerLeave { node: 3, at: 4 },
                FaultEvent::WorkerJoin { node: 1, at: 6 },
                FaultEvent::WorkerLeave { node: 5, at: 8 },
                FaultEvent::WorkerJoin { node: 3, at: 10 },
            ]),
            ..paper_base
        },
        // the serving scenario (`crossfed serve`): identity config the
        // serve subsystem derives its transport, seed and price book
        // from ([`crate::serve::ServeConfig::from_experiment`]). Trained
        // with the cost-aware hierarchy, deployed to every cloud.
        "paper-serve" => ExperimentConfig {
            aggregation: AggregationKind::FedAvg,
            hierarchical: true,
            compression: Compression::None,
            placement: crate::cost::Placement::Auto,
            price_book: crate::cost::PriceBook::paper_default(),
            target_loss: None,
            rounds: 20,
            ..paper_base
        },
        "hier-gradient" => ExperimentConfig {
            aggregation: AggregationKind::GradientAgg,
            hierarchical: true,
            compression: Compression::TopK { ratio: 0.6 },
            error_feedback: true,
            ..paper_base
        },

        // ------------- Figure-2 cycle ablation: fixed vs dynamic
        "fig-partition-fixed" => ExperimentConfig {
            partition: PartitionStrategy::Fixed,
            aggregation: AggregationKind::FedAvg,
            proportional_local_work: true,
            target_loss: None,
            rounds: 40,
            ..paper_base
        },
        "fig-partition-dynamic" => ExperimentConfig {
            partition: PartitionStrategy::Dynamic,
            aggregation: AggregationKind::FedAvg,
            proportional_local_work: true,
            adaptive_granularity: false,
            target_loss: None,
            rounds: 40,
            ..paper_base
        },

        // ------------- §3.2 protocol comparison
        "fig-protocol-grpc" => ExperimentConfig {
            protocol: Protocol::Grpc,
            target_loss: None,
            rounds: 30,
            ..paper_base
        },
        "fig-protocol-quic" => ExperimentConfig {
            protocol: Protocol::Quic,
            target_loss: None,
            rounds: 30,
            ..paper_base
        },
        "fig-protocol-tcp" => ExperimentConfig {
            protocol: Protocol::Tcp,
            streams: 1,
            target_loss: None,
            rounds: 30,
            ..paper_base
        },

        // ------------- privacy ablation
        "privacy-off" => ExperimentConfig {
            encrypt: false,
            target_loss: None,
            rounds: 30,
            ..paper_base
        },
        "privacy-dp" => ExperimentConfig {
            encrypt: true,
            dp: DpConfig { clip_norm: 1.0, noise_multiplier: 0.8, delta: 1e-5 },
            target_loss: None,
            rounds: 30,
            ..paper_base
        },
        "privacy-secureagg" => ExperimentConfig {
            encrypt: true,
            secure_agg: true,
            aggregation: AggregationKind::FedAvg,
            compression: Compression::None,
            target_loss: None,
            rounds: 30,
            ..paper_base
        },

        // ------------- fast smoke preset
        "quick" => ExperimentConfig {
            rounds: 5,
            target_loss: None,
            eval_every: 2,
            eval_batches: 2,
            corpus: CorpusConfig { n_docs: 60, doc_sentences: 4, n_topics: 6, seed: 1 },
            ..paper_base
        },
        _ => return None,
    };
    debug_assert!(cfg.validate().is_ok(), "preset {name} invalid");
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve_and_validate() {
        for name in preset_names() {
            let c = preset(name).unwrap_or_else(|| panic!("missing {name}"));
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(c.name, name);
        }
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(preset("nope").is_none());
    }

    #[test]
    fn paper_presets_share_the_table1_setup() {
        let a = preset("paper-fedavg").unwrap();
        let b = preset("paper-gradient").unwrap();
        assert_eq!(a.rounds, 100);
        assert_eq!(b.rounds, 100);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.corpus.n_docs, b.corpus.n_docs);
        // only the algorithm-specific knobs differ
        assert_ne!(a.aggregation, b.aggregation);
    }

    #[test]
    fn spot_preset_is_the_buffered_elastic_scenario() {
        let c = preset("paper-hier-async-spot").unwrap();
        assert!(c.hierarchical);
        assert!(matches!(c.aggregation, AggregationKind::Async { .. }));
        assert!(c.secure_agg);
        assert!(c.spot);
        // churn plan leaves then rejoins; every event inside the horizon
        assert!(!c.faults.events().is_empty());
        assert!(c.faults.events().iter().all(|e| e.at() < c.rounds));
    }
}

//! AES-128-CTR + HMAC-SHA256 encrypt-then-MAC sealing.
//!
//! (The vendored RustCrypto set has `aes`, `cipher`, `hmac`, `sha2` but no
//! AEAD crate, so we compose the classic EtM construction: unique nonce per
//! seal, MAC over nonce || ciphertext, constant-time tag comparison via the
//! `subtle`-backed `hmac::verify_slice`.)
//!
//! The CTR keystream is generated in parallel: byte i of the stream
//! depends only on (key, iv, i), so the buffer is cut into lanes whose
//! counters start at the lane's absolute block offset — byte-identical to
//! the serial stream for any thread count. `seal_in_place`/`open_in_place`
//! operate on the transport's round-persistent buffer with no
//! plaintext/ciphertext copies.

use anyhow::{bail, Result};
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

use aes::cipher::KeyInit;

type HmacSha256 = Hmac<Sha256>;

mod ctr_impl {
    //! Minimal CTR mode over AES-128 (the `ctr` crate is not vendored).
    //! Big-endian 128-bit counter, as in NIST SP 800-38A, split across
    //! threads by counter offset.
    use aes::cipher::{generic_array::GenericArray, BlockEncrypt};

    use crate::util::par;

    /// Bytes per parallel work lane — a multiple of the 16-byte block, so
    /// every lane starts on a block boundary.
    const LANE_BYTES: usize = 1 << 14;

    pub(super) fn apply_ctr(cipher: &aes::Aes128, iv: &[u8; 16], data: &mut [u8]) {
        let base = u128::from_be_bytes(*iv);
        if data.len() <= LANE_BYTES || par::current_threads() == 1 {
            xor_stream(cipher, base, data);
            return;
        }
        let items: Vec<(usize, &mut [u8])> =
            data.chunks_mut(LANE_BYTES).enumerate().collect();
        par::run_items(items, |(lane, chunk)| {
            let blocks_before = (lane * (LANE_BYTES / 16)) as u128;
            xor_stream(cipher, base.wrapping_add(blocks_before), chunk);
        });
    }

    fn xor_stream(cipher: &aes::Aes128, mut counter: u128, data: &mut [u8]) {
        for chunk in data.chunks_mut(16) {
            let mut block = GenericArray::clone_from_slice(&counter.to_be_bytes());
            cipher.encrypt_block(&mut block);
            for (b, k) in chunk.iter_mut().zip(block.iter()) {
                *b ^= k;
            }
            counter = counter.wrapping_add(1);
        }
    }
}

/// Per-pair transport key material (enc key + mac key).
#[derive(Clone)]
pub struct TransportKey {
    enc: [u8; 16],
    mac: [u8; 32],
    /// monotonically increasing nonce counter (per sender)
    seq: u64,
}

/// nonce(16) + tag(32)
pub const SEAL_OVERHEAD_BYTES: u64 = 48;

impl TransportKey {
    /// Derive a key pair from a shared secret + context label (HKDF-lite:
    /// two labeled SHA-256 expansions).
    pub fn derive(secret: &[u8], context: &str) -> TransportKey {
        let mut h1 = Sha256::new();
        h1.update(b"crossfed-enc");
        h1.update(secret);
        h1.update(context.as_bytes());
        let enc_full = h1.finalize();

        let mut h2 = Sha256::new();
        h2.update(b"crossfed-mac");
        h2.update(secret);
        h2.update(context.as_bytes());
        let mac_full = h2.finalize();

        let mut enc = [0u8; 16];
        enc.copy_from_slice(&enc_full[..16]);
        let mut mac = [0u8; 32];
        mac.copy_from_slice(&mac_full);
        TransportKey { enc, mac, seq: 0 }
    }

    /// Current nonce counter (WAL snapshot). Key material itself is
    /// re-derived from the shared secret on resume; only the counter is
    /// run state.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Restore the nonce counter (WAL resume). Replaying a run from round
    /// r must continue the nonce sequence where the original left off —
    /// both for nonce uniqueness and for bit-identical ciphertexts.
    pub fn set_seq(&mut self, seq: u64) {
        self.seq = seq;
    }
}

/// An encrypted, authenticated payload.
#[derive(Clone, Debug)]
pub struct SealedPayload {
    pub nonce: [u8; 16],
    pub ciphertext: Vec<u8>,
    pub tag: [u8; 32],
}

impl SealedPayload {
    pub fn byte_len(&self) -> u64 {
        self.ciphertext.len() as u64 + SEAL_OVERHEAD_BYTES
    }
}

/// Encrypt-then-MAC. The nonce is seq-derived — never reused per key.
pub fn seal(key: &mut TransportKey, plaintext: &[u8]) -> SealedPayload {
    let mut ciphertext = plaintext.to_vec();
    let (nonce, tag) = seal_in_place(key, &mut ciphertext);
    SealedPayload { nonce, ciphertext, tag }
}

/// Encrypt-then-MAC in place over a caller-owned buffer (the transport's
/// round-persistent send buffer) — no plaintext/ciphertext copies.
/// Returns (nonce, tag); the buffer holds the ciphertext afterwards.
pub fn seal_in_place(key: &mut TransportKey, buf: &mut [u8]) -> ([u8; 16], [u8; 32]) {
    let mut nonce = [0u8; 16];
    nonce[..8].copy_from_slice(&key.seq.to_be_bytes());
    key.seq += 1;

    let cipher = aes::Aes128::new_from_slice(&key.enc).expect("key size");
    ctr_impl::apply_ctr(&cipher, &nonce, buf);
    let tag = mac_tag(&key.mac, &nonce, buf);
    (nonce, tag)
}

/// Verify + decrypt. Fails on any tampering.
pub fn open(key: &TransportKey, sealed: &SealedPayload) -> Result<Vec<u8>> {
    let mut plaintext = sealed.ciphertext.clone();
    open_in_place(key, &sealed.nonce, &sealed.tag, &mut plaintext)?;
    Ok(plaintext)
}

/// Verify + decrypt in place (CTR is self-inverse). On MAC failure the
/// buffer is left untouched (still ciphertext).
pub fn open_in_place(
    key: &TransportKey,
    nonce: &[u8; 16],
    tag: &[u8; 32],
    buf: &mut [u8],
) -> Result<()> {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(&key.mac).unwrap();
    mac.update(nonce);
    mac.update(buf);
    if mac.verify_slice(tag).is_err() {
        bail!("MAC verification failed: payload tampered or wrong key");
    }
    let cipher = aes::Aes128::new_from_slice(&key.enc).expect("key size");
    ctr_impl::apply_ctr(&cipher, nonce, buf);
    Ok(())
}

fn mac_tag(mac_key: &[u8; 32], nonce: &[u8; 16], ciphertext: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(mac_key).unwrap();
    mac.update(nonce);
    mac.update(ciphertext);
    let tag_bytes = mac.finalize().into_bytes();
    let mut tag = [0u8; 32];
    tag.copy_from_slice(&tag_bytes);
    tag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut k = TransportKey::derive(b"secret", "w0->leader");
        let msg = b"gradient bytes here".to_vec();
        let sealed = seal(&mut k, &msg);
        assert_ne!(sealed.ciphertext, msg); // actually encrypted
        assert_eq!(open(&k, &sealed).unwrap(), msg);
    }

    #[test]
    fn tamper_detected() {
        let mut k = TransportKey::derive(b"secret", "ctx");
        let sealed = seal(&mut k, b"payload");
        let mut bad = sealed.clone();
        bad.ciphertext[0] ^= 1;
        assert!(open(&k, &bad).is_err());
        let mut bad2 = sealed.clone();
        bad2.tag[5] ^= 0x80;
        assert!(open(&k, &bad2).is_err());
        let mut bad3 = sealed;
        bad3.nonce[0] ^= 1;
        assert!(open(&k, &bad3).is_err());
    }

    #[test]
    fn wrong_key_rejected() {
        let mut k1 = TransportKey::derive(b"secret-a", "ctx");
        let k2 = TransportKey::derive(b"secret-b", "ctx");
        let sealed = seal(&mut k1, b"payload");
        assert!(open(&k2, &sealed).is_err());
    }

    #[test]
    fn nonces_unique_per_seal() {
        let mut k = TransportKey::derive(b"secret", "ctx");
        let a = seal(&mut k, b"x");
        let b = seal(&mut k, b"x");
        assert_ne!(a.nonce, b.nonce);
        assert_ne!(a.ciphertext, b.ciphertext); // same msg, distinct stream
    }

    #[test]
    fn context_separates_keys() {
        let mut k1 = TransportKey::derive(b"s", "a->b");
        let k2 = TransportKey::derive(b"s", "b->a");
        let sealed = seal(&mut k1, b"payload");
        assert!(open(&k2, &sealed).is_err());
    }

    #[test]
    fn overhead_is_constant() {
        let mut k = TransportKey::derive(b"s", "c");
        for n in [0usize, 1, 1000] {
            let sealed = seal(&mut k, &vec![0u8; n]);
            assert_eq!(sealed.byte_len(), n as u64 + SEAL_OVERHEAD_BYTES);
        }
    }

    #[test]
    fn empty_payload() {
        let mut k = TransportKey::derive(b"s", "c");
        let sealed = seal(&mut k, b"");
        assert_eq!(open(&k, &sealed).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn parallel_keystream_matches_serial() {
        use crate::util::par;
        // > LANE_BYTES so the parallel path engages; odd tail too
        let msg: Vec<u8> = (0..200_003).map(|i| (i * 31 % 251) as u8).collect();
        let s = par::with_threads(1, || {
            let mut k = TransportKey::derive(b"x", "c");
            seal(&mut k, &msg)
        });
        let p = par::with_threads(8, || {
            let mut k = TransportKey::derive(b"x", "c");
            seal(&mut k, &msg)
        });
        assert_eq!(s.nonce, p.nonce);
        assert_eq!(s.ciphertext, p.ciphertext);
        assert_eq!(s.tag, p.tag);
        assert_eq!(open(&TransportKey::derive(b"x", "c"), &p).unwrap(), msg);
    }

    #[test]
    fn in_place_roundtrip_matches_owned_api() {
        let mut k1 = TransportKey::derive(b"secret", "ctx");
        let mut k2 = TransportKey::derive(b"secret", "ctx");
        let msg = b"zero-copy pipeline payload".to_vec();
        let sealed = seal(&mut k1, &msg);
        let mut buf = msg.clone();
        let (nonce, tag) = seal_in_place(&mut k2, &mut buf);
        assert_eq!(nonce, sealed.nonce);
        assert_eq!(buf, sealed.ciphertext);
        assert_eq!(tag, sealed.tag);
        open_in_place(&k2, &nonce, &tag, &mut buf).unwrap();
        assert_eq!(buf, msg);
        // tamper: buffer untouched on failure
        let mut bad = sealed.ciphertext.clone();
        bad[3] ^= 1;
        let before = bad.clone();
        assert!(open_in_place(&k2, &nonce, &tag, &mut bad).is_err());
        assert_eq!(bad, before);
    }

    #[test]
    fn ctr_keystream_known_pattern() {
        // CTR must be length-preserving and self-inverse
        let mut k = TransportKey::derive(b"kat", "c");
        let msg: Vec<u8> = (0..=255).collect();
        let sealed = seal(&mut k, &msg);
        assert_eq!(sealed.ciphertext.len(), 256);
        assert_eq!(open(&k, &sealed).unwrap(), msg);
    }
}

//! AES-128-CTR + HMAC-SHA256 encrypt-then-MAC sealing.
//!
//! (The vendored RustCrypto set has `aes`, `cipher`, `hmac`, `sha2` but no
//! AEAD crate, so we compose the classic EtM construction: unique nonce per
//! seal, MAC over nonce || ciphertext, constant-time tag comparison via the
//! `subtle`-backed `hmac::verify_slice`.)

use anyhow::{bail, Result};
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type Aes128Ctr = ctr_impl::Ctr128BE<aes::Aes128>;
type HmacSha256 = Hmac<Sha256>;

mod ctr_impl {
    //! Minimal CTR mode over the block cipher (the `ctr` crate is not
    //! vendored). Big-endian 128-bit counter, as in NIST SP 800-38A.
    use aes::cipher::{
        generic_array::GenericArray, BlockEncrypt, KeyInit, KeySizeUser,
    };

    pub struct Ctr128BE<C: BlockEncrypt + KeyInit> {
        cipher: C,
        counter: u128,
        keystream: [u8; 16],
        used: usize,
    }

    impl<C: BlockEncrypt + KeyInit> Ctr128BE<C> {
        fn refill(&mut self) {
            let mut block = GenericArray::clone_from_slice(
                &self.counter.to_be_bytes(),
            );
            self.cipher.encrypt_block(&mut block);
            self.keystream.copy_from_slice(&block);
            self.counter = self.counter.wrapping_add(1);
            self.used = 0;
        }
    }

    impl<C: BlockEncrypt + KeyInit + KeySizeUser> super::KeyIvInitCompat for Ctr128BE<C> {
        fn new_compat(key: &[u8], iv: &[u8; 16]) -> Self {
            let cipher = C::new_from_slice(key).expect("key size");
            let mut s = Ctr128BE {
                cipher,
                counter: u128::from_be_bytes(*iv),
                keystream: [0u8; 16],
                used: 16,
            };
            s.refill();
            s.used = 0;
            s
        }
    }

    impl<C: BlockEncrypt + KeyInit> super::StreamCipherCompat for Ctr128BE<C> {
        fn apply_keystream_compat(&mut self, data: &mut [u8]) {
            for b in data {
                if self.used == 16 {
                    self.refill();
                }
                *b ^= self.keystream[self.used];
                self.used += 1;
            }
        }
    }
}

/// Compat traits so the impl reads like the `ctr` crate's API.
trait KeyIvInitCompat {
    fn new_compat(key: &[u8], iv: &[u8; 16]) -> Self;
}
trait StreamCipherCompat {
    fn apply_keystream_compat(&mut self, data: &mut [u8]);
}

/// Per-pair transport key material (enc key + mac key).
#[derive(Clone)]
pub struct TransportKey {
    enc: [u8; 16],
    mac: [u8; 32],
    /// monotonically increasing nonce counter (per sender)
    seq: u64,
}

/// nonce(16) + tag(32)
pub const SEAL_OVERHEAD_BYTES: u64 = 48;

impl TransportKey {
    /// Derive a key pair from a shared secret + context label (HKDF-lite:
    /// two labeled SHA-256 expansions).
    pub fn derive(secret: &[u8], context: &str) -> TransportKey {
        let mut h1 = Sha256::new();
        h1.update(b"crossfed-enc");
        h1.update(secret);
        h1.update(context.as_bytes());
        let enc_full = h1.finalize();

        let mut h2 = Sha256::new();
        h2.update(b"crossfed-mac");
        h2.update(secret);
        h2.update(context.as_bytes());
        let mac_full = h2.finalize();

        let mut enc = [0u8; 16];
        enc.copy_from_slice(&enc_full[..16]);
        let mut mac = [0u8; 32];
        mac.copy_from_slice(&mac_full);
        TransportKey { enc, mac, seq: 0 }
    }
}

/// An encrypted, authenticated payload.
#[derive(Clone, Debug)]
pub struct SealedPayload {
    pub nonce: [u8; 16],
    pub ciphertext: Vec<u8>,
    pub tag: [u8; 32],
}

impl SealedPayload {
    pub fn byte_len(&self) -> u64 {
        self.ciphertext.len() as u64 + SEAL_OVERHEAD_BYTES
    }
}

/// Encrypt-then-MAC. The nonce is seq-derived — never reused per key.
pub fn seal(key: &mut TransportKey, plaintext: &[u8]) -> SealedPayload {
    let mut nonce = [0u8; 16];
    nonce[..8].copy_from_slice(&key.seq.to_be_bytes());
    key.seq += 1;

    let mut ciphertext = plaintext.to_vec();
    let mut ctr = <Aes128Ctr as KeyIvInitCompat>::new_compat(&key.enc, &nonce);
    StreamCipherCompat::apply_keystream_compat(&mut ctr, &mut ciphertext);

    let mut mac = <HmacSha256 as Mac>::new_from_slice(&key.mac).unwrap();
    mac.update(&nonce);
    mac.update(&ciphertext);
    let tag_bytes = mac.finalize().into_bytes();
    let mut tag = [0u8; 32];
    tag.copy_from_slice(&tag_bytes);

    SealedPayload { nonce, ciphertext, tag }
}

/// Verify + decrypt. Fails on any tampering.
pub fn open(key: &TransportKey, sealed: &SealedPayload) -> Result<Vec<u8>> {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(&key.mac).unwrap();
    mac.update(&sealed.nonce);
    mac.update(&sealed.ciphertext);
    if mac.verify_slice(&sealed.tag).is_err() {
        bail!("MAC verification failed: payload tampered or wrong key");
    }
    let mut plaintext = sealed.ciphertext.clone();
    let mut ctr =
        <Aes128Ctr as KeyIvInitCompat>::new_compat(&key.enc, &sealed.nonce);
    StreamCipherCompat::apply_keystream_compat(&mut ctr, &mut plaintext);
    Ok(plaintext)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut k = TransportKey::derive(b"secret", "w0->leader");
        let msg = b"gradient bytes here".to_vec();
        let sealed = seal(&mut k, &msg);
        assert_ne!(sealed.ciphertext, msg); // actually encrypted
        assert_eq!(open(&k, &sealed).unwrap(), msg);
    }

    #[test]
    fn tamper_detected() {
        let mut k = TransportKey::derive(b"secret", "ctx");
        let sealed = seal(&mut k, b"payload");
        let mut bad = sealed.clone();
        bad.ciphertext[0] ^= 1;
        assert!(open(&k, &bad).is_err());
        let mut bad2 = sealed.clone();
        bad2.tag[5] ^= 0x80;
        assert!(open(&k, &bad2).is_err());
        let mut bad3 = sealed;
        bad3.nonce[0] ^= 1;
        assert!(open(&k, &bad3).is_err());
    }

    #[test]
    fn wrong_key_rejected() {
        let mut k1 = TransportKey::derive(b"secret-a", "ctx");
        let k2 = TransportKey::derive(b"secret-b", "ctx");
        let sealed = seal(&mut k1, b"payload");
        assert!(open(&k2, &sealed).is_err());
    }

    #[test]
    fn nonces_unique_per_seal() {
        let mut k = TransportKey::derive(b"secret", "ctx");
        let a = seal(&mut k, b"x");
        let b = seal(&mut k, b"x");
        assert_ne!(a.nonce, b.nonce);
        assert_ne!(a.ciphertext, b.ciphertext); // same msg, distinct stream
    }

    #[test]
    fn context_separates_keys() {
        let mut k1 = TransportKey::derive(b"s", "a->b");
        let k2 = TransportKey::derive(b"s", "b->a");
        let sealed = seal(&mut k1, b"payload");
        assert!(open(&k2, &sealed).is_err());
    }

    #[test]
    fn overhead_is_constant() {
        let mut k = TransportKey::derive(b"s", "c");
        for n in [0usize, 1, 1000] {
            let sealed = seal(&mut k, &vec![0u8; n]);
            assert_eq!(sealed.byte_len(), n as u64 + SEAL_OVERHEAD_BYTES);
        }
    }

    #[test]
    fn empty_payload() {
        let mut k = TransportKey::derive(b"s", "c");
        let sealed = seal(&mut k, b"");
        assert_eq!(open(&k, &sealed).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn ctr_keystream_known_pattern() {
        // CTR must be length-preserving and self-inverse
        let mut k = TransportKey::derive(b"kat", "c");
        let msg: Vec<u8> = (0..=255).collect();
        let sealed = seal(&mut k, &msg);
        assert_eq!(sealed.ciphertext.len(), 256);
        assert_eq!(open(&k, &sealed).unwrap(), msg);
    }
}

//! Security substrate (§3.1 "Ensure Data Security" and the paper's
//! encryption / privacy-protection discussion).
//!
//! Two real mechanisms, plus a cost model for the homomorphic-encryption
//! variant the paper mentions:
//!
//! * [`seal`]/[`open`] — AES-128-CTR + HMAC-SHA256 encrypt-then-MAC
//!   transport sealing for every update payload crossing the WAN. Real
//!   crypto (vendored RustCrypto crates), real byte overhead.
//! * [`secure_agg`] — pairwise additive masking (Bonawitz et al. 2017):
//!   the leader only ever sees the *sum* of worker updates, matching the
//!   property the paper invokes homomorphic encryption for. Masks are
//!   derived from pairwise shared secrets and cancel exactly in the sum.
//! * [`he_cost`] — an additively-homomorphic-encryption cost model
//!   (Paillier-like) for the ablation that prices real HE against
//!   masking-based secure aggregation.

mod aead;
mod secure_agg;

pub use aead::{
    open, open_in_place, seal, seal_in_place, SealedPayload, TransportKey,
    SEAL_OVERHEAD_BYTES,
};
pub use secure_agg::{he_cost, HeCost, MaskedUpdate, SecureAggregator};

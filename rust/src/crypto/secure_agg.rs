//! Secure aggregation via pairwise additive masking (Bonawitz et al. 2017)
//! and an additive-HE cost model.
//!
//! Property delivered: the aggregation leader learns only
//! `sum_i update_i`, never an individual worker's update — the same
//! guarantee the paper invokes homomorphic encryption for, at a tiny
//! fraction of the CPU cost. Each ordered pair (i, j) shares a secret;
//! worker i adds `+m_ij` and worker j adds `-m_ij` where `m_ij` is a
//! pseudorandom vector expanded from the pair secret per round. All masks
//! cancel exactly in the sum (float-exact: masks are generated as f32 and
//! added/subtracted symmetrically — see `paired_mask`).

use sha2::{Digest, Sha256};

use crate::util::rng::Pcg64;

/// A masked update ready to send to the leader.
#[derive(Clone, Debug)]
pub struct MaskedUpdate {
    pub worker: usize,
    pub data: Vec<f32>,
}

/// Coordinates mask generation across `n` workers for each round.
#[derive(Clone, Debug)]
pub struct SecureAggregator {
    n: usize,
    /// pair_secret[i][j] for i < j
    pair_seeds: Vec<Vec<u64>>,
}

impl SecureAggregator {
    /// Set up pairwise secrets from a session secret (in a real
    /// deployment this is a DH exchange; here the session secret stands
    /// in for the PKI).
    pub fn new(n: usize, session_secret: &[u8]) -> SecureAggregator {
        let mut pair_seeds = vec![vec![0u64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let mut h = Sha256::new();
                h.update(b"crossfed-pair");
                h.update(session_secret);
                h.update((i as u64).to_le_bytes());
                h.update((j as u64).to_le_bytes());
                let d = h.finalize();
                let seed = u64::from_le_bytes(d[..8].try_into().unwrap());
                pair_seeds[i][j] = seed;
                pair_seeds[j][i] = seed;
            }
        }
        SecureAggregator { n, pair_seeds }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The pseudorandom mask for pair (i, j) at `round`, from i's view.
    /// Antisymmetric: mask(i, j) == -mask(j, i) element-for-element, so
    /// sums cancel exactly in f32.
    fn paired_mask(&self, i: usize, j: usize, round: u64, len: usize) -> Vec<f32> {
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let mut rng = Pcg64::new(self.pair_seeds[lo][hi] ^ round, round);
        let sign = if i < j { 1.0f32 } else { -1.0f32 };
        (0..len).map(|_| sign * (rng.normal() as f32)).collect()
    }

    /// Mask one worker's update for `round`.
    pub fn mask(&self, worker: usize, round: u64, update: &[f32]) -> MaskedUpdate {
        assert!(worker < self.n);
        let mut data = update.to_vec();
        for other in 0..self.n {
            if other == worker {
                continue;
            }
            let m = self.paired_mask(worker, other, round, update.len());
            for (d, mv) in data.iter_mut().zip(&m) {
                *d += mv;
            }
        }
        MaskedUpdate { worker, data }
    }

    /// Sum the masked updates. Panics unless every worker reported
    /// (dropout recovery needs the full Bonawitz protocol — out of scope,
    /// documented in DESIGN.md).
    pub fn unmask_sum(&self, updates: &[MaskedUpdate]) -> Vec<f32> {
        assert_eq!(
            updates.len(),
            self.n,
            "secure agg requires all {} workers (got {})",
            self.n,
            updates.len()
        );
        let mut seen = vec![false; self.n];
        for u in updates {
            assert!(!seen[u.worker], "duplicate worker {}", u.worker);
            seen[u.worker] = true;
        }
        let len = updates[0].data.len();
        let mut sum = vec![0.0f32; len];
        for u in updates {
            assert_eq!(u.data.len(), len);
            for (s, x) in sum.iter_mut().zip(&u.data) {
                *s += x;
            }
        }
        sum
    }
}

/// Cost model for additively homomorphic encryption (Paillier, 2048-bit),
/// the heavyweight alternative the paper names. Used by the privacy
/// ablation bench to price HE against masking.
#[derive(Clone, Copy, Debug)]
pub struct HeCost {
    /// ciphertext expansion: bytes on wire per plaintext f32
    pub bytes_per_elem: f64,
    /// encryption cost per element, seconds (amortized, batched)
    pub enc_secs_per_elem: f64,
    /// aggregation (ciphertext multiply) cost per element-worker, seconds
    pub agg_secs_per_elem: f64,
    /// decryption cost per element, seconds
    pub dec_secs_per_elem: f64,
}

/// Published Paillier-2048 throughput figures (order-of-magnitude:
/// ~1k enc/s/core, 512-byte ciphertexts, cheap ciphertext adds).
pub fn he_cost() -> HeCost {
    HeCost {
        bytes_per_elem: 512.0,
        enc_secs_per_elem: 1e-3,
        agg_secs_per_elem: 2e-6,
        dec_secs_per_elem: 3e-4,
    }
}

impl HeCost {
    /// Total extra seconds to HE-protect one round of `n_workers` updates
    /// of `n_elems` each.
    pub fn round_secs(&self, n_workers: usize, n_elems: usize) -> f64 {
        let e = n_elems as f64;
        let w = n_workers as f64;
        w * e * self.enc_secs_per_elem
            + w * e * self.agg_secs_per_elem
            + e * self.dec_secs_per_elem
    }

    /// Wire bytes for one worker's HE-encrypted update.
    pub fn wire_bytes(&self, n_elems: usize) -> u64 {
        (self.bytes_per_elem * n_elems as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn updates(n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(42, 0);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn masks_cancel_exactly() {
        let n = 4;
        let len = 257;
        let agg = SecureAggregator::new(n, b"session");
        let raw = updates(n, len);
        let masked: Vec<MaskedUpdate> =
            (0..n).map(|i| agg.mask(i, 3, &raw[i])).collect();
        let sum = agg.unmask_sum(&masked);
        for j in 0..len {
            let want: f32 = raw.iter().map(|u| u[j]).sum();
            // exact cancellation (antisymmetric f32 masks)
            assert!((sum[j] - want).abs() < 1e-5, "j={j}: {} vs {want}", sum[j]);
        }
    }

    #[test]
    fn individual_updates_are_hidden() {
        let agg = SecureAggregator::new(3, b"s");
        let raw = updates(3, 64);
        let masked = agg.mask(0, 1, &raw[0]);
        // masked data must be far from the raw update
        let dist: f32 = masked
            .data
            .iter()
            .zip(&raw[0])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(dist / 64.0 > 0.3, "mask too weak: {dist}");
    }

    #[test]
    fn rounds_use_fresh_masks() {
        let agg = SecureAggregator::new(2, b"s");
        let u = vec![0.0f32; 16];
        let m1 = agg.mask(0, 1, &u);
        let m2 = agg.mask(0, 2, &u);
        assert_ne!(m1.data, m2.data);
    }

    #[test]
    #[should_panic(expected = "requires all")]
    fn dropout_detected() {
        let agg = SecureAggregator::new(3, b"s");
        let raw = updates(3, 8);
        let masked = vec![agg.mask(0, 1, &raw[0]), agg.mask(1, 1, &raw[1])];
        agg.unmask_sum(&masked);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_detected() {
        let agg = SecureAggregator::new(2, b"s");
        let raw = updates(2, 8);
        let masked = vec![agg.mask(0, 1, &raw[0]), agg.mask(0, 1, &raw[0])];
        agg.unmask_sum(&masked);
    }

    #[test]
    fn he_cost_scales() {
        let c = he_cost();
        assert!(c.round_secs(3, 1_000_000) > 1000.0); // HE is brutal
        assert_eq!(c.wire_bytes(1000), 512_000);
        // masking sends 4 bytes/elem; HE sends 128x more
        assert!(c.bytes_per_elem / 4.0 > 100.0);
    }

    #[test]
    fn single_worker_degenerate() {
        let agg = SecureAggregator::new(1, b"s");
        let u = vec![1.0f32, 2.0];
        let masked = agg.mask(0, 0, &u);
        assert_eq!(masked.data, u); // no pairs, no masks
        assert_eq!(agg.unmask_sum(&[masked]), u);
    }
}

//! Simulated cloud platforms.
//!
//! The paper's testbed is "three major cloud platforms (such as AWS,
//! Google Cloud, and Azure)". This module models each platform's compute
//! capability and cost so the coordinator can reason about heterogeneity;
//! the WAN between platforms lives in [`crate::netsim`].
//!
//! A [`ClusterSpec`] is a flat list of *worker nodes*. Each node belongs
//! to a cloud (the `cloud` id): single-node clouds reproduce the paper's
//! 3-platform star, while [`ClusterSpec::paper_default_scaled`] puts
//! several AZ-level nodes inside each cloud so the hierarchical
//! aggregation path has an intra-cloud tier to reduce over. The first
//! node of each cloud acts as that cloud's WAN gateway.

use crate::util::rng::Pcg64;

/// One cloud worker node participating in federated training.
#[derive(Clone, Debug)]
pub struct CloudPlatform {
    pub name: String,
    /// relative training-step speed: 1.0 = baseline; 2.0 = twice as fast.
    /// Simulated step time = measured_step_time / compute_speed.
    pub compute_speed: f64,
    /// USD per hour of compute (for the paper's training-cost claims)
    pub cost_per_hour: f64,
    /// region label (used by the WAN topology presets)
    pub region: String,
    /// per-step slowdown probability (transient stragglers)
    pub straggler_prob: f64,
    /// multiplicative slowdown when straggling
    pub straggler_factor: f64,
    /// owning cloud id (nodes sharing a cloud are AZ-level peers behind
    /// one WAN gateway; see [`ClusterSpec::gateway`])
    pub cloud: usize,
}

impl CloudPlatform {
    pub fn new(name: &str, compute_speed: f64) -> CloudPlatform {
        CloudPlatform {
            name: name.to_string(),
            compute_speed,
            cost_per_hour: 3.0,
            region: "us".to_string(),
            straggler_prob: 0.0,
            straggler_factor: 3.0,
            cloud: 0,
        }
    }

    /// Simulated duration of work that takes `base_secs` on the baseline
    /// platform, with straggler injection from `rng`.
    pub fn step_time(&self, base_secs: f64, rng: &mut Pcg64) -> f64 {
        assert!(self.compute_speed > 0.0);
        let mut t = base_secs / self.compute_speed;
        if self.straggler_prob > 0.0 && rng.uniform() < self.straggler_prob {
            t *= self.straggler_factor;
        }
        t
    }
}

/// The set of platforms in one experiment.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub platforms: Vec<CloudPlatform>,
    /// current WAN gateway per cloud — each cloud's first member until a
    /// failure forces re-election ([`ClusterSpec::reelect_gateway`])
    gateways: Vec<usize>,
    /// nodes whose WAN egress failed: ineligible for (re-)election
    egress_failed: Vec<bool>,
    /// elastic-membership roster: inactive nodes (preempted spot
    /// instances, departed workers) hold no shard, run no steps and are
    /// ineligible for gateway election until they re-join
    active: Vec<bool>,
}

impl ClusterSpec {
    /// Build a cluster from its node list; each cloud's first member
    /// starts as its WAN gateway.
    pub fn new(platforms: Vec<CloudPlatform>) -> ClusterSpec {
        let n_clouds =
            platforms.iter().map(|p| p.cloud + 1).max().unwrap_or(0);
        let gateways = (0..n_clouds)
            .map(|c| {
                (0..platforms.len())
                    .find(|&i| platforms[i].cloud == c)
                    .unwrap_or_else(|| panic!("cloud {c} has no members"))
            })
            .collect();
        let egress_failed = vec![false; platforms.len()];
        let active = vec![true; platforms.len()];
        ClusterSpec { platforms, gateways, egress_failed, active }
    }

    pub fn n(&self) -> usize {
        self.platforms.len()
    }

    /// The paper's 3-platform setup: heterogeneous compute speeds and
    /// costs shaped like AWS / GCP / Azure GPU instances.
    pub fn paper_default() -> ClusterSpec {
        ClusterSpec::paper_default_scaled(1)
    }

    /// The paper's 3 clouds, each hosting `nodes_per_cloud` AZ-level
    /// worker nodes (same region/cost/straggler profile per cloud).
    /// `paper_default_scaled(1)` is exactly [`ClusterSpec::paper_default`];
    /// larger counts give the hierarchical aggregation path an
    /// intra-cloud tier to reduce over.
    pub fn paper_default_scaled(nodes_per_cloud: usize) -> ClusterSpec {
        assert!(nodes_per_cloud >= 1);
        let bases = [
            CloudPlatform {
                name: "aws".into(),
                compute_speed: 1.00,
                cost_per_hour: 3.06, // p3.2xlarge-like
                region: "us-east".into(),
                straggler_prob: 0.05,
                straggler_factor: 2.5,
                cloud: 0,
            },
            CloudPlatform {
                name: "gcp".into(),
                compute_speed: 0.85,
                cost_per_hour: 2.48,
                region: "us-central".into(),
                straggler_prob: 0.05,
                straggler_factor: 2.5,
                cloud: 1,
            },
            CloudPlatform {
                name: "azure".into(),
                compute_speed: 0.70,
                cost_per_hour: 3.40,
                region: "eu-west".into(),
                straggler_prob: 0.08,
                straggler_factor: 3.0,
                cloud: 2,
            },
        ];
        let mut platforms = Vec::with_capacity(3 * nodes_per_cloud);
        for base in bases {
            for az in 0..nodes_per_cloud {
                let mut p = base.clone();
                if nodes_per_cloud > 1 {
                    p.name = format!("{}-az{az}", base.name);
                }
                platforms.push(p);
            }
        }
        ClusterSpec::new(platforms)
    }

    /// Planet-scale heterogeneous generator: `n_clouds` clouds whose
    /// member counts cycle through `sizes` (cloud `c` gets
    /// `sizes[c % sizes.len()]` nodes). Clouds cycle through the three
    /// paper platform profiles (AWS/GCP/Azure-like speed, cost and
    /// straggler shape) and are grouped four-per-region, so the WAN mesh
    /// exercises intra-region *and* inter-region gateway links at scale.
    /// `scaled(64, &[320, 128, 64])` is the ≥10k-node planet-scale
    /// topology the `sim_scale` bench and `examples/planet_scale.rs` run.
    pub fn scaled(n_clouds: usize, sizes: &[usize]) -> ClusterSpec {
        assert!(n_clouds >= 1, "need at least one cloud");
        assert!(
            !sizes.is_empty() && sizes.iter().all(|&s| s >= 1),
            "every cloud needs at least one node"
        );
        // (speed, $/h, straggler_prob, straggler_factor) per profile,
        // matching the paper_default platforms
        let profiles = [
            (1.00, 3.06, 0.05, 2.5),
            (0.85, 2.48, 0.05, 2.5),
            (0.70, 3.40, 0.08, 3.0),
        ];
        let total: usize = (0..n_clouds).map(|c| sizes[c % sizes.len()]).sum();
        let mut platforms = Vec::with_capacity(total);
        for c in 0..n_clouds {
            let (speed, cost, sprob, sfac) = profiles[c % profiles.len()];
            let region = format!("region{}", c / 4);
            for az in 0..sizes[c % sizes.len()] {
                platforms.push(CloudPlatform {
                    name: format!("c{c}-az{az}"),
                    compute_speed: speed,
                    cost_per_hour: cost,
                    region: region.clone(),
                    straggler_prob: sprob,
                    straggler_factor: sfac,
                    cloud: c,
                });
            }
        }
        ClusterSpec::new(platforms)
    }

    /// Homogeneous cluster of `n` identical platforms (ablation baseline).
    pub fn homogeneous(n: usize) -> ClusterSpec {
        ClusterSpec::new(
            (0..n)
                .map(|i| {
                    let mut p = CloudPlatform::new(&format!("cloud{i}"), 1.0);
                    p.cloud = i;
                    p
                })
                .collect(),
        )
    }

    /// Strongly heterogeneous cluster (speeds spread geometrically) used
    /// by the partitioning/straggler ablations.
    pub fn heterogeneous(n: usize, spread: f64) -> ClusterSpec {
        assert!(n >= 1);
        assert!(spread >= 1.0);
        let platforms = (0..n)
            .map(|i| {
                // speeds from 1.0 down to 1/spread
                let f = if n == 1 {
                    1.0
                } else {
                    (1.0 / spread).powf(i as f64 / (n - 1) as f64)
                };
                let mut p = CloudPlatform::new(&format!("cloud{i}"), f);
                p.straggler_prob = 0.05;
                p.cloud = i;
                p
            })
            .collect();
        ClusterSpec::new(platforms)
    }

    /// Number of distinct clouds (cloud ids are expected to be dense,
    /// `0..n_clouds`).
    pub fn n_clouds(&self) -> usize {
        self.platforms.iter().map(|p| p.cloud + 1).max().unwrap_or(0)
    }

    /// Cloud id of node `i`.
    pub fn cloud_of(&self, node: usize) -> usize {
        self.platforms[node].cloud
    }

    /// Node indices belonging to cloud `c`, in node order.
    pub fn cloud_members(&self, c: usize) -> Vec<usize> {
        (0..self.platforms.len())
            .filter(|&i| self.platforms[i].cloud == c)
            .collect()
    }

    /// The current WAN gateway node of cloud `c` — its first member
    /// until a failure forces re-election. Intra-cloud traffic
    /// terminates here; only the gateway talks across regions.
    pub fn gateway(&self, c: usize) -> usize {
        self.gateways[c]
    }

    /// Record that `node`'s WAN egress failed: it keeps training but can
    /// no longer serve (or be re-elected) as a gateway.
    pub fn mark_egress_failed(&mut self, node: usize) {
        self.egress_failed[node] = true;
    }

    /// Whether `node` is eligible to serve as a WAN gateway.
    pub fn egress_ok(&self, node: usize) -> bool {
        !self.egress_failed[node]
    }

    /// Record that `node`'s WAN egress recovered: it is eligible for
    /// (re-)election again (transient-outage recovery; the counterpart
    /// of [`ClusterSpec::mark_egress_failed`]).
    pub fn mark_egress_restored(&mut self, node: usize) {
        self.egress_failed[node] = false;
    }

    /// Members of cloud `c` whose WAN egress is currently failed, in
    /// node order (lowest id first — the fail-back priority).
    pub fn egress_failed_members(&self, c: usize) -> Vec<usize> {
        self.cloud_members(c)
            .into_iter()
            .filter(|&m| self.egress_failed[m])
            .collect()
    }

    /// Whether `node` is currently part of the training roster.
    pub fn is_active(&self, node: usize) -> bool {
        self.active[node]
    }

    /// Drop `node` from the roster (spot preemption / `worker-leave:`).
    /// The node keeps its channels and local state so a later
    /// [`ClusterSpec::activate`] can bring it back.
    pub fn deactivate(&mut self, node: usize) {
        self.active[node] = false;
    }

    /// Return `node` to the roster (`worker-join:` after a preemption).
    pub fn activate(&mut self, node: usize) {
        self.active[node] = true;
    }

    /// Roster members of cloud `c`, in node order.
    pub fn active_members(&self, c: usize) -> Vec<usize> {
        self.cloud_members(c)
            .into_iter()
            .filter(|&m| self.active[m])
            .collect()
    }

    /// All roster members across clouds, in node order.
    pub fn active_nodes(&self) -> Vec<usize> {
        (0..self.platforms.len()).filter(|&i| self.active[i]).collect()
    }

    /// Current roster size.
    pub fn n_active(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Re-elect cloud `c`'s gateway after its egress failed: the next
    /// member by node id with a working egress takes over. The rule is a
    /// pure function of the cluster state, so every replica of the run
    /// elects the same standby (determinism across runs and thread
    /// counts). Errors when no standby is left.
    pub fn reelect_gateway(&mut self, c: usize) -> anyhow::Result<usize> {
        let new_gw = self
            .cloud_members(c)
            .into_iter()
            .find(|&m| !self.egress_failed[m] && self.active[m])
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "cloud {c} has no standby gateway left (none of its {} \
                     members is active with working egress); run with \
                     --nodes-per-cloud >= 2",
                    self.cloud_members(c).len()
                )
            })?;
        self.gateways[c] = new_gw;
        Ok(new_gw)
    }

    /// Members of every cloud, indexed by cloud id.
    pub fn clouds(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.n_clouds()];
        for (i, p) in self.platforms.iter().enumerate() {
            out[p.cloud].push(i);
        }
        out
    }

    /// Total cost of `hours` wall-clock on all platforms.
    pub fn cost(&self, hours: f64) -> f64 {
        self.platforms.iter().map(|p| p.cost_per_hour * hours).sum()
    }

    /// Snapshot the election state (current gateways + failed-egress
    /// flags + the elastic roster) for the WAL. The platform list itself
    /// is config, rebuilt from the run spec on resume.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_usize(self.gateways.len());
        for &g in &self.gateways {
            w.put_usize(g);
        }
        w.put_usize(self.egress_failed.len());
        for &f in &self.egress_failed {
            w.put_bool(f);
        }
        w.put_usize(self.active.len());
        for &a in &self.active {
            w.put_bool(a);
        }
    }

    /// Restore state written by [`ClusterSpec::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        let n_gw = r.get_usize()?;
        anyhow::ensure!(
            n_gw == self.gateways.len(),
            "WAL cluster state has {n_gw} clouds, run has {}",
            self.gateways.len()
        );
        for g in self.gateways.iter_mut() {
            *g = r.get_usize()?;
        }
        let n_nodes = r.get_usize()?;
        anyhow::ensure!(
            n_nodes == self.egress_failed.len(),
            "WAL cluster state has {n_nodes} nodes, run has {}",
            self.egress_failed.len()
        );
        for f in self.egress_failed.iter_mut() {
            *f = r.get_bool()?;
        }
        let n_active = r.get_usize()?;
        anyhow::ensure!(
            n_active == self.active.len(),
            "WAL roster covers {n_active} nodes, run has {}",
            self.active.len()
        );
        for a in self.active.iter_mut() {
            *a = r.get_bool()?;
        }
        for (c, &g) in self.gateways.iter().enumerate() {
            anyhow::ensure!(
                g < self.platforms.len() && self.platforms[g].cloud == c,
                "WAL gateway {g} is not a member of cloud {c}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_has_three_heterogeneous_platforms() {
        let c = ClusterSpec::paper_default();
        assert_eq!(c.n(), 3);
        let speeds: Vec<f64> =
            c.platforms.iter().map(|p| p.compute_speed).collect();
        assert!(speeds[0] > speeds[1] && speeds[1] > speeds[2]);
    }

    #[test]
    fn step_time_scales_with_speed() {
        let mut rng = Pcg64::new(1, 0);
        let fast = CloudPlatform::new("f", 2.0);
        let slow = CloudPlatform::new("s", 0.5);
        assert!((fast.step_time(1.0, &mut rng) - 0.5).abs() < 1e-12);
        assert!((slow.step_time(1.0, &mut rng) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stragglers_slow_down_sometimes() {
        let mut rng = Pcg64::new(2, 0);
        let mut p = CloudPlatform::new("x", 1.0);
        p.straggler_prob = 0.5;
        p.straggler_factor = 10.0;
        let times: Vec<f64> =
            (0..200).map(|_| p.step_time(1.0, &mut rng)).collect();
        let slow = times.iter().filter(|&&t| t > 5.0).count();
        assert!(slow > 50 && slow < 150, "slow={slow}");
    }

    #[test]
    fn heterogeneous_spread() {
        let c = ClusterSpec::heterogeneous(4, 4.0);
        assert_eq!(c.platforms[0].compute_speed, 1.0);
        assert!((c.platforms[3].compute_speed - 0.25).abs() < 1e-9);
    }

    #[test]
    fn cost_accumulates() {
        let c = ClusterSpec::homogeneous(2);
        assert!((c.cost(2.0) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn single_node_clouds_are_their_own_gateways() {
        let c = ClusterSpec::paper_default();
        assert_eq!(c.n_clouds(), 3);
        for i in 0..3 {
            assert_eq!(c.cloud_of(i), i);
            assert_eq!(c.gateway(i), i);
            assert_eq!(c.cloud_members(i), vec![i]);
        }
    }

    #[test]
    fn reelection_walks_members_by_id() {
        let mut c = ClusterSpec::paper_default_scaled(3);
        // cloud 1 = {3, 4, 5}, gateway 3
        assert_eq!(c.gateway(1), 3);
        c.mark_egress_failed(3);
        assert_eq!(c.reelect_gateway(1).unwrap(), 4);
        assert_eq!(c.gateway(1), 4);
        // a second failure moves to the last standby
        c.mark_egress_failed(4);
        assert_eq!(c.reelect_gateway(1).unwrap(), 5);
        // no standby left: hard error, not a panic
        c.mark_egress_failed(5);
        assert!(c.reelect_gateway(1).is_err());
        // other clouds are untouched
        assert_eq!(c.gateway(0), 0);
        assert_eq!(c.gateway(2), 6);
        assert!(c.egress_ok(0) && !c.egress_ok(3));
        // restoring the original node fails the gateway role back to it
        assert_eq!(c.egress_failed_members(1), vec![3, 4, 5]);
        c.mark_egress_restored(3);
        assert!(c.egress_ok(3));
        assert_eq!(c.egress_failed_members(1), vec![4, 5]);
        assert_eq!(c.reelect_gateway(1).unwrap(), 3);
        assert_eq!(c.gateway(1), 3);
    }

    #[test]
    fn scaled_preset_groups_nodes_by_cloud() {
        let c = ClusterSpec::paper_default_scaled(4);
        assert_eq!(c.n(), 12);
        assert_eq!(c.n_clouds(), 3);
        assert_eq!(c.cloud_members(1), vec![4, 5, 6, 7]);
        assert_eq!(c.gateway(2), 8);
        // nodes of a cloud share the cloud's profile
        for i in c.cloud_members(0) {
            assert_eq!(c.platforms[i].region, "us-east");
            assert!((c.platforms[i].compute_speed - 1.0).abs() < 1e-12);
        }
        // scaled(1) is exactly the paper default
        let p1 = ClusterSpec::paper_default_scaled(1);
        assert_eq!(p1.n(), 3);
        assert_eq!(p1.platforms[0].name, "aws");
        let groups = c.clouds();
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[2], vec![8, 9, 10, 11]);
    }

    #[test]
    fn roster_tracks_leave_and_join() {
        let mut c = ClusterSpec::paper_default_scaled(3);
        assert_eq!(c.n_active(), 9);
        assert!(c.is_active(4));
        c.deactivate(4);
        assert!(!c.is_active(4));
        assert_eq!(c.n_active(), 8);
        // cloud 1 = {3, 4, 5}
        assert_eq!(c.active_members(1), vec![3, 5]);
        assert_eq!(c.cloud_members(1), vec![3, 4, 5], "topology unchanged");
        // an inactive node is skipped by gateway election
        c.deactivate(3);
        assert_eq!(c.reelect_gateway(1).unwrap(), 5);
        // no active member with working egress left: hard error
        c.deactivate(5);
        assert!(c.reelect_gateway(1).is_err());
        c.activate(4);
        assert_eq!(c.reelect_gateway(1).unwrap(), 4);
        assert_eq!(c.active_nodes(), vec![0, 1, 2, 4, 6, 7, 8]);
    }

    #[test]
    fn scaled_generator_cycles_sizes_and_profiles() {
        let c = ClusterSpec::scaled(6, &[4, 2]);
        assert_eq!(c.n_clouds(), 6);
        assert_eq!(c.n(), 3 * (4 + 2));
        // sizes cycle: clouds 0,2,4 get 4 nodes, clouds 1,3,5 get 2
        assert_eq!(c.cloud_members(0).len(), 4);
        assert_eq!(c.cloud_members(1).len(), 2);
        assert_eq!(c.cloud_members(4).len(), 4);
        // profiles cycle through the paper's three platforms
        let g0 = c.gateway(0);
        let g3 = c.gateway(3);
        assert_eq!(c.platforms[g0].compute_speed, 1.00);
        assert_eq!(c.platforms[g3].compute_speed, 1.00);
        assert_eq!(c.platforms[c.gateway(1)].compute_speed, 0.85);
        // four clouds per region: 0..=3 share one, 4..=5 the next
        assert_eq!(c.platforms[g0].region, "region0");
        assert_eq!(c.platforms[g3].region, "region0");
        assert_eq!(c.platforms[c.gateway(4)].region, "region1");
        // every cloud's first member is its gateway
        for cloud in 0..6 {
            assert_eq!(c.gateway(cloud), c.cloud_members(cloud)[0]);
        }
    }
}

//! Server-side optimizers over [`ParamSet`].
//!
//! Workers do plain SGD locally (matching FedAvg's local update); the
//! *server* optimizer is what gradient aggregation (paper formula 3)
//! applies to the aggregated gradient — and giving the server momentum or
//! Adam is exactly where gradient aggregation's generalization advantage
//! comes from in practice (server-side momentum smooths conflicting
//! client directions under heterogeneity).

use crate::model::ParamSet;

/// Optimizer selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerKind {
    Sgd,
    Momentum { beta: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Some(OptimizerKind::Sgd),
            "momentum" => Some(OptimizerKind::Momentum { beta: 0.9 }),
            "adam" => Some(OptimizerKind::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Momentum { .. } => "momentum",
            OptimizerKind::Adam { .. } => "adam",
        }
    }
}

/// Stateful optimizer: `step` applies one update from a gradient.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub lr: f32,
    t: u64,
    m: Option<ParamSet>,
    v: Option<ParamSet>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, lr: f32) -> Optimizer {
        assert!(lr > 0.0);
        Optimizer { kind, lr, t: 0, m: None, v: None }
    }

    /// params ← params − update(grad)
    pub fn step(&mut self, params: &mut ParamSet, grad: &ParamSet) {
        self.t += 1;
        match self.kind {
            OptimizerKind::Sgd => {
                params.axpy(-self.lr, grad);
            }
            OptimizerKind::Momentum { beta } => {
                let m = self.m.get_or_insert_with(|| {
                    ParamSet { leaves: grad.leaves.iter().map(|l| vec![0.0; l.len()]).collect() }
                });
                // m = beta*m + grad ; p -= lr*m
                for (ml, gl) in m.leaves.iter_mut().zip(&grad.leaves) {
                    for (mx, gx) in ml.iter_mut().zip(gl) {
                        *mx = beta * *mx + gx;
                    }
                }
                params.axpy(-self.lr, m);
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                let m = self.m.get_or_insert_with(|| {
                    ParamSet { leaves: grad.leaves.iter().map(|l| vec![0.0; l.len()]).collect() }
                });
                let v = self.v.get_or_insert_with(|| {
                    ParamSet { leaves: grad.leaves.iter().map(|l| vec![0.0; l.len()]).collect() }
                });
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for ((pl, gl), (ml, vl)) in params
                    .leaves
                    .iter_mut()
                    .zip(&grad.leaves)
                    .zip(m.leaves.iter_mut().zip(v.leaves.iter_mut()))
                {
                    for ((px, gx), (mx, vx)) in
                        pl.iter_mut().zip(gl).zip(ml.iter_mut().zip(vl.iter_mut()))
                    {
                        *mx = beta1 * *mx + (1.0 - beta1) * gx;
                        *vx = beta2 * *vx + (1.0 - beta2) * gx * gx;
                        let mhat = *mx / bc1;
                        let vhat = *vx / bc2;
                        *px -= self.lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Snapshot the mutable state (step count + moment buffers) for the
    /// WAL. `kind`/`lr` are configuration and not part of the snapshot.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_u64(self.t);
        for s in [&self.m, &self.v] {
            match s {
                None => w.put_u8(0),
                Some(p) => {
                    w.put_u8(1);
                    crate::wal::write_param_set(w, p);
                }
            }
        }
    }

    /// Restore state written by [`Optimizer::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        self.t = r.get_u64()?;
        self.m =
            if r.get_u8()? == 1 { Some(crate::wal::read_param_set(r)?) } else { None };
        self.v =
            if r.get_u8()? == 1 { Some(crate::wal::read_param_set(r)?) } else { None };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &ParamSet, target: f32) -> ParamSet {
        ParamSet {
            leaves: p
                .leaves
                .iter()
                .map(|l| l.iter().map(|x| x - target).collect())
                .collect(),
        }
    }

    fn loss(p: &ParamSet, target: f32) -> f64 {
        p.leaves
            .iter()
            .flat_map(|l| l.iter())
            .map(|&x| 0.5 * ((x - target) as f64).powi(2))
            .sum()
    }

    fn start() -> ParamSet {
        ParamSet { leaves: vec![vec![5.0; 8], vec![-3.0; 4]] }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = start();
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.3);
        for _ in 0..100 {
            let g = quad_grad(&p, 1.0);
            opt.step(&mut p, &g);
        }
        assert!(loss(&p, 1.0) < 1e-6);
        assert_eq!(opt.steps_taken(), 100);
    }

    #[test]
    fn momentum_faster_than_sgd_on_ill_conditioned() {
        // 1-D with tiny lr: momentum accelerates
        let run = |kind| {
            let mut p = ParamSet { leaves: vec![vec![10.0]] };
            let mut opt = Optimizer::new(kind, 0.02);
            for _ in 0..60 {
                let g = quad_grad(&p, 0.0);
                opt.step(&mut p, &g);
            }
            loss(&p, 0.0)
        };
        let sgd = run(OptimizerKind::Sgd);
        let mom = run(OptimizerKind::Momentum { beta: 0.9 });
        assert!(mom < sgd, "momentum={mom} sgd={sgd}");
    }

    #[test]
    fn adam_converges_and_is_scale_invariant() {
        for scale in [1.0f32, 100.0] {
            let mut p = ParamSet { leaves: vec![vec![5.0; 4]] };
            let mut opt = Optimizer::new(
                OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
                0.2,
            );
            for _ in 0..200 {
                let mut g = quad_grad(&p, 0.0);
                g.scale(scale); // Adam normalizes out the scale
                opt.step(&mut p, &g);
            }
            assert!(loss(&p, 0.0) < 1e-3, "scale={scale}: {}", loss(&p, 0.0));
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(OptimizerKind::parse("sgd"), Some(OptimizerKind::Sgd));
        assert!(matches!(
            OptimizerKind::parse("momentum"),
            Some(OptimizerKind::Momentum { .. })
        ));
        assert!(matches!(OptimizerKind::parse("adam"), Some(OptimizerKind::Adam { .. })));
        assert_eq!(OptimizerKind::parse("lamb"), None);
    }
}

//! Test & bench support: a mini property-testing framework and a
//! bench harness (the offline image has neither `proptest` nor
//! `criterion`; see DESIGN.md substitutions).

pub mod bench_kit;
pub mod proptest_kit;

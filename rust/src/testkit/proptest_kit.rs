//! Mini property-testing framework (proptest is not vendored offline).
//!
//! Provides seeded random generators, a `forall` runner that reports the
//! failing case number + seed, and greedy input shrinking for slices.

use crate::util::rng::Pcg64;
use std::ops::Range;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// case index (for diagnostics)
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below((r.end - r.start) as u64) as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        r.start + (r.end - r.start) * self.rng.uniform_f32()
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.range_f64(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of f32 with random length in `len` and values in `vals`.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Vector with occasional special values (0, range endpoints) mixed
    /// in — the proptest-style "edge case bias".
    pub fn vec_f32_edgy(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let mut v = self.vec_f32(len, vals.clone());
        for x in v.iter_mut() {
            match self.rng.below(12) {
                0 => *x = 0.0,
                1 => *x = vals.end,
                2 => *x = vals.start,
                _ => {}
            }
        }
        v
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }
}

/// Run `cases` random cases of `prop`. On panic, re-raises with the case
/// index and seed in the message so the failure is reproducible with
/// [`rerun`].
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    prop: F,
) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(name.len() as u64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Pcg64::new(seed, 0xF0A11), case };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed (debugging aid).
pub fn rerun<F: FnOnce(&mut Gen)>(seed: u64, prop: F) {
    let mut g = Gen { rng: Pcg64::new(seed, 0xF0A11), case: 0 };
    prop(&mut g);
}

/// Greedy shrink: find a minimal subsequence of `input` that still
/// fails `fails`. Complements `forall` for slice-shaped inputs.
pub fn shrink_slice<T: Clone>(
    input: &[T],
    fails: impl Fn(&[T]) -> bool,
) -> Vec<T> {
    assert!(fails(input), "shrink_slice needs a failing input");
    let mut cur = input.to_vec();
    loop {
        let mut improved = false;
        let mut chunk = (cur.len() / 2).max(1);
        loop {
            let mut i = 0;
            while i + chunk <= cur.len() {
                let mut candidate = Vec::with_capacity(cur.len() - chunk);
                candidate.extend_from_slice(&cur[..i]);
                candidate.extend_from_slice(&cur[i + chunk..]);
                if !candidate.is_empty() && fails(&candidate) {
                    cur = candidate;
                    improved = true;
                } else {
                    i += chunk;
                }
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
        if !improved {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_good_property() {
        forall("abs is non-negative", 100, |g| {
            let x = g.f32_in(-100.0..100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall("fails on big input", 100, |g| {
            let n = g.usize_in(0..100);
            assert!(n < 90, "n={n}");
        });
    }

    #[test]
    fn generators_cover_ranges() {
        let mut g = Gen { rng: Pcg64::new(7, 0xF0A11), case: 0 };
        for _ in 0..1000 {
            let u = g.usize_in(3..10);
            assert!((3..10).contains(&u));
            let f = g.f32_in(-1.0..1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = g.vec_f32_edgy(1..50, -5.0..5.0);
        assert!(!v.is_empty() && v.len() < 50);
    }

    #[test]
    fn shrink_finds_minimal_failure() {
        // property fails iff slice contains a 7
        let input = vec![1, 3, 7, 9, 11, 7, 2];
        let min = shrink_slice(&input, |s| s.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn rerun_reproduces() {
        let mut out1 = 0.0;
        rerun(42, |g| out1 = g.f32_in(0.0..1.0));
        let mut out2 = 0.0;
        rerun(42, |g| out2 = g.f32_in(0.0..1.0));
        assert_eq!(out1, out2);
    }
}

//! Bench harness for `cargo bench` (criterion is not vendored offline).
//!
//! Usage in a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = BenchSet::new("table2");
//! b.bench("fedavg", || run_fedavg());
//! b.report();
//! ```
//!
//! Measures wall-clock with warmup, reports mean/p50/p95 and throughput.
//!
//! `CROSSFED_BENCH_QUICK=1` clamps every set to zero warmup + one
//! measured iteration — the CI bench-smoke mode (compile + exercise the
//! bench targets without burning minutes on statistics).

use std::sync::OnceLock;
use std::time::Instant;

use crate::util::stats::Summary;

/// True when `CROSSFED_BENCH_QUICK` is set (to anything but `0`).
pub fn quick_mode() -> bool {
    static QUICK: OnceLock<bool> = OnceLock::new();
    *QUICK.get_or_init(|| {
        std::env::var("CROSSFED_BENCH_QUICK")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false)
    })
}

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// optional items-per-iteration for throughput reporting
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n / self.summary.mean)
    }
}

/// A named set of benchmarks with uniform reporting.
pub struct BenchSet {
    pub title: String,
    pub warmup_iters: usize,
    pub measure_iters: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSet {
    pub fn new(title: &str) -> BenchSet {
        let (warmup, measure) = if quick_mode() { (0, 1) } else { (3, 10) };
        BenchSet {
            title: title.to_string(),
            warmup_iters: warmup,
            measure_iters: measure,
            results: Vec::new(),
        }
    }

    /// Preset for slow end-to-end benches (single iteration, no warmup).
    pub fn slow(title: &str) -> BenchSet {
        BenchSet { warmup_iters: 0, measure_iters: 1, ..BenchSet::new(title) }
    }

    /// Measure `f`, discarding its output.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_items(name, None, &mut f)
    }

    /// Measure `f` that processes `items` items per call (throughput).
    pub fn bench_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_items(name, Some(items), &mut f)
    }

    fn bench_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // quick mode wins even over per-set overrides: CI smoke runs
        // every target at one iteration
        let (warmup, measure) = if quick_mode() {
            (0, 1)
        } else {
            (self.warmup_iters, self.measure_iters)
        };
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(measure);
        for _ in 0..measure.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            items,
        };
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print the set in a stable, greppable format.
    pub fn report(&self) {
        println!("\n== bench: {} ==", self.title);
        for r in &self.results {
            let tput = match r.throughput() {
                Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
                Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
                Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
                Some(t) => format!("  {t:8.2} item/s"),
                None => String::new(),
            };
            println!(
                "{:<32} mean {:>10} p50 {:>10} p95 {:>10}{}",
                r.name,
                fmt_secs(r.summary.mean),
                fmt_secs(r.summary.p50),
                fmt_secs(r.summary.p95),
                tput
            );
        }
    }

    /// Find a result by name (for cross-variant assertions in benches).
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = BenchSet::new("t");
        b.measure_iters = 5;
        b.warmup_iters = 1;
        let r = b.bench_throughput("sum", 1000.0, || {
            (0..1000u64).sum::<u64>()
        });
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(b.get("sum").is_some());
        b.report(); // smoke: must not panic
    }

    #[test]
    fn fmt_is_human() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}

//! Run metrics: per-round records, communication ledger, curves, writers.

use std::fmt::Write as _;

/// One aggregation round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// simulated wall-clock at the end of this round (seconds)
    pub sim_secs: f64,
    /// cumulative wire bytes (up + down + distribution)
    pub wire_bytes: u64,
    /// mean local training loss across platforms this round
    pub train_loss: f32,
    /// held-out eval loss (None between eval rounds)
    pub eval_loss: Option<f32>,
    /// held-out next-token accuracy in [0,1]
    pub eval_acc: Option<f64>,
    /// per-platform compute seconds this round (load diagnostics; async
    /// pseudo-rounds report the compute behind the updates applied in
    /// the round's window)
    pub platform_secs: Vec<f64>,
    /// cumulative DP epsilon after this round
    pub epsilon: f64,
    /// partition generation in effect
    pub partition_gen: u64,
}

/// Aggregate outcome of a run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub history: Vec<RoundRecord>,
    pub rounds_run: usize,
    pub sim_secs: f64,
    pub wire_bytes: u64,
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    pub final_eval_acc: f64,
    pub reached_target: bool,
    /// real (host) seconds spent inside PJRT/aggregation — profiling
    pub host_compute_secs: f64,
}

impl RunResult {
    /// Simulated training time in hours (Table 2 column).
    pub fn sim_hours(&self) -> f64 {
        self.sim_secs / 3600.0
    }

    /// Communication overhead in GB (Table 2 column).
    pub fn comm_gb(&self) -> f64 {
        self.wire_bytes as f64 / 1e9
    }

    /// Convergence accuracy in percent (Table 3 column).
    pub fn acc_pct(&self) -> f64 {
        self.final_eval_acc * 100.0
    }

    /// Loss/accuracy curve as CSV (round, sim_hours, comm_gb, train_loss,
    /// eval_loss, eval_acc).
    pub fn curve_csv(&self) -> String {
        let mut s = String::from(
            "round,sim_hours,comm_gb,train_loss,eval_loss,eval_acc\n",
        );
        for r in &self.history {
            let _ = writeln!(
                s,
                "{},{:.4},{:.4},{:.4},{},{}",
                r.round,
                r.sim_secs / 3600.0,
                r.wire_bytes as f64 / 1e9,
                r.train_loss,
                r.eval_loss.map_or(String::new(), |x| format!("{x:.4}")),
                r.eval_acc.map_or(String::new(), |x| format!("{x:.4}")),
            );
        }
        s
    }

    /// Latest eval numbers walking back from the end.
    pub fn last_eval(&self) -> Option<(f32, f64)> {
        self.history
            .iter()
            .rev()
            .find_map(|r| r.eval_loss.map(|l| (l, r.eval_acc.unwrap_or(0.0))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, eval: Option<(f32, f64)>) -> RoundRecord {
        RoundRecord {
            round,
            sim_secs: round as f64 * 60.0,
            wire_bytes: round as u64 * 1_000_000,
            train_loss: 4.0 - round as f32 * 0.1,
            eval_loss: eval.map(|e| e.0),
            eval_acc: eval.map(|e| e.1),
            platform_secs: vec![1.0, 1.1],
            epsilon: 0.0,
            partition_gen: 0,
        }
    }

    fn result() -> RunResult {
        RunResult {
            name: "t".into(),
            history: vec![
                record(1, None),
                record(2, Some((3.5, 0.3))),
                record(3, None),
            ],
            rounds_run: 3,
            sim_secs: 7200.0,
            wire_bytes: 4_500_000_000,
            final_train_loss: 3.7,
            final_eval_loss: 3.5,
            final_eval_acc: 0.3,
            reached_target: false,
            host_compute_secs: 1.0,
        }
    }

    #[test]
    fn unit_conversions() {
        let r = result();
        assert!((r.sim_hours() - 2.0).abs() < 1e-12);
        assert!((r.comm_gb() - 4.5).abs() < 1e-12);
        assert!((r.acc_pct() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = result().curve_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[2].contains("3.5"));
        // eval columns empty on non-eval rounds
        assert!(lines[1].ends_with(",,"));
    }

    #[test]
    fn last_eval_walks_back() {
        let r = result();
        let (loss, acc) = r.last_eval().unwrap();
        assert_eq!(loss, 3.5);
        assert_eq!(acc, 0.3);
    }
}

//! Run metrics: per-round records, communication ledger, curves, writers.

use std::fmt::Write as _;

use crate::cost::CostBreakdown;
use crate::netsim::LinkClass;
use crate::util::json::Json;

/// One aggregation round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// simulated wall-clock at the end of this round (seconds)
    pub sim_secs: f64,
    /// cumulative wire bytes (up + down + distribution)
    pub wire_bytes: u64,
    /// cumulative wire bytes split by link class, indexed by
    /// [`LinkClass::index`] — keeps the streamed curve schema-identical
    /// to [`RunResult`]'s per-class split
    pub wire_bytes_class: [u64; 3],
    /// mean local training loss across platforms this round
    pub train_loss: f32,
    /// held-out eval loss (None between eval rounds)
    pub eval_loss: Option<f32>,
    /// held-out next-token accuracy in [0,1]
    pub eval_acc: Option<f64>,
    /// per-platform compute seconds this round (load diagnostics; async
    /// pseudo-rounds report the compute behind the updates applied in
    /// the round's window)
    pub platform_secs: Vec<f64>,
    /// cumulative DP epsilon after this round
    pub epsilon: f64,
    /// partition generation in effect
    pub partition_gen: u64,
    /// roster size when the round committed — elastic membership
    /// (worker-leave/worker-join faults) shrinks and regrows this
    pub active_members: usize,
    /// this round's dollar bill (compute + egress, per cloud and class)
    pub cost: CostBreakdown,
    /// cumulative dollars at the end of this round (incl. setup)
    pub cum_cost_usd: f64,
}

impl RoundRecord {
    /// Header line of the curve CSV ([`RoundRecord::csv_row`] columns).
    pub const CSV_HEADER: &'static str = "round,sim_hours,comm_gb,intra_az_gb,\
         intra_region_gb,inter_region_gb,cost_usd,train_loss,active,\
         eval_loss,eval_acc\n";

    /// One curve-CSV row (no trailing newline) — the ONE encoder shared
    /// by [`RunResult::curve_csv`] and the coordinator's streaming
    /// `--history-csv` sink, so a streamed curve is byte-identical to a
    /// post-hoc one (same columns, incl. dollars and the per-class
    /// byte split).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{},{},{}",
            self.round,
            self.sim_secs / 3600.0,
            self.wire_bytes as f64 / 1e9,
            self.wire_bytes_class[0] as f64 / 1e9,
            self.wire_bytes_class[1] as f64 / 1e9,
            self.wire_bytes_class[2] as f64 / 1e9,
            self.cum_cost_usd,
            self.train_loss,
            self.active_members,
            self.eval_loss.map_or(String::new(), |x| format!("{x:.4}")),
            self.eval_acc.map_or(String::new(), |x| format!("{x:.4}")),
        )
    }
}

/// Aggregate outcome of a run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub history: Vec<RoundRecord>,
    pub rounds_run: usize,
    pub sim_secs: f64,
    pub wire_bytes: u64,
    /// cumulative wire bytes split by link class, indexed by
    /// [`LinkClass::index`] — the single source of truth cost, tests and
    /// figures read (mirrors the WAN's per-link ledger; on a
    /// checkpoint-resumed run this and `cost` cover the resumed segment,
    /// while `wire_bytes`/`sim_secs` include the checkpointed totals)
    pub wire_bytes_class: [u64; 3],
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    pub final_eval_acc: f64,
    pub reached_target: bool,
    /// real (host) seconds spent inside PJRT/aggregation — profiling
    pub host_compute_secs: f64,
    /// the run's cumulative dollar bill (see [`crate::cost`])
    pub cost: CostBreakdown,
}

impl RunResult {
    /// Simulated training time in hours (Table 2 column).
    pub fn sim_hours(&self) -> f64 {
        self.sim_secs / 3600.0
    }

    /// Communication overhead in GB (Table 2 column).
    pub fn comm_gb(&self) -> f64 {
        self.wire_bytes as f64 / 1e9
    }

    /// Convergence accuracy in percent (Table 3 column).
    pub fn acc_pct(&self) -> f64 {
        self.final_eval_acc * 100.0
    }

    /// Total dollars billed (compute + egress, incl. setup).
    pub fn cost_usd(&self) -> f64 {
        self.cost.total_usd()
    }

    /// Egress dollars billed across clouds and classes.
    pub fn egress_usd(&self) -> f64 {
        self.cost.egress_total_usd()
    }

    /// Bytes that crossed links of `class` (per-link ledger split).
    pub fn wire_bytes_of(&self, class: LinkClass) -> u64 {
        self.wire_bytes_class[class.index()]
    }

    /// Loss/accuracy/cost curve as CSV (round, sim_hours, comm_gb,
    /// cost_usd, train_loss, eval_loss, eval_acc) — the figure series.
    pub fn curve_csv(&self) -> String {
        let mut s = String::from(RoundRecord::CSV_HEADER);
        for r in &self.history {
            let _ = writeln!(s, "{}", r.csv_row());
        }
        s
    }

    /// JSON summary (report artifact): headline numbers, the per-class
    /// wire-byte split and the dollar breakdown — one source of truth
    /// for cost, tests and figures.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("rounds_run", Json::num(self.rounds_run as f64)),
            ("sim_secs", Json::num(self.sim_secs)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
            (
                "wire_bytes_class",
                Json::obj(
                    LinkClass::ALL
                        .iter()
                        .map(|&c| {
                            (c.name(), Json::num(self.wire_bytes_of(c) as f64))
                        })
                        .collect(),
                ),
            ),
            ("final_train_loss", Json::num(self.final_train_loss as f64)),
            ("final_eval_loss", Json::num(self.final_eval_loss as f64)),
            ("final_eval_acc", Json::num(self.final_eval_acc)),
            ("reached_target", Json::Bool(self.reached_target)),
            ("cost", self.cost.to_json()),
        ])
    }

    /// Latest eval numbers walking back from the end.
    pub fn last_eval(&self) -> Option<(f32, f64)> {
        self.history
            .iter()
            .rev()
            .find_map(|r| r.eval_loss.map(|l| (l, r.eval_acc.unwrap_or(0.0))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, eval: Option<(f32, f64)>) -> RoundRecord {
        RoundRecord {
            round,
            sim_secs: round as f64 * 60.0,
            wire_bytes: round as u64 * 1_000_000,
            wire_bytes_class: [round as u64 * 600_000, 0, round as u64 * 400_000],
            train_loss: 4.0 - round as f32 * 0.1,
            eval_loss: eval.map(|e| e.0),
            eval_acc: eval.map(|e| e.1),
            platform_secs: vec![1.0, 1.1],
            epsilon: 0.0,
            partition_gen: 0,
            active_members: 2,
            cost: CostBreakdown::zero(2),
            cum_cost_usd: round as f64 * 0.5,
        }
    }

    fn result() -> RunResult {
        let mut cost = CostBreakdown::zero(2);
        cost.compute_usd = vec![10.0, 5.0];
        cost.egress_usd = vec![[0.5, 0.0, 2.0], [0.25, 0.0, 1.0]];
        RunResult {
            name: "t".into(),
            history: vec![
                record(1, None),
                record(2, Some((3.5, 0.3))),
                record(3, None),
            ],
            rounds_run: 3,
            sim_secs: 7200.0,
            wire_bytes: 4_500_000_000,
            wire_bytes_class: [3_000_000_000, 0, 1_500_000_000],
            final_train_loss: 3.7,
            final_eval_loss: 3.5,
            final_eval_acc: 0.3,
            reached_target: false,
            host_compute_secs: 1.0,
            cost,
        }
    }

    #[test]
    fn unit_conversions() {
        let r = result();
        assert!((r.sim_hours() - 2.0).abs() < 1e-12);
        assert!((r.comm_gb() - 4.5).abs() < 1e-12);
        assert!((r.acc_pct() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = result().curve_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[0].contains(",active,"));
        assert!(lines[2].contains("3.5"));
        // eval columns empty on non-eval rounds; the active-member count
        // sits just before them
        assert!(lines[1].ends_with(",2,,"));
    }

    #[test]
    fn last_eval_walks_back() {
        let r = result();
        let (loss, acc) = r.last_eval().unwrap();
        assert_eq!(loss, 3.5);
        assert_eq!(acc, 0.3);
    }

    #[test]
    fn cost_and_class_accessors() {
        let r = result();
        assert!((r.cost_usd() - 18.75).abs() < 1e-12);
        assert!((r.egress_usd() - 3.75).abs() < 1e-12);
        assert_eq!(r.wire_bytes_of(LinkClass::IntraAz), 3_000_000_000);
        assert_eq!(r.wire_bytes_of(LinkClass::InterRegion), 1_500_000_000);
        // the curve carries the per-class byte split and the cumulative
        // dollar column in one shared schema
        let csv = r.curve_csv();
        assert!(csv.starts_with(
            "round,sim_hours,comm_gb,intra_az_gb,intra_region_gb,\
             inter_region_gb,cost_usd,"
        ));
        assert!(csv.lines().nth(2).unwrap().contains("1.0000"));
    }

    #[test]
    fn json_summary_has_split_and_cost() {
        let j = result().to_json().to_string();
        assert!(j.contains("\"inter-region\":1500000000"), "{j}");
        assert!(j.contains("\"total_usd\":18.75"), "{j}");
        assert!(j.contains("\"egress_usd\":3.75"), "{j}");
        // round-trips through the JSON parser
        assert!(Json::parse(&j).is_ok());
    }
}

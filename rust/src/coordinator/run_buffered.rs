//! Buffered asynchronous hierarchy — the FedBuff-style schedule.
//!
//! Two nested asynchronous loops over the shared [`EventEngine`]:
//!
//! ```text
//! member w:  train vs gateway model ──codec/AZ hop──▶ gateway buffer
//! gateway c: buffer mixes each arrival with α₀/(1+staleness)·n_w/Σn;
//!            when every active member contributed once ──▶ ship cycle
//! leader:    apply cloud buffer with the async mixing rate (formula 4),
//!            unicast the fresh global back to that gateway
//! ```
//!
//! Gateways run *cycles*, not rounds: a cycle closes when every active
//! member of the cloud has contributed exactly once, the buffered
//! aggregate ships over the WAN, and the next cycle opens immediately —
//! fast members that lap the cycle stall with their update stashed until
//! the flush (at most one stash per member), which keeps the
//! exactly-once-per-cycle invariant secure aggregation needs. The leader
//! applies cloud-level buffers on arrival like the flat async scheduler
//! applies worker updates, so clouds never barrier against each other.
//!
//! With secure aggregation each cloud gets its own pairwise-mask session
//! over its *active* members ([`Coordinator::rekey_secure`]): the
//! gateway sees only masked member contributions and the masks cancel in
//! the completed buffer sum, so the shipped aggregate is clean and the
//! gateway learns nothing but the cloud total. Every roster change
//! aborts the dirty cloud's in-progress cycle
//! ([`Coordinator::buffered_roster_repair`]) — a partially-summed buffer
//! under the old roster could never unmask.
//!
//! Pseudo-round accounting matches the flat async loop: one round ==
//! `n_clouds` leader applies, and each boundary WAL-snapshots the full
//! scheduler state (queues, buffers, stashes, clamps) so a crash resumes
//! bit-identically.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::aggregation::ClientUpdate;
use crate::coordinator::build::Coordinator;
use crate::coordinator::engine::EventEngine;
use crate::metrics::{RoundRecord, RunResult};
use crate::model::ParamSet;
use crate::runtime::ComputeBackend;

/// Buffered-scheduler events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum BufEv {
    /// worker finished local training (`gen` guards against stale events
    /// after a roster repair re-kicked the worker)
    Member { worker: usize, gen: u64 },
    /// a cloud's buffered aggregate reached the leader
    Cloud { cloud: usize },
    /// a fresh global model reached a cloud's gateway
    Params { cloud: usize },
}

/// One gateway's buffered-cycle state.
pub(crate) struct GwState {
    /// the (lagged) model this cloud's members train against
    pub(crate) params: ParamSet,
    /// leader version `params` corresponds to (staleness bookkeeping)
    pub(crate) version: u64,
    /// current buffer cycle — also the mask round of the per-cloud
    /// secure-aggregation session
    pub(crate) cycle: u64,
    /// the mixing buffer (None = empty)
    pub(crate) buf: Option<ParamSet>,
    /// Σ mean_loss · n_samples over the buffered contributions
    pub(crate) buf_loss: f64,
    /// Σ n_samples over the buffered contributions
    pub(crate) buf_samples: usize,
    /// contributed-to-current-cycle flags, indexed by global worker id
    pub(crate) contributed: Vec<bool>,
    /// Σ n_samples over the cloud's active members (weight normalizer,
    /// fixed for the duration of one cycle)
    pub(crate) ns_total: f64,
    /// latest member-update arrival at this gateway — the earliest time
    /// a completed buffer can start its WAN leg
    pub(crate) last_arrive: f64,
    /// FIFO clamps: a later cycle's buffer (or model) cannot overtake an
    /// earlier one on the same gateway↔leader pipe
    pub(crate) up_clamp: f64,
    pub(crate) down_clamp: f64,
}

/// One cloud-level buffered aggregate in flight to (or queued at) the
/// leader.
pub(crate) struct CloudUpdate {
    pub(crate) delta: ParamSet,
    pub(crate) mean_loss: f32,
    pub(crate) n_samples: usize,
    /// leader version the gateway's model had when the buffer shipped —
    /// the leader's staleness input
    pub(crate) base_version: u64,
}

/// Full mutable state of the buffered scheduler, WAL-snapshotted at
/// every pseudo-round boundary (see `wal_state.rs`).
pub(crate) struct BufState {
    /// per worker: update in flight (delta, mean_loss, compute_secs)
    pub(crate) pending: Vec<Option<(ParamSet, f32, f64)>>,
    /// per worker: a second same-cycle update parked until the flush
    /// (delta, mean_loss) — the member stalls while this is Some
    pub(crate) stash: Vec<Option<(ParamSet, f32)>>,
    /// per worker: kick generation (stale-event guard across repairs)
    pub(crate) kick_gen: Vec<u64>,
    /// per worker: the gateway cycle its in-flight update trained under
    pub(crate) base_cycle: Vec<u64>,
    pub(crate) gw: Vec<GwState>,
    /// per cloud: shipped buffers awaiting leader application (FIFO)
    pub(crate) cloud_q: Vec<VecDeque<CloudUpdate>>,
    /// per cloud: fresh (model, version) pairs in flight to the gateway
    pub(crate) param_q: Vec<VecDeque<(ParamSet, u64)>>,
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Run the buffered hierarchy for `cfg.rounds * n_clouds` leader
    /// applies (one pseudo-round == every cloud's buffer landing once on
    /// average, mirroring the flat async loop's granularity).
    pub(crate) fn run_buffered(&mut self) -> Result<RunResult> {
        let n = self.workers.len();
        let n_clouds = self.cluster.n_clouds();
        let total = self.cfg.rounds * n_clouds;

        let mut engine: EventEngine<BufEv>;
        let mut st: BufState;
        let mut applies: usize;
        // compute seconds behind the updates picked up this pseudo-round
        let mut round_compute = vec![0.0f64; n];

        if let Some(snap) = self.buffered_resume.take() {
            // WAL resume: replay the queue in pop order onto a fresh
            // engine (seq numbers reassigned densely, relative order —
            // and so every future pop — preserved exactly)
            engine = EventEngine::new(snap.now);
            for (at, ev) in snap.queued {
                engine.at(at, ev);
            }
            st = snap.state;
            applies = self.rounds_done * n_clouds;
            if applies < total {
                // faults due at the boundary the crash interrupted (the
                // crash event itself was stripped on resume)
                self.apply_faults(self.rounds_done)?;
                self.buffered_roster_repair(&mut engine, &mut st)?;
            }
        } else {
            engine = EventEngine::new(self.sim_secs);
            applies = 0;
            // round-0 faults strike before anything starts; the initial
            // kicks below already cover the post-fault roster, so no
            // cycle exists to abort yet
            self.apply_faults(0)?;
            self.roster_dirty.clear();
            st = BufState {
                pending: (0..n).map(|_| None).collect(),
                stash: (0..n).map(|_| None).collect(),
                kick_gen: vec![0; n],
                base_cycle: vec![0; n],
                gw: (0..n_clouds)
                    .map(|c| GwState {
                        params: self.global.clone(),
                        version: self.global_version,
                        cycle: 0,
                        buf: None,
                        buf_loss: 0.0,
                        buf_samples: 0,
                        contributed: vec![false; n],
                        ns_total: self
                            .cluster
                            .active_members(c)
                            .iter()
                            .map(|&m| self.workers[m].n_samples as f64)
                            .sum(),
                        last_arrive: self.sim_secs,
                        up_clamp: self.sim_secs,
                        down_clamp: self.sim_secs,
                    })
                    .collect(),
                cloud_q: (0..n_clouds).map(|_| VecDeque::new()).collect(),
                param_q: (0..n_clouds).map(|_| VecDeque::new()).collect(),
            };
            // kick every active member; the model was distributed at
            // setup, so the first cycle pays no downlink
            let start = self.sim_secs;
            for w in self.cluster.active_nodes() {
                let c = self.cluster.cloud_of(w);
                self.buf_kick(&mut engine, &mut st, c, w, start, false)?;
            }
        }

        let mut train_loss_acc = 0.0f32;
        let mut reached = false;
        while applies < total {
            match engine.pop().expect("buffered queue nonempty") {
                BufEv::Member { worker: w, gen } => {
                    if gen != st.kick_gen[w] || !self.cluster.is_active(w) {
                        // aborted by a roster repair (or the node was
                        // preempted mid-flight): the work is lost
                        continue;
                    }
                    let (update, mean_loss, compute_secs) =
                        st.pending[w].take().expect("pending update");
                    round_compute[w] += compute_secs;
                    let c = self.cluster.cloud_of(w);
                    let gw_node = self.cluster.gateway(c);
                    let now = engine.now();
                    // gateway members loop back through the codec;
                    // others pay the intra-cloud hop
                    let (delivered, up_secs) = if w == gw_node {
                        (self.up[w].codec_loopback(&update)?, 0.0)
                    } else {
                        let d = self.up[w].send_update(
                            &update,
                            mean_loss,
                            self.workers[w].n_samples,
                            1.0,
                            &mut self.wan,
                        )?;
                        self.wire_bytes += d.wire_bytes;
                        (d.update, d.secs)
                    };
                    let arrive = now + up_secs;
                    self.sim_secs = self.sim_secs.max(arrive);
                    st.gw[c].last_arrive = st.gw[c].last_arrive.max(arrive);
                    if st.gw[c].contributed[w] {
                        // second update inside one cycle: stall until
                        // the flush drains the stash (exactly-once)
                        st.stash[w] = Some((delivered, mean_loss));
                    } else {
                        self.buf_contribute(&mut st, c, w, delivered, mean_loss);
                        if self.buf_cycle_complete(&st, c) {
                            self.buf_flush(&mut engine, &mut st, c)?;
                            // the member that completed the cycle
                            // resumes under the fresh cycle
                            let start = st.gw[c].last_arrive.max(engine.now());
                            self.buf_kick(&mut engine, &mut st, c, w, start, true)?;
                        } else {
                            self.buf_kick(&mut engine, &mut st, c, w, arrive, true)?;
                        }
                    }
                }
                BufEv::Cloud { cloud: c } => {
                    let cu =
                        st.cloud_q[c].pop_front().expect("shipped buffer queued");
                    self.sim_secs = self.sim_secs.max(engine.now());
                    // --- apply with the staleness discount (formula 4),
                    // cloud-level
                    let staleness = self.global_version - cu.base_version;
                    let u = ClientUpdate {
                        worker: self.cluster.gateway(c),
                        n_samples: cu.n_samples,
                        local_loss: cu.mean_loss,
                        delta: cu.delta,
                        staleness,
                    };
                    let t0 = Instant::now();
                    self.aggregator.apply_one(&mut self.global, &u);
                    self.host_secs += t0.elapsed().as_secs_f64();
                    self.accountant.record_round();
                    self.global_version += 1;
                    applies += 1;
                    train_loss_acc += cu.mean_loss;

                    // --- unicast the fresh model back to this gateway
                    let gw_node = self.cluster.gateway(c);
                    let secs = if gw_node == self.leader {
                        0.0
                    } else {
                        let (secs, wire) = self.gw_down[c]
                            .send_params(&self.global, &mut self.wan)?;
                        self.wire_bytes += wire;
                        secs
                    };
                    let arrival =
                        (engine.now() + secs).max(st.gw[c].down_clamp);
                    st.gw[c].down_clamp = arrival;
                    st.param_q[c]
                        .push_back((self.global.clone(), self.global_version));
                    engine.at(arrival, BufEv::Params { cloud: c });
                    self.sim_secs = self.sim_secs.max(arrival);

                    // --- pseudo-round bookkeeping: every n_clouds applies
                    if applies % n_clouds == 0 {
                        let round = applies / n_clouds - 1;
                        let do_eval = round % self.cfg.eval_every.max(1) == 0
                            || applies == total;
                        let (eval_loss, eval_acc) = if do_eval {
                            let (l, a) = self.evaluate()?;
                            (Some(l), Some(a))
                        } else {
                            (None, None)
                        };
                        let platform_secs = std::mem::replace(
                            &mut round_compute,
                            vec![0.0; n],
                        );
                        let cost = self.cost_observe(&platform_secs);
                        let record = RoundRecord {
                            round,
                            sim_secs: self.sim_secs,
                            wire_bytes: self.wire_bytes,
                            wire_bytes_class: self.wan_class_split(),
                            train_loss: train_loss_acc / n_clouds as f32,
                            eval_loss,
                            eval_acc,
                            platform_secs,
                            epsilon: self.accountant.epsilon(),
                            partition_gen: self.plan.generation,
                            active_members: self.cluster.n_active(),
                            cost,
                            cum_cost_usd: self
                                .cost_ledger
                                .cumulative()
                                .total_usd(),
                        };
                        let cum_cost = record.cum_cost_usd;
                        train_loss_acc = 0.0;
                        // snapshot the boundary durably before acting on
                        // it: round_compute/train_loss_acc are freshly
                        // zeroed, so queue + state capture everything
                        self.wal_append_buffered(&record, &engine, &st)?;
                        self.commit_round(record)?;
                        if let (Some(l), Some(t)) =
                            (eval_loss, self.cfg.target_loss)
                        {
                            if (l as f64) <= t {
                                reached = true;
                                break;
                            }
                        }
                        if let Some(budget) = self.cfg.target_cost {
                            if cum_cost >= budget {
                                log::info!(
                                    "pseudo-round {round}: cost budget \
                                     {budget} USD exhausted, stopping"
                                );
                                break;
                            }
                        }
                        if applies < total {
                            // next boundary's faults, then abort any
                            // cycle whose roster changed
                            self.apply_faults(applies / n_clouds)?;
                            self.buffered_roster_repair(&mut engine, &mut st)?;
                        }
                    }
                }
                BufEv::Params { cloud: c } => {
                    let (params, version) =
                        st.param_q[c].pop_front().expect("model in flight");
                    st.gw[c].params = params;
                    st.gw[c].version = version;
                }
            }
        }
        self.sim_events += engine.scheduled_total();
        self.finish(reached)
    }

    /// Start (or restart) local training for member `w` of cloud `c`
    /// against its gateway's current model. `pay_downlink` bills the
    /// gateway→member model transfer (everything but the initial
    /// kick-off, whose model arrived with the setup distribution).
    fn buf_kick(
        &mut self,
        engine: &mut EventEngine<BufEv>,
        st: &mut BufState,
        c: usize,
        w: usize,
        start: f64,
        pay_downlink: bool,
    ) -> Result<()> {
        let gw_node = self.cluster.gateway(c);
        let down_secs = if pay_downlink && w != gw_node {
            let (secs, wire) =
                self.down[w].send_params(&st.gw[c].params, &mut self.wan)?;
            self.wire_bytes += wire;
            secs
        } else {
            0.0
        };
        st.base_cycle[w] = st.gw[c].cycle;
        let kind = self.cfg.aggregation.update_kind();
        let model = st.gw[c].params.clone();
        let r = self.workers[w].local_round(
            self.backend,
            &model,
            kind,
            self.cfg.local_steps,
            self.cfg.local_lr,
            self.cfg.base_step_secs,
            &self.cfg.dp,
        )?;
        self.host_secs += r.host_secs;
        engine.at(
            start + down_secs + r.compute_secs,
            BufEv::Member { worker: w, gen: st.kick_gen[w] },
        );
        st.pending[w] = Some((r.update, r.mean_loss, r.compute_secs));
        Ok(())
    }

    /// Mix one delivered member update into its gateway's buffer with
    /// the FedBuff weight `α₀/(1+staleness) · n_w/Σn`. With secure
    /// aggregation the scaled update is masked under the per-cloud
    /// session first — the gateway's buffer then holds a sum that only
    /// unmasks once every active member has contributed.
    fn buf_contribute(
        &mut self,
        st: &mut BufState,
        c: usize,
        w: usize,
        delta: ParamSet,
        mean_loss: f32,
    ) {
        let cycle = st.gw[c].cycle;
        let staleness = cycle - st.base_cycle[w];
        let alpha = self
            .hier
            .as_ref()
            .expect("buffered mode is hierarchical")
            .mixing_rate(staleness);
        let n_w = self.workers[w].n_samples;
        let weight = alpha * (n_w as f64 / st.gw[c].ns_total) as f32;
        let t0 = Instant::now();
        let mut scaled = delta;
        scaled.scale(weight);
        let contrib = if self.cfg.secure_agg {
            let idx = self.sa_cloud_index[w]
                .expect("contributing member is in its cloud's session");
            let masked =
                self.secure_clouds[c].mask(idx, cycle, &scaled.to_flat());
            ParamSet::from_flat(&masked.data, &scaled)
                .expect("shape preserved")
        } else {
            scaled
        };
        let gw = &mut st.gw[c];
        match gw.buf.as_mut() {
            Some(b) => b.axpy(1.0, &contrib),
            None => gw.buf = Some(contrib),
        }
        self.host_secs += t0.elapsed().as_secs_f64();
        gw.buf_loss += mean_loss as f64 * n_w as f64;
        gw.buf_samples += n_w;
        gw.contributed[w] = true;
    }

    /// Has every active member of cloud `c` contributed to the current
    /// cycle?
    fn buf_cycle_complete(&self, st: &BufState, c: usize) -> bool {
        self.cluster
            .active_members(c)
            .iter()
            .all(|&m| st.gw[c].contributed[m])
    }

    /// Close cloud `c`'s cycle: assert exactly-once coverage of the
    /// active roster (the secure masks cancel iff this holds), ship the
    /// buffered aggregate toward the leader on the FIFO gateway pipe,
    /// open the next cycle and drain stalled members into it.
    fn buf_flush(
        &mut self,
        engine: &mut EventEngine<BufEv>,
        st: &mut BufState,
        c: usize,
    ) -> Result<()> {
        let active = self.cluster.active_members(c);
        let covered = active.iter().filter(|&&m| st.gw[c].contributed[m]).count();
        assert_eq!(
            covered,
            active.len(),
            "buffered flush must cover every active member of cloud {c}"
        );
        if self.cfg.secure_agg {
            assert_eq!(
                self.secure_clouds[c].n(),
                active.len(),
                "cloud {c}'s secure session must span its active roster"
            );
        }
        let gw_node = self.cluster.gateway(c);
        let (delta, mean_loss, n_samples) = {
            let gw = &mut st.gw[c];
            let delta = gw.buf.take().expect("completed cycle has a buffer");
            let mean_loss =
                (gw.buf_loss / gw.buf_samples.max(1) as f64) as f32;
            (delta, mean_loss, gw.buf_samples)
        };
        let (delivered, secs) = if gw_node == self.leader {
            (self.gw_up[c].codec_loopback(&delta)?, 0.0)
        } else {
            let d = self.gw_up[c].send_update(
                &delta,
                mean_loss,
                n_samples,
                1.0,
                &mut self.wan,
            )?;
            self.wire_bytes += d.wire_bytes;
            (d.update, d.secs)
        };
        {
            let gw = &mut st.gw[c];
            // the buffer is complete at the last member arrival; the WAN
            // leg cannot overtake the previous cycle's
            let ready = gw.last_arrive.max(engine.now());
            let arrival = (ready + secs).max(gw.up_clamp);
            gw.up_clamp = arrival;
            engine.at(arrival, BufEv::Cloud { cloud: c });
            st.cloud_q[c].push_back(CloudUpdate {
                delta: delivered,
                mean_loss,
                n_samples,
                base_version: gw.version,
            });
            // open the next cycle
            gw.cycle += 1;
            gw.buf_loss = 0.0;
            gw.buf_samples = 0;
            gw.contributed.fill(false);
        }
        st.gw[c].ns_total = active
            .iter()
            .map(|&m| self.workers[m].n_samples as f64)
            .sum();
        self.sim_secs = self.sim_secs.max(st.gw[c].up_clamp);
        // drain stalled members into the fresh cycle in worker-id order
        // (cannot re-complete it: the flush-triggering member has not
        // contributed yet)
        for &m in &active {
            if let Some((d, l)) = st.stash[m].take() {
                self.buf_contribute(st, c, m, d, l);
                let start = st.gw[c].last_arrive.max(engine.now());
                self.buf_kick(engine, st, c, m, start, true)?;
            }
        }
        Ok(())
    }

    /// Abort the in-progress cycle of every cloud whose roster changed
    /// at this boundary (`roster_dirty`, set by `roster_changed`): a
    /// buffer partially summed under the old roster's masks can never
    /// unmask, so the cycle restarts clean — buffer cleared, cycle
    /// bumped (fresh mask round), stalls dropped, in-flight member
    /// events invalidated via `kick_gen`, and every active member
    /// re-kicked from the gateway's current model. Already-shipped
    /// buffers stay valid: their masks cancelled at flush time.
    pub(crate) fn buffered_roster_repair(
        &mut self,
        engine: &mut EventEngine<BufEv>,
        st: &mut BufState,
    ) -> Result<()> {
        let mut dirty = std::mem::take(&mut self.roster_dirty);
        dirty.sort_unstable();
        dirty.dedup();
        for c in dirty {
            let active = self.cluster.active_members(c);
            {
                let gw = &mut st.gw[c];
                gw.cycle += 1;
                gw.buf = None;
                gw.buf_loss = 0.0;
                gw.buf_samples = 0;
                gw.contributed.fill(false);
            }
            st.gw[c].ns_total = active
                .iter()
                .map(|&m| self.workers[m].n_samples as f64)
                .sum();
            for m in self.cluster.cloud_members(c) {
                st.pending[m] = None;
                st.stash[m] = None;
                st.kick_gen[m] += 1;
            }
            let start = self.sim_secs;
            for &m in &active {
                self.buf_kick(engine, st, c, m, start, true)?;
            }
        }
        Ok(())
    }
}

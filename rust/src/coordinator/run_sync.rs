//! Synchronous round loop: FedAvg / dynamic weighted / gradient
//! aggregation with the full Figure-2 partitioning cycle.

use std::time::Instant;

use anyhow::Result;

use crate::aggregation::ClientUpdate;
use crate::coordinator::build::Coordinator;
use crate::metrics::{RoundRecord, RunResult};
use crate::runtime::ComputeBackend;

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Run synchronous rounds until `cfg.rounds` or the loss target.
    pub(crate) fn run_sync(&mut self) -> Result<RunResult> {
        let mut reached = false;
        for round in 0..self.cfg.rounds {
            let record = self.sync_round(round)?;
            let hit_target = match (record.eval_loss, self.cfg.target_loss) {
                (Some(l), Some(t)) => (l as f64) <= t,
                _ => false,
            };
            self.history.push(record);
            if hit_target {
                reached = true;
                log::info!(
                    "round {round}: eval loss target {:?} reached",
                    self.cfg.target_loss
                );
                break;
            }
        }
        self.finish(reached)
    }

    /// One synchronous round: local training on every platform →
    /// (DP → compress → encrypt → WAN) → barrier → aggregate → broadcast
    /// → monitor/re-partition.
    fn sync_round(&mut self, round: usize) -> Result<RoundRecord> {
        let base_steps = if self.cfg.adaptive_granularity {
            self.granularity.local_steps()
        } else {
            self.cfg.local_steps
        };
        let kind = self.cfg.aggregation.update_kind();

        // "local epoch over the partition" semantics: each platform's
        // step count tracks its shard share, so partition sizing controls
        // per-round load (the Figure-2 balancing lever)
        let total_samples: f64 = self
            .workers
            .iter()
            .map(|w| w.n_samples as f64)
            .sum();
        let proportional = self.cfg.proportional_local_work;
        let budget = (base_steps * self.workers.len()) as f64;
        let step_counts: Vec<usize> = self
            .workers
            .iter()
            .map(|w| {
                if proportional {
                    ((budget * w.n_samples as f64 / total_samples).round()
                        as usize)
                        .max(1)
                } else {
                    base_steps
                }
            })
            .collect();

        // --- phase 1: local training (platforms run in parallel in sim
        // time; sequentially on the host against the shared backend)
        let mut locals = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            let steps = step_counts[w];
            let r = self.workers[w].local_round(
                self.backend,
                &self.global,
                kind,
                steps,
                self.cfg.local_lr,
                self.cfg.base_step_secs,
                &self.cfg.dp,
            )?;
            self.host_secs += r.host_secs;
            locals.push(r);
        }

        // --- phase 2: uplink through the real pipeline
        let mut updates = Vec::with_capacity(self.workers.len());
        let mut platform_secs = Vec::with_capacity(self.workers.len());
        let mut round_wire = 0u64;
        for (w, local) in locals.iter().enumerate() {
            let (delivered, up_secs, wire) = if w == 0 {
                // leader-colocated platform: loopback, no WAN
                (local.update.clone(), 0.0, 0u64)
            } else {
                let d = self.up[w].send_update(
                    &local.update,
                    local.mean_loss,
                    self.workers[w].n_samples,
                    &mut self.wan,
                )?;
                (d.update, d.secs, d.wire_bytes)
            };
            round_wire += wire;
            platform_secs.push(local.compute_secs + up_secs);
            updates.push(ClientUpdate {
                worker: w,
                n_samples: self.workers[w].n_samples,
                local_loss: local.mean_loss,
                delta: delivered,
                staleness: 0,
            });
        }

        // --- phase 3: barrier + aggregation (leader host CPU measured)
        let barrier_secs =
            platform_secs.iter().cloned().fold(0.0f64, f64::max);
        let t0 = Instant::now();
        if self.secure.is_some() {
            let agg = self.secure_aggregate(&updates);
            // masked path: FedAvg-style application of the summed delta
            match self.cfg.aggregation.update_kind() {
                crate::aggregation::UpdateKind::ParamDelta => {
                    self.global.axpy(1.0, &agg);
                }
                crate::aggregation::UpdateKind::Gradient => {
                    // the masked sum is the weighted mean gradient
                    self.global.axpy(-self.cfg.server_lr, &agg);
                }
            }
        } else {
            self.aggregator.aggregate(&mut self.global, &updates);
        }
        let agg_host = t0.elapsed().as_secs_f64();
        self.host_secs += agg_host;
        self.accountant.record_round();
        self.global_version += 1;

        // --- phase 4: broadcast the new global model
        let mut bcast_secs = 0.0f64;
        for w in 1..self.workers.len() {
            let (secs, wire) = self.down[w].send_params(&self.global, &mut self.wan)?;
            bcast_secs = bcast_secs.max(secs);
            round_wire += wire;
        }

        self.wire_bytes += round_wire;
        self.sim_secs += barrier_secs + agg_host + bcast_secs;

        // --- phase 5: monitor & adjust (Figure-2 cycle)
        let compute_times: Vec<f64> =
            locals.iter().map(|l| l.compute_secs).collect();
        if self.cfg.adaptive_granularity {
            let comm = barrier_secs - compute_times.iter().cloned().fold(0.0, f64::max)
                + bcast_secs;
            self.granularity
                .observe(compute_times.iter().cloned().fold(0.0, f64::max), comm.max(0.0));
        }
        if self.monitor.observe(&compute_times) {
            let caps = self.monitor.capacity_estimates();
            if let Some(plan) =
                self.planner.replan(&self.corpus, &self.cluster, &caps)
            {
                log::info!(
                    "round {round}: re-partitioning (gen {} -> {}), caps {:?}",
                    self.plan.generation,
                    plan.generation,
                    caps
                );
                self.plan = plan;
                for (w, shard) in self.plan.shards.iter().enumerate() {
                    self.workers[w].set_shard(
                        &shard.tokens,
                        self.batch_size,
                        self.seq_len,
                        self.cfg.seed ^ self.plan.generation,
                    );
                }
                self.account_distribution()?;
            }
        }

        // --- eval
        let (eval_loss, eval_acc) = if round % self.cfg.eval_every.max(1) == 0
            || round + 1 == self.cfg.rounds
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        let train_loss = locals.iter().map(|l| l.mean_loss).sum::<f32>()
            / locals.len() as f32;
        log::debug!(
            "round {round}: train={train_loss:.3} eval={eval_loss:?} sim={:.0}s wire={}",
            self.sim_secs,
            self.wire_bytes
        );

        Ok(RoundRecord {
            round,
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            train_loss,
            eval_loss,
            eval_acc,
            platform_secs: compute_times,
            epsilon: self.accountant.epsilon(),
            partition_gen: self.plan.generation,
        })
    }
}

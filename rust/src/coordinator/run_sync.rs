//! Synchronous round loop: FedAvg / dynamic weighted / gradient
//! aggregation with the full Figure-2 partitioning cycle, driven by the
//! shared event engine (the barrier is simply "wait for every update's
//! arrival event").

use std::time::Instant;

use anyhow::Result;

use crate::aggregation::ClientUpdate;
use crate::coordinator::build::Coordinator;
use crate::coordinator::engine::EventEngine;
use crate::metrics::{RoundRecord, RunResult};
use crate::runtime::ComputeBackend;

/// Star-topology sync events.
enum Ev {
    /// worker finished local training
    ComputeDone(usize),
    /// worker's update reached the leader
    Arrived(usize),
    /// broadcast reached the worker
    BcastDone(usize),
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Run synchronous rounds until `cfg.rounds`, the loss target or the
    /// cost budget (star or hierarchical per the config). On a WAL
    /// resume the history is pre-populated and the loop picks up at the
    /// first un-logged round.
    pub(crate) fn run_sync(&mut self) -> Result<RunResult> {
        let mut reached = false;
        for round in self.rounds_done..self.cfg.rounds {
            self.apply_faults(round)?;
            let record = if self.hier.is_some() {
                if self.cfg.par_rounds {
                    self.hier_round_par(round)?
                } else {
                    self.hier_round(round)?
                }
            } else {
                self.sync_round(round)?
            };
            let hit_loss = match (record.eval_loss, self.cfg.target_loss) {
                (Some(l), Some(t)) => (l as f64) <= t,
                _ => false,
            };
            let hit_budget = match self.cfg.target_cost {
                Some(budget) => record.cum_cost_usd >= budget,
                None => false,
            };
            // log the round before acting on it: a crash after the stop
            // decision must resume into the identical decision
            self.wal_append_sync(&record)?;
            self.commit_round(record)?;
            if hit_loss {
                reached = true;
                log::info!(
                    "round {round}: eval loss target {:?} reached",
                    self.cfg.target_loss
                );
                break;
            }
            if hit_budget {
                log::info!(
                    "round {round}: cost budget {:?} USD exhausted, stopping",
                    self.cfg.target_cost
                );
                break;
            }
        }
        self.finish(reached)
    }

    /// One synchronous star round: local training on every active
    /// platform → (DP → compress → encrypt → WAN) → barrier → aggregate →
    /// broadcast → monitor/re-partition. Uplinks overlap with slower
    /// workers' compute; the barrier fires at the last arrival event.
    /// Inactive (preempted) members sit the round out entirely.
    fn sync_round(&mut self, round: usize) -> Result<RoundRecord> {
        let n = self.workers.len();
        let step_counts = self.local_step_counts();
        let round_start = self.sim_secs;
        let mut engine: EventEngine<Ev> = EventEngine::new(round_start);

        // --- phase 1: local training (platforms run in parallel in sim
        // time; sequentially on the host against the shared backend).
        // `locals[w]` is None for inactive members — they schedule no
        // events and the barrier waits only for the active set.
        let locals = self.train_all_workers(&step_counts)?;
        let n_active = locals.iter().flatten().count();
        for (w, r) in locals.iter().enumerate() {
            if let Some(r) = r {
                engine.at(round_start + r.compute_secs, Ev::ComputeDone(w));
            }
        }

        // --- phase 2: uplinks through the real pipeline, as events.
        // The leader-colocated worker's update still passes the codec
        // (loopback), skipping only the WAN/encrypt hop, so aggregation
        // sees uniformly-compressed updates.
        let mut updates: Vec<Option<ClientUpdate>> =
            (0..n).map(|_| None).collect();
        let mut round_wire = 0u64;
        let mut n_arrived = 0usize;
        while n_arrived < n_active {
            match engine.pop().expect("arrival events pending") {
                Ev::ComputeDone(w) => {
                    let local = locals[w].as_ref().expect("active trained");
                    let (delivered, up_secs, wire) = if w == self.leader {
                        (self.up[w].codec_loopback(&local.update)?, 0.0, 0)
                    } else {
                        let d = self.up[w].send_update(
                            &local.update,
                            local.mean_loss,
                            self.workers[w].n_samples,
                            1.0,
                            &mut self.wan,
                        )?;
                        (d.update, d.secs, d.wire_bytes)
                    };
                    round_wire += wire;
                    updates[w] = Some(ClientUpdate {
                        worker: w,
                        n_samples: self.workers[w].n_samples,
                        local_loss: local.mean_loss,
                        delta: delivered,
                        staleness: 0,
                    });
                    engine.after(up_secs, Ev::Arrived(w));
                }
                Ev::Arrived(_) => n_arrived += 1,
                Ev::BcastDone(_) => unreachable!("no broadcast yet"),
            }
        }
        let barrier_at = engine.now();
        let updates: Vec<ClientUpdate> =
            updates.into_iter().flatten().collect();
        debug_assert_eq!(updates.len(), n_active);

        // --- phase 3: aggregation at the barrier (leader host CPU is
        // profiled, not added to simulated time)
        let t0 = Instant::now();
        if self.secure.is_some() {
            let agg = self.secure_aggregate(&updates);
            self.apply_masked_aggregate(&agg);
        } else {
            self.aggregator.aggregate(&mut self.global, &updates);
        }
        self.host_secs += t0.elapsed().as_secs_f64();
        self.accountant.record_round();
        self.global_version += 1;

        // --- phase 4: broadcast the new global model (transfers overlap;
        // the round ends at the last delivery event). Departed members
        // receive nothing — a rejoining node trains against the then-
        // current global, delivered with its re-planned shard.
        for w in 0..n {
            if w == self.leader || !self.cluster.is_active(w) {
                continue; // hosts the global model already / preempted
            }
            let (secs, wire) =
                self.down[w].send_params(&self.global, &mut self.wan)?;
            round_wire += wire;
            engine.after(secs, Ev::BcastDone(w));
        }
        while let Some(_ev) = engine.pop() {
            debug_assert!(matches!(_ev, Ev::BcastDone(_)));
        }
        let round_end = engine.now();
        self.sim_events += engine.scheduled_total();

        // --- phase 5: totals, monitor & adjust (Figure-2 cycle), eval
        self.finalize_round(
            round,
            &locals,
            round_start,
            barrier_at,
            round_end,
            round_wire,
        )
    }
}

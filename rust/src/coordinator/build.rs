//! Coordinator construction and shared round machinery.

use anyhow::{Context, Result};

use crate::aggregation::{self, Aggregator, ClientUpdate};
use crate::cluster::ClusterSpec;
use crate::compress::Compressor;
use crate::config::ExperimentConfig;
use crate::crypto::SecureAggregator;
use crate::data::{BatchIter, SyntheticCorpus};
use crate::metrics::{RoundRecord, RunResult};
use crate::model::ParamSet;
use crate::netsim::Wan;
use crate::optimizer::Optimizer;
use crate::partition::{GranularityController, LoadMonitor, PartitionPlan, PartitionPlanner};
use crate::privacy::PrivacyAccountant;
use crate::runtime::ComputeBackend;
use crate::transport::Channel;
use crate::worker::CloudWorker;

/// Fraction of documents held out for evaluation.
const EVAL_FRACTION: f64 = 0.1;

/// The federation leader plus its simulated platforms.
pub struct Coordinator<'a, B: ComputeBackend + ?Sized> {
    pub cfg: ExperimentConfig,
    pub cluster: ClusterSpec,
    pub(crate) backend: &'a B,
    pub(crate) wan: Wan,
    pub(crate) workers: Vec<CloudWorker>,
    /// per-worker uplink / downlink channels (leader is node 0's colo;
    /// worker w uses WAN node w, leader node 0 — worker 0 is local)
    pub(crate) up: Vec<Channel>,
    pub(crate) down: Vec<Channel>,
    pub(crate) global: ParamSet,
    pub(crate) aggregator: Box<dyn Aggregator>,
    pub(crate) monitor: LoadMonitor,
    pub(crate) granularity: GranularityController,
    pub(crate) planner: PartitionPlanner,
    pub(crate) plan: PartitionPlan,
    pub(crate) accountant: PrivacyAccountant,
    pub(crate) secure: Option<SecureAggregator>,
    pub(crate) eval_iter: BatchIter,
    pub(crate) corpus: SyntheticCorpus,
    // running totals
    pub(crate) sim_secs: f64,
    pub(crate) wire_bytes: u64,
    pub(crate) host_secs: f64,
    pub(crate) global_version: u64,
    pub(crate) history: Vec<RoundRecord>,
    pub(crate) batch_size: usize,
    pub(crate) seq_len: usize,
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Build a coordinator: generates the corpus, plans the partition,
    /// distributes shards (counting the encrypted distribution bytes) and
    /// wires the channels.
    ///
    /// `batch_size`/`seq_len` must match the backend's compiled shapes.
    pub fn new(
        cfg: ExperimentConfig,
        cluster: ClusterSpec,
        backend: &'a B,
        init: ParamSet,
        batch_size: usize,
        seq_len: usize,
    ) -> Result<Coordinator<'a, B>> {
        cfg.validate()?;
        anyhow::ensure!(cluster.n() >= 1, "need at least one platform");

        let corpus = SyntheticCorpus::generate(&cfg.corpus);
        let n_eval = ((corpus.docs.len() as f64 * EVAL_FRACTION) as usize).max(1);
        let train_corpus = SyntheticCorpus {
            docs: corpus.docs[..corpus.docs.len() - n_eval].to_vec(),
            n_topics: corpus.n_topics,
        };
        let eval_tokens: Vec<i32> = {
            let tok = crate::data::CharTokenizer;
            corpus.docs[corpus.docs.len() - n_eval..]
                .iter()
                .flat_map(|d| tok.encode(&d.text))
                .collect()
        };
        let eval_iter =
            BatchIter::new(&eval_tokens, batch_size, seq_len, cfg.seed ^ 0xE7A1);

        // Capacities are *learned*, not assumed: the initial plan uses
        // uniform estimates; the load monitor's measurements drive
        // re-planning ("Monitor and Adjust in Real-Time", Figure 2).
        let capacities: Vec<f64> = vec![1.0; cluster.n()];
        let mut planner = PartitionPlanner::new(cfg.partition, cfg.seed);
        let plan = planner.plan(&train_corpus, &cluster, &capacities);

        let wan = Wan::from_cluster(&cluster, cfg.seed);
        let n_params = init.numel();
        let secret: Option<&[u8]> =
            cfg.encrypt.then_some(b"crossfed-session-secret".as_slice());

        let mut workers = Vec::with_capacity(cluster.n());
        let mut up = Vec::with_capacity(cluster.n());
        let mut down = Vec::with_capacity(cluster.n());
        for (i, platform) in cluster.platforms.iter().enumerate() {
            workers.push(CloudWorker::new(
                i,
                platform.clone(),
                &plan.shards[i].tokens,
                batch_size,
                seq_len,
                cfg.seed,
            ));
            up.push(Channel::new(
                i,
                0,
                cfg.protocol,
                cfg.streams,
                Compressor::new(cfg.compression, cfg.seed ^ i as u64),
                cfg.error_feedback,
                n_params,
                secret,
            ));
            down.push(Channel::new(
                0,
                i,
                cfg.protocol,
                cfg.streams,
                Compressor::new(crate::compress::Compression::None, 0),
                false,
                n_params,
                secret,
            ));
        }

        let secure = cfg
            .secure_agg
            .then(|| SecureAggregator::new(cluster.n(), b"crossfed-sa"));

        let aggregator = aggregation::build(
            cfg.aggregation,
            Optimizer::new(cfg.server_opt, cfg.server_lr),
        );
        let monitor = LoadMonitor::new(cluster.n(), 0.25, 3);
        let granularity = GranularityController::new(
            cfg.local_steps,
            1,
            (cfg.local_steps * 16).max(cfg.local_steps),
        );
        let accountant = PrivacyAccountant::new(cfg.dp);

        let mut coord = Coordinator {
            monitor,
            granularity,
            accountant,
            secure,
            aggregator,
            cfg,
            cluster,
            backend,
            wan,
            workers,
            up,
            down,
            global: init,
            planner,
            plan,
            eval_iter,
            corpus: train_corpus,
            sim_secs: 0.0,
            wire_bytes: 0,
            host_secs: 0.0,
            global_version: 0,
            history: Vec::new(),
            batch_size,
            seq_len,
        };
        // initial distribution: every platform receives its (encrypted)
        // shard once — "Ensure Data Security" phase of the Figure-2 cycle
        coord.account_distribution()?;
        Ok(coord)
    }

    /// Charge the WAN for distributing the current plan's shards.
    pub(crate) fn account_distribution(&mut self) -> Result<()> {
        let mut max_secs = 0.0f64;
        for shard in &self.plan.shards {
            if shard.platform == 0 {
                continue; // leader-colocated: local copy
            }
            let bytes = (shard.n_tokens() * 4) as u64
                + if self.plan.require_encryption {
                    crate::crypto::SEAL_OVERHEAD_BYTES
                } else {
                    0
                };
            let stats = self.wan.transfer(
                0,
                shard.platform,
                bytes,
                self.cfg.protocol,
                self.cfg.streams,
            );
            self.wire_bytes += stats.wire_bytes;
            max_secs = max_secs.max(stats.time_s);
        }
        self.sim_secs += max_secs;
        Ok(())
    }

    /// Held-out evaluation of the global model.
    pub(crate) fn evaluate(&mut self) -> Result<(f32, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for _ in 0..self.cfg.eval_batches.max(1) {
            let batch = self.eval_iter.next_batch();
            let out = self
                .backend
                .eval(&self.global, &batch)
                .context("eval step")?;
            loss_sum += out.loss as f64;
            correct += out.n_correct as u64;
            total += out.n_total as u64;
        }
        Ok((
            (loss_sum / self.cfg.eval_batches.max(1) as f64) as f32,
            correct as f64 / total.max(1) as f64,
        ))
    }

    /// Secure-aggregation path: mask pre-scaled updates, sum, unmask.
    /// Returns the aggregate delta the leader applies.
    pub(crate) fn secure_aggregate(
        &mut self,
        updates: &[ClientUpdate],
    ) -> ParamSet {
        let sa = self.secure.as_ref().expect("secure agg enabled");
        let n_total: f64 = updates.iter().map(|u| u.n_samples as f64).sum();
        let round = self.global_version;
        let masked: Vec<crate::crypto::MaskedUpdate> = updates
            .iter()
            .map(|u| {
                // pre-scale by n_i/n so the masked *sum* is the FedAvg /
                // mean-gradient aggregate
                let mut scaled = u.delta.clone();
                scaled.scale((u.n_samples as f64 / n_total) as f32);
                sa.mask(u.worker, round, &scaled.to_flat())
            })
            .collect();
        let sum = sa.unmask_sum(&masked);
        ParamSet::from_flat(&sum, &updates[0].delta).expect("shape preserved")
    }

    /// Current partition generation (diagnostics / tests).
    pub fn partition_generation(&self) -> u64 {
        self.plan.generation
    }

    /// Global model (read access for examples / tests).
    pub fn global(&self) -> &ParamSet {
        &self.global
    }

    /// Total simulated seconds so far.
    pub fn sim_secs(&self) -> f64 {
        self.sim_secs
    }

    /// Total wire bytes so far.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes
    }

    /// Snapshot the current run state (see [`crate::checkpoint`]).
    pub fn checkpoint(&self) -> crate::checkpoint::Checkpoint {
        crate::checkpoint::Checkpoint {
            params: self.global.clone(),
            round: self.history.len(),
            global_version: self.global_version,
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            experiment: self.cfg.name.clone(),
        }
    }

    /// Restore model + counters from a checkpoint (shape-checked).
    pub fn restore(&mut self, ckpt: &crate::checkpoint::Checkpoint) -> Result<()> {
        ckpt.check_compatible(&self.global)?;
        self.global = ckpt.params.clone();
        self.global_version = ckpt.global_version;
        self.sim_secs = ckpt.sim_secs;
        self.wire_bytes = ckpt.wire_bytes;
        Ok(())
    }

    /// Run the configured experiment to completion.
    pub fn run(&mut self) -> Result<RunResult> {
        if self.aggregator.is_async() {
            self.run_async()
        } else {
            self.run_sync()
        }
    }

    pub(crate) fn finish(&mut self, reached_target: bool) -> Result<RunResult> {
        let (eval_loss, eval_acc) = self.evaluate()?;
        let final_train = self
            .history
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f32::NAN);
        Ok(RunResult {
            name: self.cfg.name.clone(),
            history: self.history.clone(),
            rounds_run: self.history.len(),
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            final_train_loss: final_train,
            final_eval_loss: eval_loss,
            final_eval_acc: eval_acc,
            reached_target,
            host_compute_secs: self.host_secs,
        })
    }
}

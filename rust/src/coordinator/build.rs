//! Coordinator construction and shared round machinery.

use std::fs::File;
use std::io::{BufWriter, Write as _};

use anyhow::{Context, Result};

use crate::aggregation::{self, Aggregator, ClientUpdate, HierarchicalAggregator};
use crate::cluster::ClusterSpec;
use crate::compress::Compressor;
use crate::config::ExperimentConfig;
use crate::cost::{self, CostBreakdown, CostLedger, Placement};
use crate::crypto::SecureAggregator;
use crate::data::{BatchIter, SyntheticCorpus};
use crate::metrics::{RoundRecord, RunResult};
use crate::model::ParamSet;
use crate::netsim::{LinkClass, Wan};
use crate::optimizer::Optimizer;
use crate::partition::{GranularityController, LoadMonitor, PartitionPlan, PartitionPlanner};
use crate::privacy::PrivacyAccountant;
use crate::runtime::ComputeBackend;
use crate::transport::Channel;
use crate::worker::{CloudWorker, LocalRound};

/// Fraction of documents held out for evaluation.
const EVAL_FRACTION: f64 = 0.1;

/// Session secret for the roster-epoch's secure-aggregation sessions.
/// Epoch 0 is the seed's fixed secret byte-for-byte, so fault-free runs
/// reproduce the pre-elastic behaviour exactly; later epochs salt it so
/// departed members' pairwise seeds are useless post-change.
fn sa_secret(epoch: u64) -> Vec<u8> {
    let mut s = b"crossfed-sa".to_vec();
    if epoch > 0 {
        s.extend_from_slice(&epoch.to_le_bytes());
    }
    s
}

/// The federation leader plus its simulated platforms.
pub struct Coordinator<'a, B: ComputeBackend + ?Sized> {
    pub cfg: ExperimentConfig,
    pub cluster: ClusterSpec,
    pub(crate) backend: &'a B,
    pub(crate) wan: Wan,
    /// the node hosting the global model — the placement decision
    /// (`cfg.placement`): a fixed cloud's gateway, or the argmin of the
    /// cost model. The seed behaviour is node 0 (`fixed:0`).
    pub(crate) leader: usize,
    /// prices every round's bytes and node-seconds (see [`crate::cost`])
    pub(crate) cost_ledger: CostLedger,
    pub(crate) workers: Vec<CloudWorker>,
    /// per-worker uplink / downlink channels. Star mode: worker w ↔
    /// leader (the leader's own worker is local). Hierarchical mode:
    /// worker w ↔ its cloud's gateway node (gateway members are local
    /// to it).
    pub(crate) up: Vec<Channel>,
    pub(crate) down: Vec<Channel>,
    /// hierarchical mode only: per-cloud gateway ↔ leader channels
    /// carrying the partial aggregates / the broadcast's WAN leg
    pub(crate) gw_up: Vec<Channel>,
    pub(crate) gw_down: Vec<Channel>,
    /// two-level reducer (hierarchical mode only)
    pub(crate) hier: Option<HierarchicalAggregator>,
    pub(crate) global: ParamSet,
    pub(crate) aggregator: Box<dyn Aggregator>,
    pub(crate) monitor: LoadMonitor,
    pub(crate) granularity: GranularityController,
    pub(crate) planner: PartitionPlanner,
    pub(crate) plan: PartitionPlan,
    pub(crate) accountant: PrivacyAccountant,
    /// secure-aggregation session over the *current* roster (sync
    /// schedules; flat star and hierarchical barrier). Rebuilt by
    /// [`Coordinator::rekey_secure`] on every roster change so masks
    /// cancel exactly over the survivor set.
    pub(crate) secure: Option<SecureAggregator>,
    /// worker id → dense index into `secure` (None = not in the roster)
    pub(crate) sa_index: Vec<Option<usize>>,
    /// buffered hierarchy only: one secure-aggregation session per cloud
    /// — masks cancel inside the gateway's per-cycle buffer sum
    pub(crate) secure_clouds: Vec<SecureAggregator>,
    /// worker id → dense index into its cloud's session
    pub(crate) sa_cloud_index: Vec<Option<usize>>,
    /// bumped on every worker-leave/worker-join; salts the re-keyed
    /// secure-aggregation secrets (epoch 0 = the seed behaviour)
    pub(crate) roster_epoch: u64,
    /// clouds whose roster changed in the last `apply_faults` call — the
    /// buffered scheduler aborts these clouds' in-progress cycles
    pub(crate) roster_dirty: Vec<usize>,
    pub(crate) eval_iter: BatchIter,
    pub(crate) corpus: SyntheticCorpus,
    // running totals
    pub(crate) sim_secs: f64,
    pub(crate) wire_bytes: u64,
    pub(crate) host_secs: f64,
    pub(crate) global_version: u64,
    /// rounds committed so far — the loop counter. `history` may be a
    /// subsample of them (`cfg.history_every`), so this is the round
    /// count, not `history.len()`
    pub(crate) rounds_done: usize,
    /// the most recent round's record, kept even when `history_every`
    /// thins it out of `history`
    pub(crate) last_record: Option<RoundRecord>,
    /// streaming metrics sink (`cfg.history_csv`): every round's curve
    /// row is appended as the round commits, independent of thinning
    pub(crate) history_csv: Option<BufWriter<File>>,
    /// cumulative simulator events scheduled (events/sec diagnostics)
    pub(crate) sim_events: u64,
    pub(crate) history: Vec<RoundRecord>,
    pub(crate) batch_size: usize,
    pub(crate) seq_len: usize,
    /// open write-ahead log (attached when `cfg.wal_dir` is set; see
    /// [`crate::wal`] and `coordinator/wal_state.rs`)
    pub(crate) wal: Option<crate::wal::WalFile>,
    /// bit patterns of the global params as last written to the WAL —
    /// the base of the next record's XOR delta
    pub(crate) wal_prev_params: Option<Vec<Vec<u32>>>,
    /// WAL parameter-chain bytes: raw (words × 4) vs. as stored after
    /// the delta-varint lossless stage — the compression-ratio report
    /// in `examples/crash_resume.rs`
    pub(crate) wal_param_raw: u64,
    pub(crate) wal_param_enc: u64,
    /// async-scheduler state decoded from the WAL, consumed by
    /// `run_async` on its first iteration after a resume
    pub(crate) async_resume: Option<crate::coordinator::wal_state::AsyncWalSnapshot>,
    /// buffered-scheduler state decoded from the WAL, consumed by
    /// `run_buffered` on its first iteration after a resume
    pub(crate) buffered_resume:
        Option<crate::coordinator::wal_state::BufferedWalSnapshot>,
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Build a coordinator: generates the corpus, plans the partition,
    /// distributes shards (counting the encrypted distribution bytes) and
    /// wires the channels.
    ///
    /// `batch_size`/`seq_len` must match the backend's compiled shapes.
    pub fn new(
        cfg: ExperimentConfig,
        cluster: ClusterSpec,
        backend: &'a B,
        init: ParamSet,
        batch_size: usize,
        seq_len: usize,
    ) -> Result<Coordinator<'a, B>> {
        cfg.validate()?;
        anyhow::ensure!(cluster.n() >= 1, "need at least one platform");
        // fault plans must be survivable on *this* cluster: ids in range
        // and a standby member behind every gateway kill. `down` tracks
        // how many of a cloud's egresses are failed at each point of the
        // (round-sorted) plan: a kill consumes one standby, a restore
        // hands one back — so kill→restore→kill cycles validate.
        // `inactive` walks the elastic roster the same way: a leave must
        // keep at least one active member with working egress per cloud,
        // a join must name a node that actually left
        let mut down = vec![0usize; cluster.n_clouds()];
        let mut inactive = vec![false; cluster.n()];
        let active_in = |cloud: usize, inactive: &[bool]| {
            cluster
                .cloud_members(cloud)
                .iter()
                .filter(|&&m| !inactive[m])
                .count()
        };
        for ev in cfg.faults.events() {
            match *ev {
                crate::netsim::FaultEvent::GatewayDown { cloud, .. } => {
                    anyhow::ensure!(
                        cloud < cluster.n_clouds(),
                        "fault {ev}: cluster has {} clouds",
                        cluster.n_clouds()
                    );
                    down[cloud] += 1;
                    anyhow::ensure!(
                        active_in(cloud, &inactive) > down[cloud],
                        "fault {ev}: cloud {cloud} has {} members but the \
                         plan kills {} of its gateways — no standby would be \
                         left; run with more --nodes-per-cloud",
                        cluster.cloud_members(cloud).len(),
                        down[cloud]
                    );
                }
                crate::netsim::FaultEvent::GatewayRestore { cloud, .. } => {
                    anyhow::ensure!(
                        cloud < cluster.n_clouds(),
                        "fault {ev}: cluster has {} clouds",
                        cluster.n_clouds()
                    );
                    anyhow::ensure!(
                        down[cloud] > 0,
                        "fault {ev}: cloud {cloud} has no failed gateway \
                         egress to restore at that point in the plan \
                         (schedule a gateway-down for an earlier round)"
                    );
                    down[cloud] -= 1;
                }
                crate::netsim::FaultEvent::LinkDegrade { src, dst, .. } => {
                    anyhow::ensure!(
                        src < cluster.n() && dst < cluster.n(),
                        "fault {ev}: cluster has {} nodes",
                        cluster.n()
                    );
                }
                crate::netsim::FaultEvent::NodeSlowdown { node, .. } => {
                    anyhow::ensure!(
                        node < cluster.n(),
                        "fault {ev}: cluster has {} nodes",
                        cluster.n()
                    );
                }
                crate::netsim::FaultEvent::CoordinatorCrash { .. } => {
                    // structural checks (at >= 1, wal_dir present) already
                    // ran in FaultEvent::validate / cfg.validate; nothing
                    // is cluster-shaped about a coordinator death
                }
                crate::netsim::FaultEvent::WorkerLeave { node, .. } => {
                    anyhow::ensure!(
                        node < cluster.n(),
                        "fault {ev}: cluster has {} nodes",
                        cluster.n()
                    );
                    anyhow::ensure!(
                        !inactive[node],
                        "fault {ev}: node {node} already left at that point \
                         in the plan (schedule a worker-join first)"
                    );
                    inactive[node] = true;
                    let cloud = cluster.cloud_of(node);
                    anyhow::ensure!(
                        active_in(cloud, &inactive) > down[cloud],
                        "fault {ev}: cloud {cloud} would be left without an \
                         active member with working egress; run with more \
                         --nodes-per-cloud or stagger the preemptions"
                    );
                }
                crate::netsim::FaultEvent::WorkerJoin { node, .. } => {
                    anyhow::ensure!(
                        node < cluster.n(),
                        "fault {ev}: cluster has {} nodes",
                        cluster.n()
                    );
                    anyhow::ensure!(
                        inactive[node],
                        "fault {ev}: node {node} is already an active member \
                         at that point in the plan (schedule a worker-leave \
                         first)"
                    );
                    inactive[node] = false;
                }
            }
        }

        let corpus = SyntheticCorpus::generate(&cfg.corpus);
        let n_eval = ((corpus.docs.len() as f64 * EVAL_FRACTION) as usize).max(1);
        let train_corpus = SyntheticCorpus {
            docs: corpus.docs[..corpus.docs.len() - n_eval].to_vec(),
            n_topics: corpus.n_topics,
        };
        let eval_tokens: Vec<i32> = {
            let tok = crate::data::CharTokenizer;
            corpus.docs[corpus.docs.len() - n_eval..]
                .iter()
                .flat_map(|d| tok.encode(&d.text))
                .collect()
        };
        let eval_iter =
            BatchIter::new(&eval_tokens, batch_size, seq_len, cfg.seed ^ 0xE7A1);

        // Capacities are *learned*, not assumed: the initial plan uses
        // uniform estimates; the load monitor's measurements drive
        // re-planning ("Monitor and Adjust in Real-Time", Figure 2).
        let capacities: Vec<f64> = vec![1.0; cluster.n()];
        let mut planner = PartitionPlanner::new(cfg.partition, cfg.seed);
        let plan = planner.plan(&train_corpus, &cluster, &capacities);

        let wan = Wan::from_cluster(&cluster, cfg.seed);
        // degrade targets must name a link this topology actually has —
        // catching a bad pair here beats aborting mid-training when the
        // fault fires
        for ev in cfg.faults.events() {
            if let crate::netsim::FaultEvent::LinkDegrade { src, dst, .. } = *ev
            {
                anyhow::ensure!(
                    wan.link(src, dst).is_some(),
                    "fault {ev}: no direct link {src}->{dst} in this \
                     topology (links exist between members of one cloud \
                     and between cloud gateways)"
                );
            }
        }
        let n_params = init.numel();
        let secret: Option<&[u8]> =
            cfg.encrypt.then_some(b"crossfed-session-secret".as_slice());

        // --- placement: which cloud hosts the global model. Fixed pins
        // a cloud (the seed behaviour is fixed:0); auto scores every
        // cloud's expected egress dollars per round against the price
        // book and takes the argmin. The leader node is that cloud's
        // gateway. Placement changes routing and dollars only, never the
        // training math (pinned by tests/cost_placement.rs).
        let leader_cloud = match cfg.placement {
            Placement::Fixed(c) => {
                anyhow::ensure!(
                    c < cluster.n_clouds(),
                    "placement fixed:{c}: cluster has only {} clouds",
                    cluster.n_clouds()
                );
                c
            }
            Placement::Auto => {
                let traffic = cost::RoundTraffic {
                    update_bytes: (n_params * 4) as u64,
                    bcast_bytes: (n_params * 4) as u64,
                    hierarchical: cfg.hierarchical,
                };
                let best =
                    cost::choose_leader(&cluster, &cfg.price_book, &traffic);
                log::info!(
                    "placement auto: leader cloud {} (node {}), expected \
                     egress ${:.4}/round",
                    best.cloud,
                    best.gateway,
                    best.egress_usd_per_round
                );
                best.cloud
            }
        };
        let leader = cluster.gateway(leader_cloud);
        // the leader node hosts the coordinator process; a spot plan that
        // preempts it would kill the run, not shrink the roster
        for ev in cfg.faults.events() {
            if let crate::netsim::FaultEvent::WorkerLeave { node, .. } = *ev {
                anyhow::ensure!(
                    node != leader,
                    "fault {ev}: node {node} hosts the aggregation leader; \
                     the coordinator cannot preempt itself — pin placement \
                     elsewhere or preempt another node"
                );
            }
        }
        let mut cost_ledger =
            CostLedger::new(cfg.price_book.clone(), cluster.n_clouds());
        cost_ledger.set_spot(cfg.spot);

        let mut workers = Vec::with_capacity(cluster.n());
        let mut up = Vec::with_capacity(cluster.n());
        let mut down = Vec::with_capacity(cluster.n());
        for (i, platform) in cluster.platforms.iter().enumerate() {
            workers.push(CloudWorker::new(
                i,
                platform.clone(),
                &plan.shards[i].tokens,
                batch_size,
                seq_len,
                cfg.seed,
            ));
            // star: worker ↔ leader; hierarchical: worker ↔ its gateway
            let hub = if cfg.hierarchical {
                cluster.gateway(cluster.cloud_of(i))
            } else {
                leader
            };
            up.push(Channel::new(
                i,
                hub,
                cfg.protocol,
                cfg.streams,
                Compressor::new(cfg.compression, cfg.seed ^ i as u64)
                    .with_lossless(cfg.lossless),
                cfg.error_feedback,
                n_params,
                secret,
            ));
            down.push(Channel::new(
                hub,
                i,
                cfg.protocol,
                cfg.streams,
                Compressor::new(crate::compress::Compression::None, 0)
                    .with_lossless(cfg.lossless),
                false,
                n_params,
                secret,
            ));
        }

        // hierarchical mode: one gateway↔leader channel pair per cloud.
        // The uplink carries the cloud's partial aggregate through the
        // same codec settings as the worker uplinks (equal-codec
        // comparison with the star), the downlink the dense broadcast.
        let mut gw_up = Vec::new();
        let mut gw_down = Vec::new();
        let hier = if cfg.hierarchical {
            for c in 0..cluster.n_clouds() {
                let gw = cluster.gateway(c);
                gw_up.push(Channel::new(
                    gw,
                    leader,
                    cfg.protocol,
                    cfg.streams,
                    Compressor::new(cfg.compression, cfg.seed ^ ((0x6A7Eu64 << 16) | c as u64))
                        .with_lossless(cfg.lossless),
                    cfg.error_feedback,
                    n_params,
                    secret,
                ));
                gw_down.push(Channel::new(
                    leader,
                    gw,
                    cfg.protocol,
                    cfg.streams,
                    Compressor::new(crate::compress::Compression::None, 0)
                        .with_lossless(cfg.lossless),
                    false,
                    n_params,
                    secret,
                ));
            }
            Some(HierarchicalAggregator::new(
                cfg.aggregation,
                Optimizer::new(cfg.server_opt, cfg.server_lr),
            )?)
        } else {
            None
        };

        let aggregator = aggregation::build(
            cfg.aggregation,
            Optimizer::new(cfg.server_opt, cfg.server_lr),
        );
        let monitor = LoadMonitor::new(cluster.n(), 0.25, 3);
        let granularity = GranularityController::new(
            cfg.local_steps,
            1,
            (cfg.local_steps * 16).max(cfg.local_steps),
        );
        let accountant = PrivacyAccountant::new(cfg.dp);

        let history_csv = match cfg.history_csv.as_deref() {
            Some(path) => {
                let file = File::create(path).with_context(|| {
                    format!("creating history CSV {path:?}")
                })?;
                let mut w = BufWriter::new(file);
                w.write_all(RoundRecord::CSV_HEADER.as_bytes())
                    .context("writing history CSV header")?;
                Some(w)
            }
            None => None,
        };

        let mut coord = Coordinator {
            monitor,
            granularity,
            accountant,
            secure: None,
            sa_index: Vec::new(),
            secure_clouds: Vec::new(),
            sa_cloud_index: Vec::new(),
            roster_epoch: 0,
            roster_dirty: Vec::new(),
            aggregator,
            cfg,
            cluster,
            backend,
            wan,
            leader,
            cost_ledger,
            workers,
            up,
            down,
            gw_up,
            gw_down,
            hier,
            global: init,
            planner,
            plan,
            eval_iter,
            corpus: train_corpus,
            sim_secs: 0.0,
            wire_bytes: 0,
            host_secs: 0.0,
            global_version: 0,
            rounds_done: 0,
            last_record: None,
            history_csv,
            sim_events: 0,
            history: Vec::new(),
            batch_size,
            seq_len,
            wal: None,
            wal_prev_params: None,
            wal_param_raw: 0,
            wal_param_enc: 0,
            async_resume: None,
            buffered_resume: None,
        };
        // secure-aggregation sessions over the build-time (full) roster;
        // epoch 0 reproduces the fixed-roster seed behaviour exactly
        coord.rekey_secure();
        // initial distribution: every platform receives its (encrypted)
        // shard once — "Ensure Data Security" phase of the Figure-2 cycle
        coord.account_distribution()?;
        // bill the construction-time distribution into the cumulative
        // ledger as setup cost, so per-round breakdowns carry training
        // traffic only (a mid-run re-plan's distribution lands in its
        // round — that one *is* a consequence of training)
        let setup = coord.wan.wire_bytes_by_cloud_class();
        coord.cost_ledger.observe(&setup, &[], &coord.cluster);
        Ok(coord)
    }

    /// Charge the WAN for distributing the current plan's shards.
    pub(crate) fn account_distribution(&mut self) -> Result<()> {
        let mut max_secs = 0.0f64;
        for shard in &self.plan.shards {
            if shard.platform == self.leader {
                continue; // leader-colocated: local copy
            }
            let bytes = (shard.n_tokens() * 4) as u64
                + if self.plan.require_encryption {
                    crate::crypto::SEAL_OVERHEAD_BYTES
                } else {
                    0
                };
            let stats = self.wan.transfer(
                self.leader,
                shard.platform,
                bytes,
                self.cfg.protocol,
                self.cfg.streams,
            )?;
            self.wire_bytes += stats.wire_bytes;
            max_secs = max_secs.max(stats.time_s);
        }
        self.sim_secs += max_secs;
        Ok(())
    }

    /// Replay the fault plan's events due at the start of `round`
    /// (async: pseudo-round boundary). Gateway failures in the flat
    /// schedulers — and a failure of the leader's own egress in any mode
    /// — are repaired immediately: routing has no later detection point
    /// there, and the leader observes its own egress locally. A *remote*
    /// gateway death under the hierarchical scheduler is only observable
    /// at that cloud's reduce, where `hier_round` detects it and fails
    /// over mid-round.
    pub(crate) fn apply_faults(&mut self, round: usize) -> Result<()> {
        if self.cfg.faults.is_empty() {
            return Ok(());
        }
        let due: Vec<crate::netsim::FaultEvent> =
            self.cfg.faults.due(round).copied().collect();
        // crash-first: if the coordinator dies this round it dies *before*
        // applying any other fault due at the same boundary — the WAL's
        // last record predates all of them, so the resumed run replays
        // them exactly once (resume strips the crash, then re-enters this
        // method for the same round)
        let crashes = |e: &crate::netsim::FaultEvent| {
            matches!(e, crate::netsim::FaultEvent::CoordinatorCrash { .. })
        };
        if due.iter().any(crashes) {
            log::warn!("round {round}: injecting fault coordinator-crash");
            return Err(crate::coordinator::CoordinatorCrashed { round }.into());
        }
        for ev in due {
            log::warn!("round {round}: injecting fault {ev}");
            match ev {
                crate::netsim::FaultEvent::GatewayDown { cloud, .. } => {
                    let gw = self.cluster.gateway(cloud);
                    self.wan.fail_node(gw);
                    self.cluster.mark_egress_failed(gw);
                    if !self.cfg.hierarchical || gw == self.leader {
                        self.fail_over_gateway(round, cloud)?;
                    }
                }
                crate::netsim::FaultEvent::GatewayRestore { cloud, .. } => {
                    // transient outage over: the earliest-failed egress
                    // comes back (build-time validation guarantees one
                    // exists), then the shared failover sequence fails
                    // the gateway role back — the restored node is the
                    // lowest-id eligible member again, so the election
                    // inside `fail_over_gateway` lands on it
                    let node = *self
                        .cluster
                        .egress_failed_members(cloud)
                        .first()
                        .with_context(|| {
                            format!(
                                "round {round}: {ev} but cloud {cloud} has \
                                 no failed egress"
                            )
                        })?;
                    self.wan.restore_node(node);
                    self.cluster.mark_egress_restored(node);
                    self.fail_over_gateway(round, cloud)?;
                }
                crate::netsim::FaultEvent::LinkDegrade {
                    src, dst, factor, ..
                } => {
                    // the link existed when the plan was validated at
                    // build; if an earlier re-election tore it down the
                    // fault is moot (the link is gone, which is strictly
                    // worse than degraded) — warn, don't abort the run
                    if let Err(e) = self.wan.degrade_link(src, dst, factor) {
                        log::warn!(
                            "round {round}: {ev} targets a torn-down \
                             link ({e}); skipping"
                        );
                    }
                }
                crate::netsim::FaultEvent::NodeSlowdown {
                    node, factor, ..
                } => {
                    self.workers[node].platform.compute_speed /= factor;
                }
                crate::netsim::FaultEvent::CoordinatorCrash { .. } => {
                    unreachable!("crash events return before this loop")
                }
                crate::netsim::FaultEvent::WorkerLeave { node, .. } => {
                    let cloud = self.cluster.cloud_of(node);
                    self.cluster.deactivate(node);
                    if self.cluster.gateway(cloud) == node {
                        // the departing node held the cloud's WAN egress:
                        // elect the lowest-id active standby and retarget
                        // the cloud's channels at it
                        self.fail_over_gateway(round, cloud)?;
                    }
                    self.roster_changed(round, cloud)?;
                }
                crate::netsim::FaultEvent::WorkerJoin { node, .. } => {
                    let cloud = self.cluster.cloud_of(node);
                    self.cluster.activate(node);
                    self.roster_changed(round, cloud)?;
                }
            }
        }
        Ok(())
    }

    /// Shared tail of every roster change (worker-leave/worker-join):
    /// bump the roster epoch, re-key secure aggregation over the survivor
    /// set, regenerate the partition plan, and flag the cloud for the
    /// buffered scheduler's cycle abort.
    fn roster_changed(&mut self, round: usize, cloud: usize) -> Result<()> {
        self.roster_epoch += 1;
        self.rekey_secure();
        if !self.roster_dirty.contains(&cloud) {
            self.roster_dirty.push(cloud);
        }
        // regenerate the partition plan against the new roster. The
        // capacity estimates still cover every node (an inactive worker's
        // shard simply goes untrained until it rejoins), so re-planning
        // stays well-defined for every strategy.
        let caps = self.monitor.capacity_estimates();
        let plan = self.planner.plan(&self.corpus, &self.cluster, &caps);
        log::info!(
            "round {round}: roster epoch {} ({} active members) — \
             re-partitioning (gen {} -> {})",
            self.roster_epoch,
            self.cluster.n_active(),
            self.plan.generation,
            plan.generation
        );
        self.plan = plan;
        for (w, shard) in self.plan.shards.iter().enumerate() {
            self.workers[w].set_shard(
                &shard.tokens,
                self.batch_size,
                self.seq_len,
                self.cfg.seed ^ self.plan.generation,
            );
        }
        self.account_distribution()?;
        Ok(())
    }

    /// (Re)build the secure-aggregation sessions over the current active
    /// roster. Masks must cancel exactly over the survivor set: the sync
    /// schedules get one session spanning every active worker (dense
    /// re-indexed in worker-id order; cancellation happens in the
    /// leader's full sum), the buffered hierarchy one session per cloud
    /// (cancellation happens in the gateway's per-cycle buffer sum). The
    /// epoch-salted secret makes departed members' old pairwise seeds
    /// useless against post-change traffic.
    pub(crate) fn rekey_secure(&mut self) {
        if !self.cfg.secure_agg {
            return;
        }
        let n = self.cluster.n();
        let secret = sa_secret(self.roster_epoch);
        let active = self.cluster.active_nodes();
        self.sa_index = vec![None; n];
        for (i, &w) in active.iter().enumerate() {
            self.sa_index[w] = Some(i);
        }
        self.secure = Some(SecureAggregator::new(active.len(), &secret));
        if self.schedule() == crate::coordinator::Schedule::HierBufferedAsync {
            self.sa_cloud_index = vec![None; n];
            self.secure_clouds = (0..self.cluster.n_clouds())
                .map(|c| {
                    let members = self.cluster.active_members(c);
                    for (i, &m) in members.iter().enumerate() {
                        self.sa_cloud_index[m] = Some(i);
                    }
                    let mut s = secret.clone();
                    s.extend_from_slice(b"-cloud");
                    s.extend_from_slice(&(c as u64).to_le_bytes());
                    SecureAggregator::new(members.len(), &s)
                })
                .collect();
        }
    }

    /// The re-election sequence shared by every failover path (eager
    /// repair in `apply_faults`, reduce-time detection in `run_hier`):
    /// elect the standby, rebuild the WAN mesh around it, retarget the
    /// cloud's channels. Returns the new gateway.
    pub(crate) fn fail_over_gateway(
        &mut self,
        round: usize,
        cloud: usize,
    ) -> Result<usize> {
        let old = self.cluster.gateway(cloud);
        let new_gw = self
            .cluster
            .reelect_gateway(cloud)
            .with_context(|| format!("round {round}: cloud {cloud} failover"))?;
        self.wan.reelect_gateway(cloud, new_gw);
        self.retarget_cloud_channels(cloud);
        log::warn!(
            "round {round}: cloud {cloud} re-elected node {new_gw} as \
             gateway (was {old})"
        );
        // re-score leader placement against the post-failover topology:
        // gateways moved, so the expected egress bill per candidate cloud
        // changed. Advisory only — migrating the global model mid-run
        // would cost a full-model transfer and change routing history, so
        // we log the new argmin instead of acting on it.
        let traffic = cost::RoundTraffic {
            update_bytes: (self.global.numel() * 4) as u64,
            bcast_bytes: (self.global.numel() * 4) as u64,
            hierarchical: self.cfg.hierarchical,
        };
        let scores = cost::placement::score_leaders(
            &self.cluster,
            &self.cfg.price_book,
            &traffic,
        );
        if let Some(best) = scores.iter().min_by(|a, b| {
            a.egress_usd_per_round
                .partial_cmp(&b.egress_usd_per_round)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cloud.cmp(&b.cloud))
        }) {
            let current = self.cluster.cloud_of(self.leader);
            if best.cloud == current {
                log::info!(
                    "round {round}: placement re-check after failover — \
                     leader cloud {current} still the argmin \
                     (${:.4}/round egress)",
                    best.egress_usd_per_round
                );
            } else {
                log::warn!(
                    "round {round}: placement re-check after failover — \
                     cloud {} is now the egress argmin (${:.4}/round) but \
                     the leader stays on cloud {current}; mid-run \
                     migration is not modeled",
                    best.cloud,
                    best.egress_usd_per_round
                );
            }
        }
        Ok(new_gw)
    }

    /// Point a cloud's member channels at its current gateway (after a
    /// re-election). The channels keep their codec and error-feedback
    /// state: the worker's compressor survives the failover, only the
    /// far end of its pipe moves.
    pub(crate) fn retarget_cloud_channels(&mut self, cloud: usize) {
        if !self.cfg.hierarchical {
            return; // flat channels terminate at the leader, not a gateway
        }
        let gw = self.cluster.gateway(cloud);
        for m in self.cluster.cloud_members(cloud) {
            self.up[m].dst = gw;
            self.down[m].src = gw;
        }
        self.gw_up[cloud].src = gw;
        self.gw_down[cloud].dst = gw;
    }

    /// Wire size of one decoded update re-shipped as a dense frame
    /// (failover forwarding): payload + frame header + seal overhead.
    pub(crate) fn dense_frame_bytes(&self, numel: usize) -> u64 {
        numel as u64 * 4
            + crate::transport::FRAME_HEADER_BYTES as u64
            + if self.cfg.encrypt {
                crate::crypto::SEAL_OVERHEAD_BYTES
            } else {
                0
            }
    }

    /// Held-out evaluation of the global model.
    pub(crate) fn evaluate(&mut self) -> Result<(f32, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for _ in 0..self.cfg.eval_batches.max(1) {
            let batch = self.eval_iter.next_batch();
            let out = self
                .backend
                .eval(&self.global, &batch)
                .context("eval step")?;
            loss_sum += out.loss as f64;
            correct += out.n_correct as u64;
            total += out.n_total as u64;
        }
        Ok((
            (loss_sum / self.cfg.eval_batches.max(1) as f64) as f32,
            correct as f64 / total.max(1) as f64,
        ))
    }

    /// Mask one update for secure aggregation, pre-scaled by its *global*
    /// FedAvg weight n_i/n (so masked *sums* are the FedAvg / mean-
    /// gradient aggregate). Shared by the star and hierarchical paths.
    fn mask_scaled(
        &self,
        u: &ClientUpdate,
        n_total: f64,
        round: u64,
    ) -> crate::crypto::MaskedUpdate {
        let sa = self.secure.as_ref().expect("secure agg enabled");
        let idx = self.sa_index[u.worker]
            .expect("masking an update from a worker outside the roster");
        let mut scaled = u.delta.clone();
        scaled.scale((u.n_samples as f64 / n_total) as f32);
        sa.mask(idx, round, &scaled.to_flat())
    }

    /// Secure-aggregation path (star): mask pre-scaled updates, sum,
    /// unmask — `unmask_sum` enforces the every-worker-exactly-once
    /// invariant the masks need to cancel.
    pub(crate) fn secure_aggregate(
        &mut self,
        updates: &[ClientUpdate],
    ) -> ParamSet {
        let n_total: f64 = updates.iter().map(|u| u.n_samples as f64).sum();
        let round = self.global_version;
        let masked: Vec<crate::crypto::MaskedUpdate> = updates
            .iter()
            .map(|u| self.mask_scaled(u, n_total, round))
            .collect();
        let sum = self
            .secure
            .as_ref()
            .expect("secure agg enabled")
            .unmask_sum(&masked);
        ParamSet::from_flat(&sum, &updates[0].delta).expect("shape preserved")
    }

    /// Secure-aggregation, gateway side: mask each member update and sum.
    /// The pairwise masks span all workers, so a single cloud's partial
    /// stays masked — they only cancel once the leader sums every cloud's
    /// partial (`run_hier` asserts full worker coverage before applying).
    pub(crate) fn secure_partial(
        &self,
        updates: &[ClientUpdate],
        n_total: f64,
        round: u64,
    ) -> ParamSet {
        assert!(!updates.is_empty());
        let mut sum = vec![0.0f32; updates[0].delta.numel()];
        for u in updates {
            let masked = self.mask_scaled(u, n_total, round);
            for (s, x) in sum.iter_mut().zip(&masked.data) {
                *s += x;
            }
        }
        ParamSet::from_flat(&sum, &updates[0].delta).expect("shape preserved")
    }

    /// Apply a secure-aggregation sum (FedAvg delta or mean gradient) to
    /// the global model.
    pub(crate) fn apply_masked_aggregate(&mut self, agg: &ParamSet) {
        match self.cfg.aggregation.update_kind() {
            crate::aggregation::UpdateKind::ParamDelta => {
                self.global.axpy(1.0, agg);
            }
            crate::aggregation::UpdateKind::Gradient => {
                // the masked sum is the weighted mean gradient
                self.global.axpy(-self.cfg.server_lr, agg);
            }
        }
    }

    /// Per-worker local step counts for one synchronous round ("local
    /// epoch over the partition" semantics — shard share controls
    /// per-round load when `proportional_local_work` is on).
    pub(crate) fn local_step_counts(&self) -> Vec<usize> {
        let base_steps = if self.cfg.adaptive_granularity {
            self.granularity.local_steps()
        } else {
            self.cfg.local_steps
        };
        let total_samples: f64 =
            self.workers.iter().map(|w| w.n_samples as f64).sum();
        let budget = (base_steps * self.workers.len()) as f64;
        self.workers
            .iter()
            .map(|w| {
                if self.cfg.proportional_local_work {
                    ((budget * w.n_samples as f64 / total_samples).round()
                        as usize)
                        .max(1)
                } else {
                    base_steps
                }
            })
            .collect()
    }

    /// Phase 1 of every synchronous round: run local training on all
    /// *active* workers against the current global model (inactive
    /// members — preempted spot nodes — return `None` and cost nothing).
    /// When the backend offers a [`ComputeBackend::sync_view`] the
    /// workers train on host threads (`CROSSFED_THREADS`); each worker
    /// owns its RNG streams and reads a shared `&global`, so the results
    /// are bit-identical to the serial path in any thread count
    /// (host_secs is summed in worker order afterwards). Thread-affine
    /// backends (PJRT) return `None` from `sync_view` and stay on the
    /// serial loop.
    pub(crate) fn train_all_workers(
        &mut self,
        step_counts: &[usize],
    ) -> Result<Vec<Option<LocalRound>>> {
        let kind = self.cfg.aggregation.update_kind();
        if let Some(sv) = self.backend.sync_view() {
            let global = &self.global;
            let cluster = &self.cluster;
            let (lr, secs, dp) =
                (self.cfg.local_lr, self.cfg.base_step_secs, &self.cfg.dp);
            let mut out: Vec<Option<Result<LocalRound>>> =
                (0..self.workers.len()).map(|_| None).collect();
            let items: Vec<(usize, &mut CloudWorker, &mut Option<Result<LocalRound>>)> =
                self.workers.iter_mut().zip(out.iter_mut()).enumerate()
                    .map(|(i, (w, slot))| (i, w, slot))
                    .collect();
            crate::util::par::run_items(items, |(i, w, slot)| {
                if cluster.is_active(i) {
                    *slot = Some(w.local_round(
                        sv, global, kind, step_counts[i], lr, secs, dp,
                    ));
                }
            });
            let mut locals = Vec::with_capacity(out.len());
            for slot in out {
                match slot {
                    Some(res) => {
                        let r = res?;
                        self.host_secs += r.host_secs;
                        locals.push(Some(r));
                    }
                    None => locals.push(None),
                }
            }
            return Ok(locals);
        }
        let mut locals = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            if !self.cluster.is_active(w) {
                locals.push(None);
                continue;
            }
            let r = self.workers[w].local_round(
                self.backend,
                &self.global,
                kind,
                step_counts[w],
                self.cfg.local_lr,
                self.cfg.base_step_secs,
                &self.cfg.dp,
            )?;
            self.host_secs += r.host_secs;
            locals.push(Some(r));
        }
        Ok(locals)
    }

    /// Shared tail of every synchronous round: commit time/byte totals,
    /// run the Figure-2 monitor cycle, eval on schedule and assemble the
    /// `RoundRecord`. `barrier_at`/`round_end` come from the round's
    /// event engine.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn finalize_round(
        &mut self,
        round: usize,
        locals: &[Option<LocalRound>],
        round_start: f64,
        barrier_at: f64,
        round_end: f64,
        round_wire: u64,
    ) -> Result<RoundRecord> {
        self.wire_bytes += round_wire;
        self.sim_secs = round_end;

        // inactive members contribute zero compute seconds (and are
        // excluded from the train-loss mean below)
        let compute_times: Vec<f64> = locals
            .iter()
            .map(|l| l.as_ref().map_or(0.0, |r| r.compute_secs))
            .collect();
        let compute_max =
            compute_times.iter().cloned().fold(0.0f64, f64::max);
        let comm_secs = (barrier_at - round_start - compute_max)
            + (round_end - barrier_at);
        self.monitor_and_adjust(round, &compute_times, comm_secs)?;
        // price the round after monitor_and_adjust: a re-plan's shard
        // re-distribution is traffic this round caused
        let cost = self.cost_observe(&compute_times);

        let (eval_loss, eval_acc) = self.round_eval(round)?;
        let trained: Vec<&LocalRound> =
            locals.iter().flatten().collect();
        let train_loss = trained.iter().map(|l| l.mean_loss).sum::<f32>()
            / trained.len().max(1) as f32;
        log::debug!(
            "round {round}: train={train_loss:.3} eval={eval_loss:?} \
             sim={:.0}s wire={} inter-region={}",
            self.sim_secs,
            self.wire_bytes,
            self.wan.inter_region_bytes()
        );

        Ok(RoundRecord {
            round,
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            wire_bytes_class: self.wan_class_split(),
            train_loss,
            eval_loss,
            eval_acc,
            platform_secs: compute_times,
            epsilon: self.accountant.epsilon(),
            partition_gen: self.plan.generation,
            active_members: self.cluster.n_active(),
            cost,
            cum_cost_usd: self.cost_ledger.cumulative().total_usd(),
        })
    }

    /// Commit one finished round's record through the metrics sink:
    /// stream its CSV row when `cfg.history_csv` is set (every round,
    /// regardless of thinning), keep it as `last_record`, retain it in
    /// `history` on the `cfg.history_every` schedule, and advance the
    /// round counter. Every scheduler (and the WAL replay) routes each
    /// round through here exactly once.
    pub(crate) fn commit_round(&mut self, record: RoundRecord) -> Result<()> {
        if let Some(w) = self.history_csv.as_mut() {
            writeln!(w, "{}", record.csv_row())
                .context("writing history CSV row")?;
        }
        if record.round % self.cfg.history_every == 0 {
            self.last_record = Some(record.clone());
            self.history.push(record);
        } else {
            self.last_record = Some(record);
        }
        self.rounds_done += 1;
        Ok(())
    }

    /// Price everything since the last observation (round boundary):
    /// the WAN's cumulative per-(cloud, class) byte split plus this
    /// window's per-worker compute seconds, through the price book.
    pub(crate) fn cost_observe(
        &mut self,
        platform_secs: &[f64],
    ) -> CostBreakdown {
        let cum = self.wan.wire_bytes_by_cloud_class();
        self.cost_ledger.observe(&cum, platform_secs, &self.cluster)
    }

    /// End-of-round Figure-2 cycle, shared by the sync schedulers:
    /// granularity observation + load monitoring + re-partitioning.
    /// `comm_secs` is the round's communication share of wall-clock.
    pub(crate) fn monitor_and_adjust(
        &mut self,
        round: usize,
        compute_times: &[f64],
        comm_secs: f64,
    ) -> Result<()> {
        if self.cfg.adaptive_granularity {
            let compute_max =
                compute_times.iter().cloned().fold(0.0, f64::max);
            self.granularity.observe(compute_max, comm_secs.max(0.0));
        }
        // feed the monitor only when the full roster trained: an elastic
        // round's zeroed compute entries would read as infinitely fast
        // nodes and skew capacity estimates — churn runs re-plan through
        // `roster_changed` instead
        if self.cluster.n_active() == self.cluster.n()
            && self.monitor.observe(compute_times)
        {
            let caps = self.monitor.capacity_estimates();
            if let Some(plan) =
                self.planner.replan(&self.corpus, &self.cluster, &caps)
            {
                log::info!(
                    "round {round}: re-partitioning (gen {} -> {}), caps {:?}",
                    self.plan.generation,
                    plan.generation,
                    caps
                );
                self.plan = plan;
                for (w, shard) in self.plan.shards.iter().enumerate() {
                    self.workers[w].set_shard(
                        &shard.tokens,
                        self.batch_size,
                        self.seq_len,
                        self.cfg.seed ^ self.plan.generation,
                    );
                }
                self.account_distribution()?;
            }
        }
        Ok(())
    }

    /// Eval on schedule: every `eval_every` rounds and on the last round.
    pub(crate) fn round_eval(
        &mut self,
        round: usize,
    ) -> Result<(Option<f32>, Option<f64>)> {
        if round % self.cfg.eval_every.max(1) == 0
            || round + 1 == self.cfg.rounds
        {
            let (l, a) = self.evaluate()?;
            Ok((Some(l), Some(a)))
        } else {
            Ok((None, None))
        }
    }

    /// Current partition generation (diagnostics / tests).
    pub fn partition_generation(&self) -> u64 {
        self.plan.generation
    }

    /// Global model (read access for examples / tests).
    pub fn global(&self) -> &ParamSet {
        &self.global
    }

    /// Total simulated seconds so far.
    pub fn sim_secs(&self) -> f64 {
        self.sim_secs
    }

    /// Simulator events processed so far (transfer hops, barriers,
    /// broadcast completions) — the events/sec throughput numerator.
    pub fn sim_events(&self) -> u64 {
        self.sim_events
    }

    /// Total wire bytes so far.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes
    }

    /// Bytes that crossed WAN links of `class` so far (per-link ledger).
    pub fn wire_bytes_class(&self, class: LinkClass) -> u64 {
        self.wan.wire_bytes_class(class)
    }

    /// The WAN ledger's cumulative per-class byte split, indexed by
    /// [`LinkClass::index`] (the [`RoundRecord`]/[`RunResult`] layout).
    pub(crate) fn wan_class_split(&self) -> [u64; 3] {
        [
            self.wan.wire_bytes_class(LinkClass::IntraAz),
            self.wan.wire_bytes_class(LinkClass::IntraRegion),
            self.wan.wire_bytes_class(LinkClass::InterRegion),
        ]
    }

    /// Bytes that paid the inter-region WAN — the hierarchical-vs-star
    /// headline number.
    pub fn inter_region_wire_bytes(&self) -> u64 {
        self.wan.inter_region_bytes()
    }

    /// WAL parameter-chain bytes `(raw, stored)`: what the per-round
    /// param records would have cost as plain words × 4 vs. what the
    /// delta-varint lossless stage actually wrote. `(0, 0)` when no WAL
    /// is attached.
    pub fn wal_param_bytes(&self) -> (u64, u64) {
        (self.wal_param_raw, self.wal_param_enc)
    }

    /// The node hosting the global model (the placement decision).
    pub fn leader(&self) -> usize {
        self.leader
    }

    /// The cloud the leader lives on.
    pub fn leader_cloud(&self) -> usize {
        self.cluster.cloud_of(self.leader)
    }

    /// Dollars billed so far (cumulative breakdown, incl. setup).
    pub fn run_cost(&self) -> &CostBreakdown {
        self.cost_ledger.cumulative()
    }

    /// Snapshot the current run state (see [`crate::checkpoint`]).
    pub fn checkpoint(&self) -> crate::checkpoint::Checkpoint {
        crate::checkpoint::Checkpoint {
            params: self.global.clone(),
            round: self.rounds_done,
            global_version: self.global_version,
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            experiment: self.cfg.name.clone(),
        }
    }

    /// Restore model + counters from a checkpoint (shape-checked).
    ///
    /// Note: `sim_secs`/`wire_bytes` resume from the checkpointed
    /// totals, but the WAN's per-link ledger and the cost ledger start
    /// fresh (the checkpoint does not carry them) — a resumed run's
    /// `wire_bytes_class` and `cost` describe the resumed segment only.
    pub fn restore(&mut self, ckpt: &crate::checkpoint::Checkpoint) -> Result<()> {
        ckpt.check_compatible(&self.global)?;
        self.global = ckpt.params.clone();
        self.global_version = ckpt.global_version;
        self.sim_secs = ckpt.sim_secs;
        self.wire_bytes = ckpt.wire_bytes;
        Ok(())
    }

    /// Run the configured experiment to completion. A fresh run with
    /// `cfg.wal_dir` set starts a new write-ahead log (truncating any
    /// previous log of the same experiment — resuming instead is
    /// [`Coordinator::resume`]'s job, which arrives here with the log
    /// already attached and history replayed).
    pub fn run(&mut self) -> Result<RunResult> {
        if self.wal.is_none()
            && self.cfg.wal_dir.is_some()
            && self.rounds_done == 0
        {
            self.attach_wal()?;
        }
        match self.schedule() {
            crate::coordinator::Schedule::FlatAsync => self.run_async(),
            crate::coordinator::Schedule::HierBufferedAsync => {
                self.run_buffered()
            }
            crate::coordinator::Schedule::SyncBarrier
            | crate::coordinator::Schedule::HierSync => self.run_sync(),
        }
    }

    /// Which of the four round-pipeline policies this run executes
    /// (derived from the aggregation kind and the hierarchy knob).
    pub fn schedule(&self) -> crate::coordinator::Schedule {
        crate::coordinator::Schedule::derive(
            self.aggregator.is_async(),
            self.cfg.hierarchical,
        )
    }

    pub(crate) fn finish(&mut self, reached_target: bool) -> Result<RunResult> {
        if let Some(w) = self.history_csv.as_mut() {
            w.flush().context("flushing history CSV")?;
        }
        let (eval_loss, eval_acc) = self.evaluate()?;
        let final_train = self
            .last_record
            .as_ref()
            .map(|r| r.train_loss)
            .unwrap_or(f32::NAN);
        Ok(RunResult {
            name: self.cfg.name.clone(),
            history: self.history.clone(),
            rounds_run: self.rounds_done,
            sim_secs: self.sim_secs,
            wire_bytes: self.wire_bytes,
            wire_bytes_class: self.wan_class_split(),
            final_train_loss: final_train,
            final_eval_loss: eval_loss,
            final_eval_acc: eval_acc,
            reached_target,
            host_compute_secs: self.host_secs,
            cost: self.cost_ledger.cumulative().clone(),
        })
    }
}

//! Discrete-event engine shared by every scheduler.
//!
//! One min-heap of `(time, seq, event)` drives simulated time for the
//! synchronous barrier, the asynchronous apply-on-arrival loop and the
//! hierarchical two-level reduce alike: local-training completions,
//! intra-cloud hops, WAN uplinks and broadcasts are all timed events, so
//! per-hop times overlap exactly as they would on real hardware instead
//! of being summed ad hoc per phase.
//!
//! Determinism: ties on `at` are broken by insertion order (`seq`), and
//! every consumer schedules in a deterministic order, so the pop sequence
//! — and with it the order in which the WAN's noise RNG is consumed — is
//! a pure function of the experiment seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Scheduled<E> {
    at: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, insertion seq); BinaryHeap is a max-heap,
        // so compare reversed. `at()` rejects non-finite times, so the
        // comparison is total — mapping an incomparable (NaN) pair to
        // Equal here would silently corrupt the heap order.
        other
            .at
            .partial_cmp(&self.at)
            .expect("event times are finite (enforced in at())")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Simulated-time event queue. `now` only moves forward, to the
/// timestamp of the last popped event.
pub(crate) struct EventEngine<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: f64,
    seq: u64,
}

impl<E> EventEngine<E> {
    pub fn new(start: f64) -> EventEngine<E> {
        EventEngine { heap: BinaryHeap::new(), now: start, seq: 0 }
    }

    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now: events
    /// cannot fire in the past). Non-finite times are a hard error: a
    /// NaN would make heap comparisons incomparable and silently corrupt
    /// the pop order (and with it determinism), so it must never enter.
    pub fn at(&mut self, at: f64, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn after(&mut self, delay: f64, event: E) {
        // hard assert (not debug): a NaN delay in a release build would
        // otherwise reach `at` as now + NaN and a +inf delay would park
        // an event at the end of time
        assert!(delay.is_finite(), "non-finite event time delay {delay}");
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<E> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = self.now.max(s.at);
        Some(s.event)
    }

    /// Queued events in pop order — `(at, event)` sorted by time then
    /// insertion sequence. A WAL snapshot replays these through
    /// [`EventEngine::at`] on a fresh engine positioned at the same
    /// `now`: seq numbers are reassigned densely but the *relative*
    /// order (and therefore every future pop) is preserved exactly.
    pub fn queued(&self) -> Vec<(f64, &E)> {
        let mut items: Vec<&Scheduled<E>> = self.heap.iter().collect();
        items.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .expect("event times are finite (enforced in at())")
                .then(a.seq.cmp(&b.seq))
        });
        items.into_iter().map(|s| (s.at, &s.event)).collect()
    }

    #[allow(dead_code)] // diagnostics + tests
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[allow(dead_code)] // diagnostics + tests
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_now() {
        let mut e = EventEngine::new(10.0);
        e.at(13.0, "c");
        e.at(11.0, "a");
        e.after(2.0, "b"); // 12.0
        assert_eq!(e.len(), 3);
        assert_eq!(e.pop(), Some("a"));
        assert_eq!(e.now(), 11.0);
        assert_eq!(e.pop(), Some("b"));
        assert_eq!(e.pop(), Some("c"));
        assert_eq!(e.now(), 13.0);
        assert!(e.is_empty());
        assert_eq!(e.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = EventEngine::new(0.0);
        e.at(5.0, 1);
        e.at(5.0, 2);
        e.at(5.0, 3);
        assert_eq!(e.pop(), Some(1));
        assert_eq!(e.pop(), Some(2));
        assert_eq!(e.pop(), Some(3));
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut e = EventEngine::new(100.0);
        e.at(1.0, "late");
        assert_eq!(e.pop(), Some("late"));
        assert_eq!(e.now(), 100.0);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected() {
        let mut e = EventEngine::new(0.0);
        e.at(f64::NAN, "bad");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected() {
        let mut e = EventEngine::new(0.0);
        e.at(f64::INFINITY, "bad");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_delay_rejected_via_after() {
        let mut e = EventEngine::new(5.0);
        // now + NaN = NaN: must trip the same hard assert, not silently
        // clamp to now (the pre-fix behaviour of f64::max)
        e.after(f64::NAN, "bad");
    }
}

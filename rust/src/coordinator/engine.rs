//! Discrete-event engine shared by every scheduler.
//!
//! One min-heap of `(time, seq, event)` drives simulated time for the
//! synchronous barrier, the asynchronous apply-on-arrival loop and the
//! hierarchical two-level reduce alike: local-training completions,
//! intra-cloud hops, WAN uplinks and broadcasts are all timed events, so
//! per-hop times overlap exactly as they would on real hardware instead
//! of being summed ad hoc per phase.
//!
//! Storage is an arena: events live in a slab of reusable slots and the
//! heap orders `u32` slot indices, so a steady-state run (pop one, push
//! one) allocates nothing per event — the slab and the index heap reach
//! their high-water mark once and are reused for the rest of the run.
//! At planet scale (10k+ concurrent events) this removes the per-push
//! `Scheduled<E>` moves that made `BinaryHeap` the hot-loop bottleneck.
//!
//! Determinism: ties on `at` are broken by insertion order (`seq`), and
//! every consumer schedules in a deterministic order, so the pop sequence
//! — and with it the order in which the WAN's noise RNG is consumed — is
//! a pure function of the experiment seed.

use std::cmp::Ordering;

struct Slot<E> {
    at: f64,
    seq: u64,
    /// `None` while the slot sits on the free list.
    event: Option<E>,
}

/// `true` when slot `a` pops strictly before slot `b`:
/// min order on `(at, seq)`.
fn slot_before<E>(slots: &[Slot<E>], a: u32, b: u32) -> bool {
    let (sa, sb) = (&slots[a as usize], &slots[b as usize]);
    match sa
        .at
        .partial_cmp(&sb.at)
        .expect("event times are finite (enforced in at())")
    {
        Ordering::Less => true,
        Ordering::Greater => false,
        Ordering::Equal => sa.seq < sb.seq,
    }
}

fn sift_up<E>(slots: &[Slot<E>], heap: &mut [u32], mut i: usize) {
    while i > 0 {
        let parent = (i - 1) / 2;
        if slot_before(slots, heap[i], heap[parent]) {
            heap.swap(i, parent);
            i = parent;
        } else {
            break;
        }
    }
}

fn sift_down<E>(slots: &[Slot<E>], heap: &mut [u32], mut i: usize) {
    loop {
        let l = 2 * i + 1;
        if l >= heap.len() {
            break;
        }
        let r = l + 1;
        let mut child = l;
        if r < heap.len() && slot_before(slots, heap[r], heap[l]) {
            child = r;
        }
        if slot_before(slots, heap[child], heap[i]) {
            heap.swap(i, child);
            i = child;
        } else {
            break;
        }
    }
}

/// Simulated-time event queue. `now` only moves forward, to the
/// timestamp of the last popped event.
pub(crate) struct EventEngine<E> {
    /// Event arena; freed slots are recycled via `free`, never shrunk.
    slots: Vec<Slot<E>>,
    /// Indices of vacant slots in `slots`.
    free: Vec<u32>,
    /// Binary min-heap of slot indices ordered by `(at, seq)`.
    heap: Vec<u32>,
    now: f64,
    seq: u64,
}

impl<E> EventEngine<E> {
    pub fn new(start: f64) -> EventEngine<E> {
        EventEngine { slots: Vec::new(), free: Vec::new(), heap: Vec::new(), now: start, seq: 0 }
    }

    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now: events
    /// cannot fire in the past). Non-finite times are a hard error: a
    /// NaN would make heap comparisons incomparable and silently corrupt
    /// the pop order (and with it determinism), so it must never enter.
    pub fn at(&mut self, at: f64, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let idx = match self.free.pop() {
            Some(idx) => {
                let slot = &mut self.slots[idx as usize];
                slot.at = at;
                slot.seq = seq;
                slot.event = Some(event);
                idx
            }
            None => {
                let idx = u32::try_from(self.slots.len()).expect("event arena fits in u32");
                self.slots.push(Slot { at, seq, event: Some(event) });
                idx
            }
        };
        self.heap.push(idx);
        sift_up(&self.slots, &mut self.heap, self.heap.len() - 1);
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn after(&mut self, delay: f64, event: E) {
        // hard assert (not debug): a NaN delay in a release build would
        // otherwise reach `at` as now + NaN and a +inf delay would park
        // an event at the end of time
        assert!(delay.is_finite(), "non-finite event time delay {delay}");
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<E> {
        if self.heap.is_empty() {
            return None;
        }
        let idx = self.heap.swap_remove(0);
        if !self.heap.is_empty() {
            sift_down(&self.slots, &mut self.heap, 0);
        }
        let slot = &mut self.slots[idx as usize];
        debug_assert!(slot.at >= self.now, "time went backwards");
        self.now = self.now.max(slot.at);
        let event = slot.event.take().expect("heap slot is occupied");
        self.free.push(idx);
        Some(event)
    }

    /// Queued events in pop order — `(at, event)` sorted by time then
    /// insertion sequence. A WAL snapshot replays these through
    /// [`EventEngine::at`] on a fresh engine positioned at the same
    /// `now`: seq numbers are reassigned densely but the *relative*
    /// order (and therefore every future pop) is preserved exactly.
    ///
    /// Only the `u32` index heap is cloned and drained in heap order —
    /// no event clones and no comparator re-sort of the full queue, so
    /// a per-round WAL snapshot costs one small index buffer instead of
    /// duplicating and sorting every pending event.
    pub fn queued(&self) -> Vec<(f64, &E)> {
        let mut heap = self.heap.clone();
        let mut out = Vec::with_capacity(heap.len());
        while !heap.is_empty() {
            let idx = heap.swap_remove(0);
            if !heap.is_empty() {
                sift_down(&self.slots, &mut heap, 0);
            }
            let slot = &self.slots[idx as usize];
            out.push((slot.at, slot.event.as_ref().expect("heap slot is occupied")));
        }
        out
    }

    /// Total events ever scheduled — the simulator's events/sec numerator.
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }

    #[allow(dead_code)] // diagnostics + tests
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[allow(dead_code)] // diagnostics + tests
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_now() {
        let mut e = EventEngine::new(10.0);
        e.at(13.0, "c");
        e.at(11.0, "a");
        e.after(2.0, "b"); // 12.0
        assert_eq!(e.len(), 3);
        assert_eq!(e.pop(), Some("a"));
        assert_eq!(e.now(), 11.0);
        assert_eq!(e.pop(), Some("b"));
        assert_eq!(e.pop(), Some("c"));
        assert_eq!(e.now(), 13.0);
        assert!(e.is_empty());
        assert_eq!(e.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = EventEngine::new(0.0);
        e.at(5.0, 1);
        e.at(5.0, 2);
        e.at(5.0, 3);
        assert_eq!(e.pop(), Some(1));
        assert_eq!(e.pop(), Some(2));
        assert_eq!(e.pop(), Some(3));
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut e = EventEngine::new(100.0);
        e.at(1.0, "late");
        assert_eq!(e.pop(), Some("late"));
        assert_eq!(e.now(), 100.0);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected() {
        let mut e = EventEngine::new(0.0);
        e.at(f64::NAN, "bad");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected() {
        let mut e = EventEngine::new(0.0);
        e.at(f64::INFINITY, "bad");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_delay_rejected_via_after() {
        let mut e = EventEngine::new(5.0);
        // now + NaN = NaN: must trip the same hard assert, not silently
        // clamp to now (the pre-fix behaviour of f64::max)
        e.after(f64::NAN, "bad");
    }

    #[test]
    fn arena_recycles_slots_in_steady_state() {
        let mut e = EventEngine::new(0.0);
        for i in 0..4u64 {
            e.at(i as f64, i);
        }
        // pop one / push one for a while: the slab must not grow past
        // its high-water mark of 4 live events
        for i in 4..1000u64 {
            assert_eq!(e.pop(), Some(i - 4));
            e.at(i as f64, i);
        }
        assert_eq!(e.slots.len(), 4);
        assert_eq!(e.len(), 4);
        assert_eq!(e.scheduled_total(), 1000);
        for i in 996..1000u64 {
            assert_eq!(e.pop(), Some(i));
        }
        assert!(e.is_empty());
        assert_eq!(e.free.len(), 4);
    }

    /// Reference queue with the pre-arena semantics: a flat list popped
    /// by linear min-scan on `(at, seq)`. Obviously correct (no heap,
    /// no slab, no index indirection) — the arena heap must reproduce
    /// its pop order, timestamps and snapshots exactly.
    struct RefEngine {
        /// (at, seq, event)
        items: Vec<(f64, u64, u64)>,
        now: f64,
        seq: u64,
    }

    impl RefEngine {
        fn new(start: f64) -> RefEngine {
            RefEngine { items: Vec::new(), now: start, seq: 0 }
        }

        fn at(&mut self, at: f64, ev: u64) {
            self.items.push((at.max(self.now), self.seq, ev));
            self.seq += 1;
        }

        fn pop(&mut self) -> Option<u64> {
            if self.items.is_empty() {
                return None;
            }
            let mut best = 0;
            for i in 1..self.items.len() {
                let (at, seq, _) = self.items[i];
                let (bat, bseq, _) = self.items[best];
                if at < bat || (at == bat && seq < bseq) {
                    best = i;
                }
            }
            let (at, _, ev) = self.items.remove(best);
            self.now = self.now.max(at);
            Some(ev)
        }

        fn queued(&self) -> Vec<(u64, u64)> {
            let mut want = self.items.clone();
            want.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
            });
            want.iter().map(|&(at, _, ev)| (at.to_bits(), ev)).collect()
        }
    }

    #[test]
    fn arena_heap_matches_reference_on_random_script() {
        let mut rng = crate::util::rng::Pcg64::new(42, 0xE4E47);
        let mut e = EventEngine::new(0.0);
        let mut r = RefEngine::new(0.0);
        let mut next_ev = 0u64;
        for step in 0..5000u64 {
            // push-heavy first half, pop-heavy second half, so the queue
            // grows to a real high-water mark and then drains through
            // recycled slots
            let push = e.is_empty()
                || rng.below(10) < if step < 2500 { 6 } else { 4 };
            if push {
                // quantized offsets make time ties common, exercising
                // the seq tiebreak on every push
                let at = e.now() + (rng.below(8) as f64) * 0.25;
                e.at(at, next_ev);
                r.at(at, next_ev);
                next_ev += 1;
            } else {
                assert_eq!(e.pop(), r.pop(), "step {step}");
                assert_eq!(e.now().to_bits(), r.now.to_bits(), "step {step}");
            }
            if step % 97 == 0 {
                let snap: Vec<(u64, u64)> = e
                    .queued()
                    .iter()
                    .map(|&(at, ev)| (at.to_bits(), *ev))
                    .collect();
                assert_eq!(snap, r.queued(), "step {step}");
            }
        }
        while let Some(want) = r.pop() {
            assert_eq!(e.pop(), Some(want));
        }
        assert!(e.is_empty());
        assert_eq!(e.scheduled_total(), next_ev);
    }

    #[test]
    fn queued_matches_pop_order_exactly() {
        let mut e = EventEngine::new(0.0);
        // interleaved times with ties, pushed out of order
        let times = [7.0, 2.0, 9.0, 2.0, 5.0, 7.0, 1.0, 5.0, 5.0];
        for (i, &t) in times.iter().enumerate() {
            e.at(t, i);
        }
        e.pop(); // free a slot so the arena has a hole, then refill
        e.at(3.0, 99);
        let snapshot: Vec<(f64, usize)> = e.queued().iter().map(|&(at, ev)| (at, *ev)).collect();
        let mut popped = Vec::new();
        while let Some(ev) = e.pop() {
            popped.push((e.now(), ev));
        }
        assert_eq!(snapshot, popped);
    }
}

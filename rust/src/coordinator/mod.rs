//! The federation leader — the paper's coordination contribution.
//!
//! Owns the global model, the WAN, the partition plan and the aggregation
//! algorithm; drives synchronous rounds (FedAvg / dynamic weighted /
//! gradient aggregation), the hierarchical two-level reduce, or the
//! asynchronous event loop (formula 4), with the full §3.1 partitioning
//! cycle (granularity control, load balancing, encrypted distribution,
//! real-time monitoring) in the loop. All schedulers are policies over
//! one discrete-event engine ([`engine`]), so per-hop communication
//! times overlap instead of being summed ad hoc.

mod build;
mod engine;
mod run_async;
mod run_hier;
mod run_sync;
mod wal_state;

pub use build::Coordinator;

/// The typed abort raised when a [`crate::netsim::FaultEvent::CoordinatorCrash`]
/// strikes: the coordinator "process" dies at the start of a round, before
/// any other fault due that round is applied. The harness catches this
/// (downcast through `anyhow`), drops the coordinator and calls
/// [`Coordinator::resume`] against the same WAL directory — the resumed
/// run replays bit-identically from the last durable round boundary.
#[derive(Debug, thiserror::Error)]
#[error(
    "coordinator crashed at the start of round {round} (injected fault); \
     resume from the write-ahead log"
)]
pub struct CoordinatorCrashed {
    pub round: usize,
}

//! The federation leader — the paper's coordination contribution.
//!
//! Owns the global model, the WAN, the partition plan and the aggregation
//! algorithm; drives one of the four [`Schedule`] policies — the flat
//! synchronous barrier (FedAvg / dynamic weighted / gradient), the flat
//! asynchronous event loop (formula 4), the hierarchical two-level
//! reduce, or the buffered (FedBuff-style) asynchronous hierarchy — with
//! the full §3.1 partitioning cycle (granularity control, load
//! balancing, encrypted distribution, real-time monitoring) in the loop.
//! All schedulers are policies over one discrete-event engine
//! ([`engine`]), so per-hop communication times overlap instead of being
//! summed ad hoc. Membership is elastic: `worker-leave`/`worker-join`
//! faults shrink and regrow the roster mid-run, with secure-aggregation
//! re-keying over the survivor set on every change.

mod build;
pub(crate) mod engine;
mod run_async;
mod run_buffered;
mod run_hier;
mod run_sync;
mod schedule;
mod wal_state;

pub use build::Coordinator;
pub use schedule::Schedule;

/// The typed abort raised when a [`crate::netsim::FaultEvent::CoordinatorCrash`]
/// strikes: the coordinator "process" dies at the start of a round, before
/// any other fault due that round is applied. The harness catches this
/// (downcast through `anyhow`), drops the coordinator and calls
/// [`Coordinator::resume`] against the same WAL directory — the resumed
/// run replays bit-identically from the last durable round boundary.
#[derive(Debug, thiserror::Error)]
#[error(
    "coordinator crashed at the start of round {round} (injected fault); \
     resume from the write-ahead log"
)]
pub struct CoordinatorCrashed {
    pub round: usize,
}

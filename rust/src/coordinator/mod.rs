//! The federation leader — the paper's coordination contribution.
//!
//! Owns the global model, the WAN, the partition plan and the aggregation
//! algorithm; drives synchronous rounds (FedAvg / dynamic weighted /
//! gradient aggregation), the hierarchical two-level reduce, or the
//! asynchronous event loop (formula 4), with the full §3.1 partitioning
//! cycle (granularity control, load balancing, encrypted distribution,
//! real-time monitoring) in the loop. All schedulers are policies over
//! one discrete-event engine ([`engine`]), so per-hop communication
//! times overlap instead of being summed ad hoc.

mod build;
mod engine;
mod run_async;
mod run_hier;
mod run_sync;

pub use build::Coordinator;

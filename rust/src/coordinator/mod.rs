//! The federation leader — the paper's coordination contribution.
//!
//! Owns the global model, the WAN, the partition plan and the aggregation
//! algorithm; drives synchronous rounds (FedAvg / dynamic weighted /
//! gradient aggregation) or the asynchronous event loop (formula 4), with
//! the full §3.1 partitioning cycle (granularity control, load balancing,
//! encrypted distribution, real-time monitoring) in the loop.

mod build;
mod run_async;
mod run_sync;

pub use build::Coordinator;

//! The round pipeline's scheduling policy.
//!
//! Every run is one of four policies over the shared
//! [`EventEngine`](crate::coordinator::engine): the two config knobs
//! (`aggregation`'s sync/async split and `hierarchical`) pick which one,
//! and [`crate::coordinator::Coordinator::run`] dispatches on it. The
//! schedulers themselves live in `run_sync.rs` (both barrier policies),
//! `run_async.rs` and `run_buffered.rs` — this enum is the single place
//! the mapping is written down, so config validation, the WAL's
//! mode-compatibility checks and the dispatch can never disagree.

/// Which round pipeline a configuration runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// flat star: every worker uplinks to the leader, one barrier per
    /// round (FedAvg / dynamic / gradient)
    SyncBarrier,
    /// flat star, no barrier: the leader applies each update on arrival
    /// with the staleness-discounted mixing rate (paper formula 4)
    FlatAsync,
    /// two-level barrier: per-cloud gateway reduces, one WAN partial per
    /// cloud, cross-cloud reduce at the leader
    HierSync,
    /// FedBuff-style buffered hierarchy: gateways mix member updates into
    /// a cloud buffer as they arrive (local mixing rate over the lagged
    /// gateway model), the leader consumes cloud-level buffered
    /// aggregates asynchronously
    HierBufferedAsync,
}

impl Schedule {
    /// Derive the policy from the two config knobs.
    pub fn derive(is_async: bool, hierarchical: bool) -> Schedule {
        match (is_async, hierarchical) {
            (false, false) => Schedule::SyncBarrier,
            (true, false) => Schedule::FlatAsync,
            (false, true) => Schedule::HierSync,
            (true, true) => Schedule::HierBufferedAsync,
        }
    }

    /// Policies without a per-round barrier (event-loop schedulers with
    /// pseudo-round accounting).
    pub fn is_async(self) -> bool {
        matches!(self, Schedule::FlatAsync | Schedule::HierBufferedAsync)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knobs_map_onto_the_four_policies() {
        assert_eq!(Schedule::derive(false, false), Schedule::SyncBarrier);
        assert_eq!(Schedule::derive(true, false), Schedule::FlatAsync);
        assert_eq!(Schedule::derive(false, true), Schedule::HierSync);
        assert_eq!(Schedule::derive(true, true), Schedule::HierBufferedAsync);
        assert!(Schedule::FlatAsync.is_async());
        assert!(Schedule::HierBufferedAsync.is_async());
        assert!(!Schedule::SyncBarrier.is_async());
        assert!(!Schedule::HierSync.is_async());
    }
}

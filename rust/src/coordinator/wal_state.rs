//! WAL round records: what the coordinator durably logs at every
//! (pseudo-)round boundary, and how a crashed run restores it.
//!
//! One record holds *everything* round r+1 depends on: the global model
//! (full snapshot every [`SNAPSHOT_EVERY`] records, XOR-of-bit-patterns
//! delta in between), every RNG stream (worker straggle/DP noise, batch
//! samplers, codec stochastic rounding, WAN jitter, eval sampler), the
//! per-channel error-feedback scratch and AEAD sequence counters, the
//! partition plan's generation + weights (the shards themselves are
//! regenerated, not stored), the load monitor / granularity / privacy
//! accountant positions, the gateway-election state, the roster epoch
//! (secure-aggregation re-keying), the cost ledger's volume-tier
//! positions, and — in the async schedulers — the event queue and the
//! in-flight updates awaiting pickup (flat async) or the full
//! gateway-buffer state (buffered hierarchy).
//!
//! Restore order matters and is fixed by the encode order: the partition
//! plan is regenerated first (so `set_shard` rebuilds each worker's token
//! buffer), then worker RNGs are overlaid; the cluster's gateway state is
//! restored before channels are retargeted at the elected gateways.

use std::path::Path;

use anyhow::{Context, Result};

use crate::cluster::ClusterSpec;
use crate::config::ExperimentConfig;
use crate::coordinator::build::Coordinator;
use crate::coordinator::engine::EventEngine;
use crate::coordinator::run_buffered::{BufEv, BufState, CloudUpdate, GwState};
use crate::cost::CostBreakdown;
use crate::metrics::RoundRecord;
use crate::model::ParamSet;
use crate::runtime::ComputeBackend;
use crate::wal::{
    read_param_set, wal_path, write_param_set, ByteReader, ByteWriter,
    WalFile, WalHeader, SNAPSHOT_EVERY,
};

/// Async-scheduler state decoded from the last WAL record: the event
/// queue and the per-worker in-flight `(delta, mean_loss, compute_secs)`
/// updates. `run_async` consumes this instead of re-kicking the workers.
pub(crate) struct AsyncWalSnapshot {
    /// simulated time the engine had advanced to at the boundary
    pub now: f64,
    /// queued `(at, worker)` completion events, in pop order
    pub queued: Vec<(f64, usize)>,
    /// per-worker update awaiting pickup
    pub pending: Vec<Option<(ParamSet, f32, f64)>>,
}

/// Buffered-scheduler state decoded from the last WAL record: the event
/// queue plus the complete per-gateway buffer/stash/queue state.
/// `run_buffered` consumes this instead of re-kicking the workers.
pub(crate) struct BufferedWalSnapshot {
    /// simulated time the engine had advanced to at the boundary
    pub now: f64,
    /// queued events, in pop order
    pub queued: Vec<(f64, BufEv)>,
    /// the scheduler's full mutable state
    pub state: BufState,
}

/// The chain/counter prefix shared by every record (decoded for *all*
/// records to rebuild the history and the parameter chain; the state
/// section after it is only applied from the last record).
struct WalPrefix {
    record: RoundRecord,
    global_version: u64,
    sim_secs: f64,
    wire_bytes: u64,
    host_secs: f64,
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// This run's WAL identity (checked against the file on resume).
    fn wal_header(&self) -> WalHeader {
        WalHeader {
            experiment: self.cfg.name.clone(),
            seed: self.cfg.seed,
            n_workers: self.workers.len() as u32,
            leaf_sizes: self
                .global
                .leaves
                .iter()
                .map(|l| l.len() as u32)
                .collect(),
        }
    }

    /// Start a fresh write-ahead log under `cfg.wal_dir` (truncating any
    /// previous log of this experiment). `run()` calls this automatically
    /// on a fresh run when `wal_dir` is configured.
    pub fn attach_wal(&mut self) -> Result<()> {
        let dir = self
            .cfg
            .wal_dir
            .clone()
            .context("attach_wal: cfg.wal_dir is not set")?;
        let path = wal_path(Path::new(&dir), &self.cfg.name);
        self.wal = Some(WalFile::create(&path, &self.wal_header())?);
        self.wal_prev_params = None;
        log::info!("write-ahead log started at {path:?}");
        Ok(())
    }

    /// Bytes in the attached WAL so far (None when no WAL is attached).
    pub fn wal_len_bytes(&self) -> Option<u64> {
        self.wal.as_ref().map(|w| w.len_bytes())
    }

    /// Rounds committed so far (== the next round index the run loop
    /// will execute). Independent of `history.len()`, which may be a
    /// `cfg.history_every` subsample.
    pub fn rounds_completed(&self) -> usize {
        self.rounds_done
    }

    /// Durably log the finished round's record (sync/hier schedulers;
    /// called before `commit_round`). No-op without an attached WAL.
    pub(crate) fn wal_append_sync(&mut self, record: &RoundRecord) -> Result<()> {
        self.wal_append_with(record, None, None)
    }

    /// Durably log the finished pseudo-round's record plus the flat async
    /// scheduler's live state (event queue + in-flight updates).
    pub(crate) fn wal_append_async(
        &mut self,
        record: &RoundRecord,
        engine: &EventEngine<usize>,
        pending: &[Option<(ParamSet, f32, f64)>],
    ) -> Result<()> {
        self.wal_append_with(record, Some((engine, pending)), None)
    }

    /// Durably log the finished pseudo-round's record plus the buffered
    /// hierarchy's live state (event queue, gateway buffers, stashes and
    /// both gateway↔leader queues).
    pub(crate) fn wal_append_buffered(
        &mut self,
        record: &RoundRecord,
        engine: &EventEngine<BufEv>,
        st: &BufState,
    ) -> Result<()> {
        self.wal_append_with(record, None, Some((engine, st)))
    }

    fn wal_append_with(
        &mut self,
        record: &RoundRecord,
        async_state: Option<(&EventEngine<usize>, &[Option<(ParamSet, f32, f64)>])>,
        buffered_state: Option<(&EventEngine<BufEv>, &BufState)>,
    ) -> Result<()> {
        if self.wal.is_none() {
            return Ok(());
        }
        let idx = record.round;
        let bits: Vec<Vec<u32>> = self
            .global
            .leaves
            .iter()
            .map(|l| l.iter().map(|x| x.to_bits()).collect())
            .collect();

        let mut w = ByteWriter::new();
        w.put_u64(idx as u64);
        // --- global params: periodic full snapshot, XOR delta between.
        // XOR of bit patterns (never f32 arithmetic) keeps the chain
        // bit-exact through NaNs, -0.0 and denormals alike. Each leaf's
        // words go through the delta-varint lossless stage (WAL v3):
        // XOR deltas are mostly zero and collapse to ~1 byte per word.
        let snapshot =
            idx % SNAPSHOT_EVERY == 0 || self.wal_prev_params.is_none();
        w.put_u8(if snapshot { 0 } else { 1 });
        w.put_usize(bits.len());
        let mut delta_words: Vec<u32> = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        for (li, leaf) in bits.iter().enumerate() {
            w.put_usize(leaf.len());
            let words: &[u32] = if snapshot {
                leaf
            } else {
                let prev = self.wal_prev_params.as_ref().expect("delta has a base");
                let pleaf = &prev[li];
                debug_assert_eq!(leaf.len(), pleaf.len(), "model shape is fixed");
                delta_words.clear();
                delta_words.extend(leaf.iter().zip(pleaf).map(|(&b, &p)| b ^ p));
                &delta_words
            };
            blob.clear();
            crate::compress::lossless::encode_words_append(
                crate::compress::LosslessStage::DeltaVarint,
                words,
                &mut blob,
            );
            w.put_bytes(&blob);
            self.wal_param_raw += words.len() as u64 * 4;
            self.wal_param_enc += blob.len() as u64;
        }
        // --- running counters
        w.put_u64(self.global_version);
        w.put_f64(self.sim_secs);
        w.put_u64(self.wire_bytes);
        w.put_f64(self.host_secs);
        for &b in &record.wire_bytes_class {
            w.put_u64(b);
        }
        // --- the round's RoundRecord (round/sim/wire reuse the fields
        // above; they are identical at the boundary by construction)
        let rec = record;
        w.put_f32(rec.train_loss);
        w.put_opt_f32(rec.eval_loss);
        w.put_opt_f64(rec.eval_acc);
        w.put_usize(rec.platform_secs.len());
        for &s in &rec.platform_secs {
            w.put_f64(s);
        }
        w.put_f64(rec.epsilon);
        w.put_u64(rec.partition_gen);
        w.put_usize(rec.active_members);
        w.put_usize(rec.cost.compute_usd.len());
        for &usd in &rec.cost.compute_usd {
            w.put_f64(usd);
        }
        for row in &rec.cost.egress_usd {
            for &usd in row {
                w.put_f64(usd);
            }
        }
        w.put_f64(rec.cum_cost_usd);
        // --- partition plan: generation + the capacity weights that
        // produced it — enough to regenerate the exact shards on resume
        // (every strategy is deterministic in (seed, generation, weights))
        w.put_u64(self.plan.generation);
        w.put_usize(self.plan.weights.len());
        for &c in &self.plan.weights {
            w.put_f64(c);
        }
        self.monitor.wal_encode(&mut w);
        w.put_usize(self.granularity.local_steps());
        w.put_u64(self.accountant.rounds());
        w.put_u64x4(self.eval_iter.rng_state());
        for worker in &self.workers {
            worker.wal_encode(&mut w);
        }
        self.cluster.wal_encode(&mut w);
        w.put_u64(self.roster_epoch);
        for ch in &self.up {
            ch.wal_encode(&mut w);
        }
        for ch in &self.down {
            ch.wal_encode(&mut w);
        }
        w.put_usize(self.gw_up.len());
        for ch in &self.gw_up {
            ch.wal_encode(&mut w);
        }
        for ch in &self.gw_down {
            ch.wal_encode(&mut w);
        }
        self.aggregator.wal_encode(&mut w);
        w.put_bool(self.hier.is_some());
        if let Some(h) = &self.hier {
            h.wal_encode(&mut w);
        }
        self.wan.wal_encode(&mut w);
        self.cost_ledger.wal_encode(&mut w);
        // --- flat async scheduler extras
        match async_state {
            None => w.put_bool(false),
            Some((engine, pending)) => {
                w.put_bool(true);
                w.put_f64(engine.now());
                let queued = engine.queued();
                w.put_usize(queued.len());
                for (at, &worker) in queued {
                    w.put_f64(at);
                    w.put_u64(worker as u64);
                }
                debug_assert_eq!(pending.len(), self.workers.len());
                for p in pending {
                    match p {
                        None => w.put_bool(false),
                        Some((delta, loss, secs)) => {
                            w.put_bool(true);
                            write_param_set(&mut w, delta);
                            w.put_f32(*loss);
                            w.put_f64(*secs);
                        }
                    }
                }
            }
        }
        // --- buffered hierarchy extras
        match buffered_state {
            None => w.put_bool(false),
            Some((engine, st)) => {
                w.put_bool(true);
                w.put_f64(engine.now());
                let queued = engine.queued();
                w.put_usize(queued.len());
                for (at, ev) in queued {
                    w.put_f64(at);
                    match *ev {
                        BufEv::Member { worker, gen } => {
                            w.put_u8(0);
                            w.put_u64(worker as u64);
                            w.put_u64(gen);
                        }
                        BufEv::Cloud { cloud } => {
                            w.put_u8(1);
                            w.put_u64(cloud as u64);
                        }
                        BufEv::Params { cloud } => {
                            w.put_u8(2);
                            w.put_u64(cloud as u64);
                        }
                    }
                }
                debug_assert_eq!(st.pending.len(), self.workers.len());
                for p in &st.pending {
                    match p {
                        None => w.put_bool(false),
                        Some((delta, loss, secs)) => {
                            w.put_bool(true);
                            write_param_set(&mut w, delta);
                            w.put_f32(*loss);
                            w.put_f64(*secs);
                        }
                    }
                }
                for s in &st.stash {
                    match s {
                        None => w.put_bool(false),
                        Some((delta, loss)) => {
                            w.put_bool(true);
                            write_param_set(&mut w, delta);
                            w.put_f32(*loss);
                        }
                    }
                }
                for &g in &st.kick_gen {
                    w.put_u64(g);
                }
                for &c in &st.base_cycle {
                    w.put_u64(c);
                }
                w.put_usize(st.gw.len());
                for gw in &st.gw {
                    write_param_set(&mut w, &gw.params);
                    w.put_u64(gw.version);
                    w.put_u64(gw.cycle);
                    match &gw.buf {
                        None => w.put_bool(false),
                        Some(b) => {
                            w.put_bool(true);
                            write_param_set(&mut w, b);
                        }
                    }
                    w.put_f64(gw.buf_loss);
                    w.put_usize(gw.buf_samples);
                    debug_assert_eq!(gw.contributed.len(), self.workers.len());
                    for &c in &gw.contributed {
                        w.put_bool(c);
                    }
                    w.put_f64(gw.ns_total);
                    w.put_f64(gw.last_arrive);
                    w.put_f64(gw.up_clamp);
                    w.put_f64(gw.down_clamp);
                }
                for q in &st.cloud_q {
                    w.put_usize(q.len());
                    for cu in q {
                        write_param_set(&mut w, &cu.delta);
                        w.put_f32(cu.mean_loss);
                        w.put_usize(cu.n_samples);
                        w.put_u64(cu.base_version);
                    }
                }
                for q in &st.param_q {
                    w.put_usize(q.len());
                    for (params, version) in q {
                        write_param_set(&mut w, params);
                        w.put_u64(*version);
                    }
                }
            }
        }

        let payload = w.into_bytes();
        self.wal
            .as_mut()
            .expect("checked above")
            .append(&payload)
            .with_context(|| format!("WAL append, round {idx}"))?;
        self.wal_prev_params = Some(bits);
        Ok(())
    }

    /// Decode one record's prefix: advance the parameter bit chain and
    /// rebuild the round's `RoundRecord` + counters. Leaves `r` at the
    /// start of the state section.
    fn wal_read_prefix(
        &self,
        r: &mut ByteReader<'_>,
        idx: usize,
        bits: &mut Vec<Vec<u32>>,
    ) -> Result<WalPrefix> {
        let round = r.get_u64()? as usize;
        anyhow::ensure!(
            round == idx,
            "WAL record {idx} claims round {round} (log out of order)"
        );
        let tag = r.get_u8()?;
        let n_leaves = r.get_usize()?;
        let mut words: Vec<u32> = Vec::new();
        match tag {
            0 => {
                bits.clear();
                for _ in 0..n_leaves {
                    let n = r.get_usize()?;
                    let blob = r.get_bytes()?;
                    crate::compress::lossless::decode_words(blob, &mut words)
                        .with_context(|| format!("WAL record {idx}: snapshot leaf"))?;
                    anyhow::ensure!(
                        words.len() == n,
                        "WAL record {idx}: snapshot leaf decodes to {} words, \
                         header says {n}",
                        words.len()
                    );
                    bits.push(words.clone());
                }
            }
            1 => {
                anyhow::ensure!(
                    !bits.is_empty(),
                    "WAL record {idx} is a delta with no prior snapshot"
                );
                anyhow::ensure!(
                    n_leaves == bits.len(),
                    "WAL record {idx}: delta has {n_leaves} leaves, \
                     chain has {}",
                    bits.len()
                );
                for leaf in bits.iter_mut() {
                    let n = r.get_usize()?;
                    anyhow::ensure!(
                        n == leaf.len(),
                        "WAL record {idx}: delta leaf size {n} != {}",
                        leaf.len()
                    );
                    let blob = r.get_bytes()?;
                    crate::compress::lossless::decode_words(blob, &mut words)
                        .with_context(|| format!("WAL record {idx}: delta leaf"))?;
                    anyhow::ensure!(
                        words.len() == n,
                        "WAL record {idx}: delta leaf decodes to {} words, \
                         header says {n}",
                        words.len()
                    );
                    for (b, &d) in leaf.iter_mut().zip(&words) {
                        *b ^= d;
                    }
                }
            }
            other => anyhow::bail!("WAL record {idx}: bad params tag {other}"),
        }
        let global_version = r.get_u64()?;
        let sim_secs = r.get_f64()?;
        let wire_bytes = r.get_u64()?;
        let host_secs = r.get_f64()?;
        let mut wire_bytes_class = [0u64; 3];
        for b in wire_bytes_class.iter_mut() {
            *b = r.get_u64()?;
        }
        let train_loss = r.get_f32()?;
        let eval_loss = r.get_opt_f32()?;
        let eval_acc = r.get_opt_f64()?;
        let n_secs = r.get_usize()?;
        anyhow::ensure!(
            n_secs == self.workers.len(),
            "WAL record {idx} covers {n_secs} platforms, run has {}",
            self.workers.len()
        );
        let mut platform_secs = Vec::with_capacity(n_secs);
        for _ in 0..n_secs {
            platform_secs.push(r.get_f64()?);
        }
        let epsilon = r.get_f64()?;
        let partition_gen = r.get_u64()?;
        let active_members = r.get_usize()?;
        anyhow::ensure!(
            active_members <= self.workers.len(),
            "WAL record {idx} claims {active_members} active members, \
             run has {} workers",
            self.workers.len()
        );
        let n_clouds = r.get_usize()?;
        anyhow::ensure!(
            n_clouds == self.cluster.n_clouds(),
            "WAL record {idx} bills {n_clouds} clouds, run has {}",
            self.cluster.n_clouds()
        );
        let mut cost = CostBreakdown::zero(n_clouds);
        for usd in cost.compute_usd.iter_mut() {
            *usd = r.get_f64()?;
        }
        for row in cost.egress_usd.iter_mut() {
            for usd in row.iter_mut() {
                *usd = r.get_f64()?;
            }
        }
        let cum_cost_usd = r.get_f64()?;
        Ok(WalPrefix {
            record: RoundRecord {
                round,
                sim_secs,
                wire_bytes,
                wire_bytes_class,
                train_loss,
                eval_loss,
                eval_acc,
                platform_secs,
                epsilon,
                partition_gen,
                active_members,
                cost,
                cum_cost_usd,
            },
            global_version,
            sim_secs,
            wire_bytes,
            host_secs,
        })
    }

    /// Apply the state section of the *last* WAL record (everything after
    /// the prefix), in the order it was encoded.
    fn wal_apply_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        // --- partition plan: regenerate the stored generation's exact
        // shards, then rebuild each worker's token buffer from them
        let gen = r.get_u64()?;
        let n_weights = r.get_usize()?;
        anyhow::ensure!(
            n_weights == self.workers.len(),
            "WAL plan weights cover {n_weights} platforms, run has {}",
            self.workers.len()
        );
        let mut weights = Vec::with_capacity(n_weights);
        for _ in 0..n_weights {
            weights.push(r.get_f64()?);
        }
        if gen != self.plan.generation {
            self.planner.set_generation(gen);
            self.plan = self.planner.plan(&self.corpus, &self.cluster, &weights);
            for (w, shard) in self.plan.shards.iter().enumerate() {
                self.workers[w].set_shard(
                    &shard.tokens,
                    self.batch_size,
                    self.seq_len,
                    self.cfg.seed ^ gen,
                );
            }
        }
        self.monitor.wal_decode(r)?;
        self.granularity.restore_steps(r.get_usize()?);
        self.accountant.restore_rounds(r.get_u64()?);
        self.eval_iter.restore_rng(r.get_u64x4()?);
        // worker RNG overlays come after set_shard rebuilt the samplers
        for worker in &mut self.workers {
            worker.wal_decode(r)?;
        }
        // gateway elections first, then point the channels at them; the
        // channels' own codec/EF/seq state is overlaid afterwards
        // (retargeting only moves the far end of the pipe)
        self.cluster.wal_decode(r)?;
        // the roster epoch re-derives every secure-aggregation session
        // (flat + per-cloud) over the restored active roster
        self.roster_epoch = r.get_u64()?;
        self.rekey_secure();
        for c in 0..self.cluster.n_clouds() {
            self.retarget_cloud_channels(c);
        }
        for ch in &mut self.up {
            ch.wal_decode(r)?;
        }
        for ch in &mut self.down {
            ch.wal_decode(r)?;
        }
        let n_gw = r.get_usize()?;
        anyhow::ensure!(
            n_gw == self.gw_up.len(),
            "WAL has {n_gw} gateway channel pairs, run has {} \
             (hierarchical config changed across resume?)",
            self.gw_up.len()
        );
        for ch in &mut self.gw_up {
            ch.wal_decode(r)?;
        }
        for ch in &mut self.gw_down {
            ch.wal_decode(r)?;
        }
        self.aggregator.wal_decode(r)?;
        let has_hier = r.get_bool()?;
        anyhow::ensure!(
            has_hier == self.hier.is_some(),
            "hierarchical config changed across resume"
        );
        if let Some(h) = &mut self.hier {
            h.wal_decode(r)?;
        }
        self.wan.wal_decode(r)?;
        self.cost_ledger.wal_decode(r)?;
        // --- flat async scheduler extras
        let is_async = r.get_bool()?;
        anyhow::ensure!(
            is_async == (self.aggregator.is_async() && !self.cfg.hierarchical),
            "aggregation mode changed across resume \
             (WAL flat-async={is_async}, config async={} hierarchical={})",
            self.aggregator.is_async(),
            self.cfg.hierarchical
        );
        if is_async {
            let now = r.get_f64()?;
            let nq = r.get_usize()?;
            let mut queued = Vec::with_capacity(nq);
            for _ in 0..nq {
                let at = r.get_f64()?;
                let worker = r.get_u64()? as usize;
                anyhow::ensure!(
                    worker < self.workers.len(),
                    "WAL queued event names worker {worker}, run has {}",
                    self.workers.len()
                );
                queued.push((at, worker));
            }
            let mut pending = Vec::with_capacity(self.workers.len());
            for _ in 0..self.workers.len() {
                pending.push(if r.get_bool()? {
                    let delta = read_param_set(r)?;
                    let loss = r.get_f32()?;
                    let secs = r.get_f64()?;
                    Some((delta, loss, secs))
                } else {
                    None
                });
            }
            self.async_resume = Some(AsyncWalSnapshot { now, queued, pending });
        }
        // --- buffered hierarchy extras
        let is_buffered = r.get_bool()?;
        anyhow::ensure!(
            is_buffered == (self.aggregator.is_async() && self.cfg.hierarchical),
            "aggregation mode changed across resume \
             (WAL buffered={is_buffered}, config async={} hierarchical={})",
            self.aggregator.is_async(),
            self.cfg.hierarchical
        );
        if is_buffered {
            let n = self.workers.len();
            let n_clouds = self.cluster.n_clouds();
            let now = r.get_f64()?;
            let nq = r.get_usize()?;
            let mut queued = Vec::with_capacity(nq);
            for _ in 0..nq {
                let at = r.get_f64()?;
                let ev = match r.get_u8()? {
                    0 => {
                        let worker = r.get_u64()? as usize;
                        anyhow::ensure!(
                            worker < n,
                            "WAL queued event names worker {worker}, run \
                             has {n}"
                        );
                        BufEv::Member { worker, gen: r.get_u64()? }
                    }
                    tag @ (1 | 2) => {
                        let cloud = r.get_u64()? as usize;
                        anyhow::ensure!(
                            cloud < n_clouds,
                            "WAL queued event names cloud {cloud}, run \
                             has {n_clouds}"
                        );
                        if tag == 1 {
                            BufEv::Cloud { cloud }
                        } else {
                            BufEv::Params { cloud }
                        }
                    }
                    other => {
                        anyhow::bail!("WAL buffered event: bad tag {other}")
                    }
                };
                queued.push((at, ev));
            }
            let mut pending = Vec::with_capacity(n);
            for _ in 0..n {
                pending.push(if r.get_bool()? {
                    let delta = read_param_set(r)?;
                    let loss = r.get_f32()?;
                    let secs = r.get_f64()?;
                    Some((delta, loss, secs))
                } else {
                    None
                });
            }
            let mut stash = Vec::with_capacity(n);
            for _ in 0..n {
                stash.push(if r.get_bool()? {
                    let delta = read_param_set(r)?;
                    let loss = r.get_f32()?;
                    Some((delta, loss))
                } else {
                    None
                });
            }
            let mut kick_gen = Vec::with_capacity(n);
            for _ in 0..n {
                kick_gen.push(r.get_u64()?);
            }
            let mut base_cycle = Vec::with_capacity(n);
            for _ in 0..n {
                base_cycle.push(r.get_u64()?);
            }
            let n_gw = r.get_usize()?;
            anyhow::ensure!(
                n_gw == n_clouds,
                "WAL has {n_gw} gateway buffer states, run has {n_clouds} \
                 clouds"
            );
            let mut gw = Vec::with_capacity(n_gw);
            for _ in 0..n_gw {
                let params = read_param_set(r)?;
                let version = r.get_u64()?;
                let cycle = r.get_u64()?;
                let buf = if r.get_bool()? {
                    Some(read_param_set(r)?)
                } else {
                    None
                };
                let buf_loss = r.get_f64()?;
                let buf_samples = r.get_usize()?;
                let mut contributed = Vec::with_capacity(n);
                for _ in 0..n {
                    contributed.push(r.get_bool()?);
                }
                let ns_total = r.get_f64()?;
                let last_arrive = r.get_f64()?;
                let up_clamp = r.get_f64()?;
                let down_clamp = r.get_f64()?;
                gw.push(GwState {
                    params,
                    version,
                    cycle,
                    buf,
                    buf_loss,
                    buf_samples,
                    contributed,
                    ns_total,
                    last_arrive,
                    up_clamp,
                    down_clamp,
                });
            }
            let mut cloud_q = Vec::with_capacity(n_clouds);
            for _ in 0..n_clouds {
                let len = r.get_usize()?;
                let mut q = std::collections::VecDeque::with_capacity(len);
                for _ in 0..len {
                    let delta = read_param_set(r)?;
                    let mean_loss = r.get_f32()?;
                    let n_samples = r.get_usize()?;
                    let base_version = r.get_u64()?;
                    q.push_back(CloudUpdate {
                        delta,
                        mean_loss,
                        n_samples,
                        base_version,
                    });
                }
                cloud_q.push(q);
            }
            let mut param_q = Vec::with_capacity(n_clouds);
            for _ in 0..n_clouds {
                let len = r.get_usize()?;
                let mut q = std::collections::VecDeque::with_capacity(len);
                for _ in 0..len {
                    let params = read_param_set(r)?;
                    let version = r.get_u64()?;
                    q.push_back((params, version));
                }
                param_q.push(q);
            }
            self.buffered_resume = Some(BufferedWalSnapshot {
                now,
                queued,
                state: BufState {
                    pending,
                    stash,
                    kick_gen,
                    base_cycle,
                    gw,
                    cloud_q,
                    param_q,
                },
            });
        }
        Ok(())
    }

    /// Resume a crashed run from its write-ahead log, bit-identically:
    /// open and validate the WAL under `cfg.wal_dir`, rebuild the
    /// coordinator exactly as a fresh run would, replay every record to
    /// reconstruct the history and the parameter chain, overlay the last
    /// record's state, and strip the crash event that stopped the run so
    /// it cannot fire again. The returned coordinator's `run()` continues
    /// from the first un-logged round.
    pub fn resume(
        cfg: ExperimentConfig,
        cluster: ClusterSpec,
        backend: &'a B,
        init: ParamSet,
        batch_size: usize,
        seq_len: usize,
    ) -> Result<Coordinator<'a, B>> {
        let dir = cfg
            .wal_dir
            .clone()
            .context("resume: cfg.wal_dir is not set")?;
        let path = wal_path(Path::new(&dir), &cfg.name);
        let (wal, header, records) = WalFile::open(&path)?;
        // identity + shape guard before building anything: a WAL must
        // never silently restore into a different experiment or model
        anyhow::ensure!(
            header.experiment == cfg.name,
            "WAL {path:?} belongs to experiment {:?}, not {:?}",
            header.experiment,
            cfg.name
        );
        anyhow::ensure!(
            header.seed == cfg.seed,
            "WAL {path:?} was written with seed {}, config has {}",
            header.seed,
            cfg.seed
        );
        anyhow::ensure!(
            header.n_workers as usize == cluster.n(),
            "WAL {path:?} covers {} workers, cluster has {}",
            header.n_workers,
            cluster.n()
        );
        let leaf_sizes: Vec<u32> =
            init.leaves.iter().map(|l| l.len() as u32).collect();
        anyhow::ensure!(
            header.leaf_sizes == leaf_sizes,
            "WAL {path:?} model shape {:?} does not match this model {:?}",
            header.leaf_sizes,
            leaf_sizes
        );
        anyhow::ensure!(
            !records.is_empty(),
            "WAL {path:?} has a header but no round records — nothing to \
             resume (the run crashed before its first round boundary)"
        );

        let mut coord =
            Coordinator::new(cfg, cluster, backend, init, batch_size, seq_len)?;
        let mut bits: Vec<Vec<u32>> = Vec::new();
        let last = records.len() - 1;
        for (i, payload) in records.iter().enumerate() {
            let mut r = ByteReader::new(payload);
            let prefix = coord
                .wal_read_prefix(&mut r, i, &mut bits)
                .with_context(|| format!("WAL {path:?}: record {i}"))?;
            if i == last {
                coord.global_version = prefix.global_version;
                coord.sim_secs = prefix.sim_secs;
                coord.wire_bytes = prefix.wire_bytes;
                coord.host_secs = prefix.host_secs;
            }
            // route the replayed record through the same sink a live
            // round uses: CSV streaming, history_every thinning and the
            // round counter all match the uninterrupted run
            coord.commit_round(prefix.record)?;
            if i == last {
                coord
                    .wal_apply_state(&mut r)
                    .with_context(|| format!("WAL {path:?}: record {i} state"))?;
                r.finish()
                    .with_context(|| format!("WAL {path:?}: record {i}"))?;
            }
        }
        coord.global = ParamSet {
            leaves: bits
                .iter()
                .map(|l| l.iter().map(|&b| f32::from_bits(b)).collect())
                .collect(),
        };
        coord.wal_prev_params = Some(bits);
        let resume_round = coord.rounds_done;
        // the crash that stopped the run (and any earlier one) must not
        // fire again; every other past fault's *effect* was restored from
        // the log, and faults due at resume_round replay normally
        coord.cfg.faults.strip_crashes_through(resume_round);
        coord.wal = Some(wal);
        log::info!(
            "resumed experiment {:?} at round {resume_round} from WAL \
             {path:?} ({} records, {} bytes)",
            coord.cfg.name,
            records.len(),
            coord.wal.as_ref().map(|w| w.len_bytes()).unwrap_or(0),
        );
        Ok(coord)
    }
}

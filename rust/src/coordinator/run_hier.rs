//! Hierarchical synchronous round: reduce inside each cloud at its
//! gateway, exchange one partial aggregate per cloud over the WAN.
//!
//! Event flow per round (all on the shared [`EventEngine`], so
//! intra-cloud hops, WAN legs and other clouds' compute overlap):
//!
//! ```text
//! worker w:   ComputeDone ──codec──▶ AtGateway(cloud)
//! cloud c:    last AtGateway ──reduce──▶ gw_up WAN leg ──▶ PartialArrived
//! leader:     all PartialArrived ──▶ cross-cloud reduce ──▶ broadcast
//! broadcast:  leader ──▶ GwBcast(c) ──▶ gateway fans out ──▶ BcastDone(w)
//! ```
//!
//! With secure aggregation the gateway forwards the *masked* partial sum
//! (in deployment each worker masks before its uplink; the simulation
//! masks at the gateway, which carries identical bytes and timing since
//! secure aggregation requires dense uncompressed updates). Pairwise
//! masks span all workers, so a single cloud's partial stays masked and
//! only the leader's full cross-cloud sum cancels them. DP privatization
//! happens at the worker in `local_round`, before anything ships.
//!
//! ## Gateway failover
//!
//! A remote gateway's WAN egress can die mid-run (fault injection:
//! [`crate::netsim::FaultPlan`]). The leader only *observes* the death
//! at that cloud's reduce — the member uplinks ride the still-healthy
//! AZ fabric — so that is where the failover runs: re-elect the next
//! member by id ([`crate::cluster::ClusterSpec::reelect_gateway`]),
//! rebuild the WAN mesh around the standby (`Wan::reelect_gateway`,
//! dropping every warm connection), re-route the already-delivered
//! member updates to the new gateway over intra-AZ links, then reduce
//! and ship the partial as usual. The round completes; nothing is lost.
//! Because every member update still reaches the reduce exactly once,
//! secure-aggregation mask coverage is unaffected, and every forward is
//! priced through the WAN so the per-class byte ledger stays honest.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::aggregation::{ClientUpdate, PartialAggregate};
use crate::coordinator::build::Coordinator;
use crate::coordinator::engine::EventEngine;
use crate::metrics::RoundRecord;
use crate::runtime::ComputeBackend;

/// Hierarchical round events.
enum Ev {
    /// worker finished local training
    ComputeDone(usize),
    /// one member update reached its cloud's gateway
    AtGateway { cloud: usize },
    /// failover: one member update re-routed to the re-elected gateway
    Forwarded { cloud: usize },
    /// the cloud's partial aggregate reached the leader
    PartialArrived { cloud: usize },
    /// the broadcast reached a cloud's gateway
    GwBcast { cloud: usize },
    /// the broadcast reached a member worker
    BcastDone(usize),
}

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// One hierarchical round (see module docs).
    pub(crate) fn hier_round(&mut self, round: usize) -> Result<RoundRecord> {
        // per-cloud *active* member lists: preempted members sit the
        // round out, and every barrier below counts the active set only
        let n_clouds = self.cluster.n_clouds();
        let clouds: Vec<Vec<usize>> = (0..n_clouds)
            .map(|c| self.cluster.active_members(c))
            .collect();
        let n_active: usize = clouds.iter().map(|m| m.len()).sum();
        let step_counts = self.local_step_counts();
        let round_start = self.sim_secs;
        let mut engine: EventEngine<Ev> = EventEngine::new(round_start);

        // --- phase 1: local training on every active worker node
        let locals = self.train_all_workers(&step_counts)?;
        for (w, r) in locals.iter().enumerate() {
            if let Some(r) = r {
                engine.at(round_start + r.compute_secs, Ev::ComputeDone(w));
            }
        }

        let n_total: f64 = clouds
            .iter()
            .flatten()
            .map(|&w| self.workers[w].n_samples as f64)
            .sum();
        let sa_round = self.global_version;

        // --- phase 2: intra-cloud uplinks, gateway reduces, WAN legs
        let mut member_updates: Vec<Option<ClientUpdate>> =
            (0..self.workers.len()).map(|_| None).collect();
        let mut cloud_pending: Vec<usize> =
            clouds.iter().map(|m| m.len()).collect();
        let mut partials: Vec<Option<PartialAggregate>> =
            (0..n_clouds).map(|_| None).collect();
        let mut arrived_clouds = 0usize;
        let mut round_wire = 0u64;
        let mut agg_host = 0.0f64;

        while arrived_clouds < n_clouds {
            match engine.pop().expect("partial arrivals pending") {
                Ev::ComputeDone(w) => {
                    let c = self.cluster.cloud_of(w);
                    let gw = self.cluster.gateway(c);
                    let local = locals[w].as_ref().expect("active trained");
                    // gateway members loop back through the codec; others
                    // pay the intra-cloud hop
                    let (delivered, secs, wire) = if w == gw {
                        (self.up[w].codec_loopback(&local.update)?, 0.0, 0)
                    } else {
                        let d = self.up[w].send_update(
                            &local.update,
                            local.mean_loss,
                            self.workers[w].n_samples,
                            1.0,
                            &mut self.wan,
                        )?;
                        (d.update, d.secs, d.wire_bytes)
                    };
                    round_wire += wire;
                    member_updates[w] = Some(ClientUpdate {
                        worker: w,
                        n_samples: self.workers[w].n_samples,
                        local_loss: local.mean_loss,
                        delta: delivered,
                        staleness: 0,
                    });
                    engine.after(secs, Ev::AtGateway { cloud: c });
                }
                // Forwarded completions share the AtGateway tail: once
                // the forwards were scheduled the re-elected gateway is
                // alive by construction, so the failover check below is
                // a no-op the second time around
                Ev::AtGateway { cloud } | Ev::Forwarded { cloud } => {
                    cloud_pending[cloud] -= 1;
                    if cloud_pending[cloud] > 0 {
                        continue;
                    }
                    // every member is in — but the gateway may have died
                    // since the uplinks were sent (fault injection): then
                    // fail over and re-route before reducing
                    let (delays, wire) = self.hier_failover(
                        round,
                        cloud,
                        &clouds[cloud],
                        &member_updates,
                    )?;
                    round_wire += wire;
                    if !delays.is_empty() {
                        cloud_pending[cloud] = delays.len();
                        for d in delays {
                            engine.after(d, Ev::Forwarded { cloud });
                        }
                        continue;
                    }
                    self.hier_cloud_ready(
                        cloud,
                        &clouds[cloud],
                        &mut member_updates,
                        n_total,
                        sa_round,
                        &mut engine,
                        &mut partials,
                        &mut round_wire,
                        &mut agg_host,
                    )?;
                }
                Ev::PartialArrived { .. } => arrived_clouds += 1,
                _ => unreachable!("no broadcast yet"),
            }
        }
        let barrier_at = engine.now();
        let partials: Vec<PartialAggregate> =
            partials.into_iter().map(|p| p.expect("arrived")).collect();

        // --- phase 3: cross-cloud reduce at the leader
        let t0 = Instant::now();
        if self.secure.is_some() {
            // sum of masked partials over *all* clouds: masks only cancel
            // with every member of the current roster present exactly
            // once — the per-cloud bookkeeping and the roster-change
            // re-keying guarantee it, this assert keeps it honest
            // (applying a still-masked sum would silently train garbage)
            let covered: usize = partials.iter().map(|p| p.n_members).sum();
            assert_eq!(
                covered, n_active,
                "secure hier reduce must cover the active roster"
            );
            let mut agg = partials[0].delta.clone();
            let terms: Vec<(f32, &crate::model::ParamSet)> = partials[1..]
                .iter()
                .map(|p| (1.0f32, &p.delta))
                .collect();
            agg.axpy_many(&terms);
            self.apply_masked_aggregate(&agg);
        } else {
            let hier = self.hier.as_mut().expect("hier mode");
            hier.reduce_global(&mut self.global, &partials);
        }
        self.host_secs += agg_host + t0.elapsed().as_secs_f64();
        self.accountant.record_round();
        self.global_version += 1;

        // --- phase 4: two-stage broadcast (leader → gateways → members);
        // gateways are read from the cluster, which reflects any
        // re-election this round
        for c in 0..n_clouds {
            let gw = self.cluster.gateway(c);
            if gw == self.leader {
                engine.after(0.0, Ev::GwBcast { cloud: c });
            } else {
                let (secs, wire) =
                    self.gw_down[c].send_params(&self.global, &mut self.wan)?;
                round_wire += wire;
                engine.after(secs, Ev::GwBcast { cloud: c });
            }
        }
        let mut have_model = 0usize;
        while have_model < n_active {
            match engine.pop().expect("broadcast events pending") {
                Ev::GwBcast { cloud } => {
                    have_model += 1; // the gateway itself
                    let gw = self.cluster.gateway(cloud);
                    for &m in &clouds[cloud] {
                        if m == gw {
                            continue;
                        }
                        if m == self.leader {
                            // the leader hosts the global model already
                            have_model += 1;
                            continue;
                        }
                        let (secs, wire) = self.down[m]
                            .send_params(&self.global, &mut self.wan)?;
                        round_wire += wire;
                        engine.after(secs, Ev::BcastDone(m));
                    }
                }
                Ev::BcastDone(_) => have_model += 1,
                _ => unreachable!("uplinks all drained"),
            }
        }
        let round_end = engine.now();
        self.sim_events += engine.scheduled_total();

        // --- phase 5: totals, monitor & adjust (Figure-2 cycle), eval
        self.finalize_round(
            round,
            &locals,
            round_start,
            barrier_at,
            round_end,
            round_wire,
        )
    }

    /// One hierarchical round with every cloud's intra-round traffic on
    /// its own host thread (`cfg.par_rounds`). Clouds are independent
    /// between the round barrier and the gateway legs: member uplinks
    /// ride intra-AZ links owned by one cloud, and the gateway reduce
    /// only reads that cloud's updates. Each parallel task draws link
    /// jitter from its cloud's dedicated RNG stream and records byte
    /// ledger/warmth effects into a [`WanScratch`], merged serially in
    /// cloud order afterwards — so the result is deterministic and
    /// thread-count-invariant (but on a different jitter stream than the
    /// serial scheduler, which draws from the shared WAN RNG in event
    /// order). Cross-cloud phases (partial legs, reduce, gateway
    /// broadcast) stay serial in cloud order. `cfg.validate` keeps
    /// secure aggregation and fault plans off this path.
    pub(crate) fn hier_round_par(
        &mut self,
        round: usize,
    ) -> Result<RoundRecord> {
        use crate::netsim::WanScratch;
        use crate::transport::Channel;
        use crate::util::rng::Pcg64;

        struct CloudOut {
            partial: PartialAggregate,
            /// when the cloud's reduce input is complete (compute +
            /// member uplinks, gateway loopback free)
            ready_at: f64,
            wire: u64,
            host: f64,
        }
        type Slot<T> = Option<Result<T>>;

        let n = self.workers.len();
        let clouds = self.cluster.clouds();
        let n_clouds = clouds.len();
        let step_counts = self.local_step_counts();
        let round_start = self.sim_secs;

        // --- phase 1: local training on every worker node (validation
        // keeps fault plans off the par-rounds path, so the roster is
        // full and every slot is Some)
        let locals = self.train_all_workers(&step_counts)?;

        // --- phase 2: per-cloud parallel member uplinks + gateway reduce
        let gws: Vec<usize> =
            (0..n_clouds).map(|c| self.cluster.gateway(c)).collect();
        let n_samples: Vec<usize> =
            self.workers.iter().map(|w| w.n_samples).collect();
        let mut rngs = self.wan.take_cloud_rngs();
        let mut scratches: Vec<WanScratch> =
            vec![WanScratch::default(); n_clouds];
        let mut outs: Vec<Slot<CloudOut>> =
            (0..n_clouds).map(|_| None).collect();
        {
            let wan = &self.wan;
            let hier = self.hier.as_ref().expect("hier mode");
            let locals = &locals;
            let (gws, n_samples) = (&gws, &n_samples);
            let mut up_refs: Vec<Option<&mut Channel>> =
                self.up.iter_mut().map(Some).collect();
            let mut items: Vec<(
                usize,
                Vec<(usize, &mut Channel)>,
                &mut Pcg64,
                &mut WanScratch,
                &mut Slot<CloudOut>,
            )> = Vec::with_capacity(n_clouds);
            for (((c, rng), scratch), out) in (0..n_clouds)
                .zip(rngs.iter_mut())
                .zip(scratches.iter_mut())
                .zip(outs.iter_mut())
            {
                let ups = clouds[c]
                    .iter()
                    .map(|&w| {
                        (w, up_refs[w].take().expect("worker in one cloud"))
                    })
                    .collect();
                items.push((c, ups, rng, scratch, out));
            }
            crate::util::par::run_items(items, |(c, ups, rng, scratch, out)| {
                let task = || -> Result<CloudOut> {
                    let gw = gws[c];
                    let mut ready_at = round_start;
                    let mut wire = 0u64;
                    let mut members = Vec::with_capacity(ups.len());
                    // worker-id order (the member list), so the reduce
                    // and the rng draws are arrival-order-independent
                    for (w, ch) in ups {
                        let local =
                            locals[w].as_ref().expect("full roster trained");
                        let (delivered, secs) = if w == gw {
                            (ch.codec_loopback(&local.update)?, 0.0)
                        } else {
                            let d = ch.send_update_scoped(
                                &local.update,
                                local.mean_loss,
                                n_samples[w],
                                1.0,
                                wan,
                                rng,
                                scratch,
                            )?;
                            wire += d.wire_bytes;
                            (d.update, d.secs)
                        };
                        ready_at = ready_at
                            .max(round_start + local.compute_secs + secs);
                        members.push(ClientUpdate {
                            worker: w,
                            n_samples: n_samples[w],
                            local_loss: local.mean_loss,
                            delta: delivered,
                            staleness: 0,
                        });
                    }
                    let t0 = Instant::now();
                    let partial = hier.reduce_cloud(c, &members);
                    let host = t0.elapsed().as_secs_f64();
                    Ok(CloudOut { partial, ready_at, wire, host })
                };
                *out = Some(task());
            });
        }
        self.wan.restore_cloud_rngs(rngs);

        // serial merge in cloud order: ledgers, warmth, totals
        let mut round_wire = 0u64;
        let mut agg_host = 0.0f64;
        let mut partials = Vec::with_capacity(n_clouds);
        let mut ready = Vec::with_capacity(n_clouds);
        for (c, out) in outs.into_iter().enumerate() {
            let o = out.expect("every cloud reduced")?;
            self.wan.apply_scratch(&scratches[c]);
            round_wire += o.wire;
            agg_host += o.host;
            partials.push(o.partial);
            ready.push(o.ready_at);
        }

        // --- phase 3: gateway → leader legs (serial, shared WAN RNG,
        // cloud order) and the cross-cloud reduce at the barrier
        let mut barrier_at = round_start;
        let mut arrived = Vec::with_capacity(n_clouds);
        for (c, p) in partials.into_iter().enumerate() {
            if gws[c] == self.leader {
                let delta = self.gw_up[c].codec_loopback(&p.delta)?;
                barrier_at = barrier_at.max(ready[c]);
                arrived.push(PartialAggregate { delta, ..p });
            } else {
                let d = self.gw_up[c].send_update(
                    &p.delta,
                    p.mean_loss,
                    p.n_samples,
                    p.weight,
                    &mut self.wan,
                )?;
                round_wire += d.wire_bytes;
                barrier_at = barrier_at.max(ready[c] + d.secs);
                arrived.push(PartialAggregate {
                    cloud: c,
                    n_members: p.n_members,
                    n_samples: d.n_samples,
                    weight: d.weight,
                    mean_loss: d.local_loss,
                    delta: d.update,
                });
            }
        }
        let t0 = Instant::now();
        let hier = self.hier.as_mut().expect("hier mode");
        hier.reduce_global(&mut self.global, &arrived);
        self.host_secs += agg_host + t0.elapsed().as_secs_f64();
        self.accountant.record_round();
        self.global_version += 1;

        // --- phase 4: two-stage broadcast. Leader → gateways stays
        // serial (shared WAN RNG, cloud order) ...
        let mut gw_at = vec![0.0f64; n_clouds];
        for c in 0..n_clouds {
            if gws[c] == self.leader {
                gw_at[c] = barrier_at;
            } else {
                let (secs, wire) =
                    self.gw_down[c].send_params(&self.global, &mut self.wan)?;
                round_wire += wire;
                gw_at[c] = barrier_at + secs;
            }
        }
        // ... then each gateway fans out to its members in parallel
        let mut rngs = self.wan.take_cloud_rngs();
        let mut scratches: Vec<WanScratch> =
            vec![WanScratch::default(); n_clouds];
        let mut outs: Vec<Slot<(f64, u64)>> =
            (0..n_clouds).map(|_| None).collect();
        let mut fanout = 0u64;
        {
            let wan = &self.wan;
            let global = &self.global;
            let leader = self.leader;
            let gw_at = &gw_at;
            let mut down_refs: Vec<Option<&mut Channel>> =
                self.down.iter_mut().map(Some).collect();
            let mut items: Vec<(
                usize,
                Vec<&mut Channel>,
                &mut Pcg64,
                &mut WanScratch,
                &mut Slot<(f64, u64)>,
            )> = Vec::with_capacity(n_clouds);
            for (((c, rng), scratch), out) in (0..n_clouds)
                .zip(rngs.iter_mut())
                .zip(scratches.iter_mut())
                .zip(outs.iter_mut())
            {
                let downs: Vec<&mut Channel> = clouds[c]
                    .iter()
                    .filter(|&&m| m != gws[c] && m != leader)
                    .map(|&m| down_refs[m].take().expect("one cloud"))
                    .collect();
                fanout += downs.len() as u64;
                items.push((c, downs, rng, scratch, out));
            }
            crate::util::par::run_items(items, |(c, downs, rng, scratch, out)| {
                let task = || -> Result<(f64, u64)> {
                    let mut end = gw_at[c];
                    let mut wire = 0u64;
                    for ch in downs {
                        let (secs, w) =
                            ch.send_params_scoped(global, wan, rng, scratch)?;
                        wire += w;
                        end = end.max(gw_at[c] + secs);
                    }
                    Ok((end, wire))
                };
                *out = Some(task());
            });
        }
        self.wan.restore_cloud_rngs(rngs);
        let mut round_end = barrier_at;
        for (c, out) in outs.into_iter().enumerate() {
            let (end, wire) = out.expect("every cloud broadcast")?;
            self.wan.apply_scratch(&scratches[c]);
            round_wire += wire;
            round_end = round_end.max(gw_at[c]).max(end);
        }
        // event accounting mirrors the serial engine's schedule: compute
        // completions, gateway arrivals, partial legs, gateway broadcasts
        // and the member fan-out
        self.sim_events += 2 * n as u64 + 2 * n_clouds as u64 + fanout;

        // --- phase 5: totals, monitor & adjust (Figure-2 cycle), eval
        self.finalize_round(
            round,
            &locals,
            round_start,
            barrier_at,
            round_end,
            round_wire,
        )
    }

    /// Shared tail of a cloud's uplink phase — run once every member
    /// update is at the (live) gateway, whether via healthy `AtGateway`
    /// arrivals or failover forwards: take the members in worker-id
    /// order, reduce and ship the partial, schedule its arrival.
    #[allow(clippy::too_many_arguments)]
    fn hier_cloud_ready(
        &mut self,
        cloud: usize,
        members: &[usize],
        member_updates: &mut [Option<ClientUpdate>],
        n_total: f64,
        sa_round: u64,
        engine: &mut EventEngine<Ev>,
        partials: &mut [Option<PartialAggregate>],
        round_wire: &mut u64,
        agg_host: &mut f64,
    ) -> Result<()> {
        let taken: Vec<ClientUpdate> = members
            .iter()
            .map(|&w| member_updates[w].take().expect("member in"))
            .collect();
        let (arrived, secs, wire, host) =
            self.hier_reduce_and_ship(cloud, taken, n_total, sa_round)?;
        *agg_host += host;
        *round_wire += wire;
        partials[cloud] = Some(arrived);
        engine.after(secs, Ev::PartialArrived { cloud });
        Ok(())
    }

    /// Detect a dead gateway at reduce time and fail over (see module
    /// docs). Returns the forward-transfer delays for re-routing each
    /// already-delivered member update to the re-elected gateway, plus
    /// the wire bytes those forwards cost; empty = gateway healthy, no
    /// failover needed.
    fn hier_failover(
        &mut self,
        round: usize,
        cloud: usize,
        members: &[usize],
        member_updates: &[Option<ClientUpdate>],
    ) -> Result<(Vec<f64>, u64)> {
        let gw = self.cluster.gateway(cloud);
        if !self.wan.node_down(gw) {
            return Ok((Vec::new(), 0));
        }
        let new_gw = self.fail_over_gateway(round, cloud)?;
        log::warn!(
            "round {round}: cloud {cloud} gateway {gw} found dead at reduce \
             time; re-routing {} member updates to node {new_gw}",
            members.len() - 1
        );
        // the decoded member updates sit at the dead gateway, whose AZ
        // fabric survives: forward each as a dense frame to the standby
        let mut delays = Vec::with_capacity(members.len());
        let mut wire = 0u64;
        for &w in members {
            if w == new_gw {
                continue;
            }
            let numel = member_updates[w]
                .as_ref()
                .expect("member delivered before failover")
                .delta
                .numel();
            let bytes = self.dense_frame_bytes(numel);
            let st = self
                .wan
                .transfer(gw, new_gw, bytes, self.cfg.protocol, self.cfg.streams)
                .context("failover forward")?;
            wire += st.wire_bytes;
            delays.push(st.time_s);
        }
        Ok((delays, wire))
    }

    /// Reduce one cloud's member updates at its gateway (members in
    /// worker-id order, so summation never depends on arrival order) and
    /// ship the partial toward the leader. Returns the partial as it
    /// arrives, the WAN delay, the wire bytes and the host CPU seconds
    /// spent reducing.
    fn hier_reduce_and_ship(
        &mut self,
        cloud: usize,
        members: Vec<ClientUpdate>,
        n_total: f64,
        sa_round: u64,
    ) -> Result<(PartialAggregate, f64, u64, f64)> {
        let gw = self.cluster.gateway(cloud);
        let t0 = Instant::now();
        let partial = if self.secure.is_some() {
            let psum = self.secure_partial(&members, n_total, sa_round);
            PartialAggregate {
                cloud,
                n_members: members.len(),
                n_samples: members.iter().map(|u| u.n_samples).sum(),
                // masked partials recombine by plain summation
                weight: 0.0,
                mean_loss: 0.0,
                delta: psum,
            }
        } else {
            let hier = self.hier.as_ref().expect("hier mode");
            hier.reduce_cloud(cloud, &members)
        };
        let host = t0.elapsed().as_secs_f64();
        if gw == self.leader {
            // leader-colocated gateway: codec loopback only
            let delta = self.gw_up[cloud].codec_loopback(&partial.delta)?;
            Ok((PartialAggregate { delta, ..partial }, 0.0, 0, host))
        } else {
            let d = self.gw_up[cloud].send_update(
                &partial.delta,
                partial.mean_loss,
                partial.n_samples,
                partial.weight,
                &mut self.wan,
            )?;
            Ok((
                PartialAggregate {
                    cloud,
                    n_members: partial.n_members,
                    n_samples: d.n_samples,
                    weight: d.weight,
                    mean_loss: d.local_loss,
                    delta: d.update,
                },
                d.secs,
                d.wire_bytes,
                host,
            ))
        }
    }
}

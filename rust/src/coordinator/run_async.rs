//! Asynchronous aggregation event loop (paper formula 4).
//!
//! No barrier: each platform trains against its latest model copy and
//! ships its delta when done; the leader applies it immediately with the
//! staleness-discounted mixing rate and unicasts the fresh model back.
//! Simulated time advances through the shared [`EventEngine`], so fast
//! platforms lap slow ones — exactly the behaviour that makes async
//! aggregation shine under stragglers. Uplinks are priced over the
//! routed topology, so a worker deep inside a cloud pays its gateway hop
//! plus the WAN leg.

use std::time::Instant;

use anyhow::Result;

use crate::aggregation::ClientUpdate;
use crate::coordinator::build::Coordinator;
use crate::coordinator::engine::EventEngine;
use crate::metrics::{RoundRecord, RunResult};
use crate::model::ParamSet;
use crate::runtime::ComputeBackend;

impl<'a, B: ComputeBackend + ?Sized> Coordinator<'a, B> {
    /// Run the async loop for `cfg.rounds * n_workers` aggregations
    /// (so "round" granularity matches the sync schedulers: one round ==
    /// every platform contributing once on average).
    pub(crate) fn run_async(&mut self) -> Result<RunResult> {
        let n = self.workers.len();
        let total_aggs = self.cfg.rounds * n;
        let kind = self.cfg.aggregation.update_kind();

        // event payload: the worker whose local training completed
        let mut engine: EventEngine<usize>;
        // in-flight updates awaiting pickup, per worker:
        // (delta, mean loss, compute seconds spent producing it)
        let mut pending: Vec<Option<(ParamSet, f32, f64)>>;
        // per-worker compute seconds applied within the current
        // pseudo-round (the async analogue of the sync schedulers'
        // platform_secs — feeds the heterogeneity diagnostics)
        let mut round_compute = vec![0.0f64; n];
        let mut aggs: usize;

        if let Some(snap) = self.async_resume.take() {
            // WAL resume: rebuild the event queue and in-flight updates
            // exactly as the crashed run logged them at the boundary.
            // Replaying `queued` in pop order onto a fresh engine
            // reassigns seq numbers densely but preserves the relative
            // order, so every future pop matches the original run.
            engine = EventEngine::new(snap.now);
            for (at, worker) in snap.queued {
                engine.at(at, worker);
            }
            pending = snap.pending;
            aggs = self.rounds_done * n;
            if aggs < total_aggs {
                // faults due at the pseudo-round the crash interrupted
                // (the crash event itself was stripped on resume) — a
                // worker-join among them needs its kick replayed too
                self.apply_faults(self.rounds_done)?;
                self.async_kick_idle(&mut engine, &mut pending)?;
            }
        } else {
            engine = EventEngine::new(self.sim_secs);
            pending = (0..n).map(|_| None).collect();
            aggs = 0;

            // faults due at the very first pseudo-round strike before
            // any platform starts
            self.apply_faults(0)?;

            // kick off every active platform at t = now, all from the
            // same global
            self.async_kick_idle(&mut engine, &mut pending)?;
        }

        let mut train_loss_acc = 0.0f32;
        let mut reached = false;
        while aggs < total_aggs {
            let worker = engine.pop().expect("queue nonempty");
            let at = engine.now();

            if !self.cluster.is_active(worker) {
                // the node was preempted while its update was in flight:
                // the work is lost (`async_kick_idle` restarts it when it
                // rejoins)
                let _ = pending[worker].take();
                continue;
            }

            // --- uplink (the leader-colocated worker: codec loopback,
            // no WAN/encrypt hop — its delta is compressed like everyone
            // else's)
            let (update, mean_loss, compute_secs) =
                pending[worker].take().expect("pending update");
            round_compute[worker] += compute_secs;
            let (delivered, up_secs) = if worker == self.leader {
                (self.up[worker].codec_loopback(&update)?, 0.0)
            } else {
                let d = self.up[worker].send_update(
                    &update,
                    mean_loss,
                    self.workers[worker].n_samples,
                    1.0,
                    &mut self.wan,
                )?;
                self.wire_bytes += d.wire_bytes;
                (d.update, d.secs)
            };
            let arrive = at + up_secs;
            self.sim_secs = self.sim_secs.max(arrive);

            // --- apply with staleness discount (formula 4)
            let staleness =
                self.global_version - self.workers[worker].base_version;
            let cu = ClientUpdate {
                worker,
                n_samples: self.workers[worker].n_samples,
                local_loss: mean_loss,
                delta: delivered,
                staleness,
            };
            let t0 = Instant::now();
            self.aggregator.apply_one(&mut self.global, &cu);
            self.host_secs += t0.elapsed().as_secs_f64();
            self.accountant.record_round();
            self.global_version += 1;
            aggs += 1;
            train_loss_acc += mean_loss;

            // --- unicast fresh model back, then restart the worker
            let down_secs = if worker == self.leader {
                0.0
            } else {
                let (secs, wire) =
                    self.down[worker].send_params(&self.global, &mut self.wan)?;
                self.wire_bytes += wire;
                secs
            };
            let restart_at = arrive + down_secs;
            // the model downlink is real simulated time: the run is not
            // over until the refreshed model reached the worker
            self.sim_secs = self.sim_secs.max(restart_at);
            self.workers[worker].base_version = self.global_version;
            let global = self.global.clone();
            let r = self.workers[worker].local_round(
                self.backend,
                &global,
                kind,
                self.cfg.local_steps,
                self.cfg.local_lr,
                self.cfg.base_step_secs,
                &self.cfg.dp,
            )?;
            self.host_secs += r.host_secs;
            engine.at(restart_at + r.compute_secs, worker);
            pending[worker] = Some((r.update, r.mean_loss, r.compute_secs));

            // --- pseudo-round bookkeeping: every n aggregations
            if aggs % n == 0 {
                let round = aggs / n - 1;
                let do_eval = round % self.cfg.eval_every.max(1) == 0
                    || aggs == total_aggs;
                let (eval_loss, eval_acc) = if do_eval {
                    let (l, a) = self.evaluate()?;
                    (Some(l), Some(a))
                } else {
                    (None, None)
                };
                // compute seconds behind the updates applied this
                // pseudo-round, per worker
                let platform_secs =
                    std::mem::replace(&mut round_compute, vec![0.0; n]);
                let cost = self.cost_observe(&platform_secs);
                let record = RoundRecord {
                    round,
                    sim_secs: self.sim_secs,
                    wire_bytes: self.wire_bytes,
                    wire_bytes_class: self.wan_class_split(),
                    train_loss: train_loss_acc / n as f32,
                    eval_loss,
                    eval_acc,
                    platform_secs,
                    epsilon: self.accountant.epsilon(),
                    partition_gen: self.plan.generation,
                    active_members: self.cluster.n_active(),
                    cost,
                    cum_cost_usd: self.cost_ledger.cumulative().total_usd(),
                };
                let cum_cost = record.cum_cost_usd;
                train_loss_acc = 0.0;
                // log the pseudo-round boundary durably before acting
                // on it; at this point every worker has a pending update
                // and round_compute/train_loss_acc are freshly zeroed,
                // so the queue + pending capture the full live state
                self.wal_append_async(&record, &engine, &pending)?;
                self.commit_round(record)?;
                if let (Some(l), Some(t)) = (eval_loss, self.cfg.target_loss) {
                    if (l as f64) <= t {
                        reached = true;
                        break;
                    }
                }
                if let Some(budget) = self.cfg.target_cost {
                    if cum_cost >= budget {
                        log::info!(
                            "pseudo-round {round}: cost budget {budget} \
                             USD exhausted, stopping"
                        );
                        break;
                    }
                }
                if aggs < total_aggs {
                    // faults scheduled for the next pseudo-round; a
                    // rejoining worker starts training against the
                    // current global immediately
                    self.apply_faults(aggs / n)?;
                    self.async_kick_idle(&mut engine, &mut pending)?;
                }
            }
        }
        self.sim_events += engine.scheduled_total();
        self.finish(reached)
    }

    /// Start local training on every active worker that has neither a
    /// pending update nor a queued completion event (fresh-start kick-off
    /// and elastic rejoins share this). The `pending[w].is_some() ⇔ one
    /// queued event for w` invariant makes idleness observable from
    /// `pending` alone: a node that left with work in flight either had
    /// its event discarded (pending None → re-kick on rejoin) or rejoins
    /// before it fires (pending Some → the stale update applies with the
    /// usual staleness discount).
    fn async_kick_idle(
        &mut self,
        engine: &mut EventEngine<usize>,
        pending: &mut [Option<(ParamSet, f32, f64)>],
    ) -> Result<()> {
        let kind = self.cfg.aggregation.update_kind();
        let t_base = self.sim_secs;
        for w in 0..self.workers.len() {
            if !self.cluster.is_active(w) || pending[w].is_some() {
                continue;
            }
            self.workers[w].base_version = self.global_version;
            let global = self.global.clone();
            let r = self.workers[w].local_round(
                self.backend,
                &global,
                kind,
                self.cfg.local_steps,
                self.cfg.local_lr,
                self.cfg.base_step_secs,
                &self.cfg.dp,
            )?;
            self.host_secs += r.host_secs;
            engine.at(t_base + r.compute_secs, w);
            pending[w] = Some((r.update, r.mean_loss, r.compute_secs));
        }
        Ok(())
    }
}

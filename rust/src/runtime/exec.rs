//! Compiled-executable wrapper for the train/eval HLO modules.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::{Manifest, ParamSet};
use crate::runtime::count_execution;

/// One training batch: token ids and next-token targets, both
/// `(batch_size, seq_len)` row-major i32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Output of one train step.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub loss: f32,
    pub grads: ParamSet,
    /// host-side wall-clock of the PJRT execution (profiling)
    pub exec_secs: f64,
}

/// Output of one eval step.
#[derive(Clone, Debug)]
pub struct EvalOut {
    pub loss: f32,
    pub n_correct: u32,
    pub n_total: u32,
}

/// Compiled train+eval executables for one model preset.
///
/// Not `Sync`: the underlying PJRT client is used from one thread at a
/// time. The simulator runs workers sequentially in simulated time, so a
/// single `StepRuntime` per process (or per OS thread) is the intended
/// pattern.
pub struct StepRuntime {
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    manifest: Manifest,
}

impl StepRuntime {
    /// Load and compile the artifacts referenced by `manifest`.
    pub fn load(manifest: &Manifest) -> Result<StepRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let train_exe = Self::compile(&client, &manifest.train_hlo)?;
        let eval_exe = Self::compile(&client, &manifest.eval_hlo)?;
        Ok(StepRuntime { client, train_exe, eval_exe, manifest: manifest.clone() })
    }

    /// Convenience: load manifest + compile from an artifacts dir.
    pub fn load_preset(artifacts_dir: &Path, preset: &str) -> Result<StepRuntime> {
        let manifest = Manifest::load(artifacts_dir, preset)?;
        Self::load(&manifest)
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Tokens per batch (for accuracy denominators).
    pub fn tokens_per_batch(&self) -> u32 {
        (self.manifest.model.batch_size * self.manifest.model.seq_len) as u32
    }

    fn check_batch(&self, batch: &Batch) -> Result<()> {
        let want = self.manifest.model.batch_size * self.manifest.model.seq_len;
        if batch.tokens.len() != want || batch.targets.len() != want {
            bail!(
                "batch shape mismatch: got tokens={} targets={}, want {want}",
                batch.tokens.len(),
                batch.targets.len()
            );
        }
        Ok(())
    }

    /// Upload params+batch, run the executable, pull the tuple back.
    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<Vec<xla::Literal>> {
        self.check_batch(batch)?;
        if params.n_leaves() != self.manifest.params.len() {
            bail!(
                "param leaf count {} != manifest {}",
                params.n_leaves(),
                self.manifest.params.len()
            );
        }
        let b = self.manifest.model.batch_size;
        let s = self.manifest.model.seq_len;

        let mut inputs = Vec::with_capacity(params.n_leaves() + 2);
        for (leaf, spec) in params.leaves.iter().zip(&self.manifest.params) {
            inputs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(leaf, &spec.shape, None)
                    .with_context(|| format!("uploading {}", spec.name))?,
            );
        }
        inputs.push(
            self.client
                .buffer_from_host_buffer::<i32>(&batch.tokens, &[b, s], None)?,
        );
        inputs.push(
            self.client
                .buffer_from_host_buffer::<i32>(&batch.targets, &[b, s], None)?,
        );

        count_execution();
        let outs = exe.execute_b(&inputs).context("executing step")?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(tuple.to_tuple()?)
    }

    /// Run the fwd+bwd train step: returns loss and gradients.
    pub fn train_step(&self, params: &ParamSet, batch: &Batch) -> Result<TrainOut> {
        let t0 = Instant::now();
        let parts = self.run(&self.train_exe, params, batch)?;
        if parts.len() != 1 + self.manifest.params.len() {
            bail!(
                "train output arity {} != 1 + {} params",
                parts.len(),
                self.manifest.params.len()
            );
        }
        let loss = parts[0].get_first_element::<f32>()?;
        let mut grads = Vec::with_capacity(self.manifest.params.len());
        for (lit, spec) in parts[1..].iter().zip(&self.manifest.params) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != spec.numel() {
                bail!("grad leaf {} has {} elems, want {}", spec.name, v.len(), spec.numel());
            }
            grads.push(v);
        }
        Ok(TrainOut {
            loss,
            grads: ParamSet { leaves: grads },
            exec_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run the eval step: mean loss + top-1 next-token correct count.
    pub fn eval_step(&self, params: &ParamSet, batch: &Batch) -> Result<EvalOut> {
        let parts = self.run(&self.eval_exe, params, batch)?;
        if parts.len() != 2 {
            bail!("eval output arity {} != 2", parts.len());
        }
        Ok(EvalOut {
            loss: parts[0].get_first_element::<f32>()?,
            n_correct: parts[1].get_first_element::<i32>()? as u32,
            n_total: self.tokens_per_batch(),
        })
    }
}

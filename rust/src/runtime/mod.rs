//! PJRT runtime: loads the AOT HLO artifacts and executes train/eval steps.
//!
//! This is the only place rust touches XLA. Pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Python is never involved at runtime; the artifacts are produced once by
//! `make artifacts`.

#[cfg(feature = "pjrt")]
mod exec;
#[cfg(not(feature = "pjrt"))]
#[path = "exec_stub.rs"]
mod exec;
mod mock;

pub use exec::{Batch, EvalOut, StepRuntime, TrainOut};
pub use mock::MockRuntime;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::model::ParamSet;

/// Process-wide counter of PJRT executions (hot-path profiling aid).
pub static EXECUTIONS: AtomicU64 = AtomicU64::new(0);

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))] // called from exec.rs
pub(crate) fn count_execution() {
    EXECUTIONS.fetch_add(1, Ordering::Relaxed);
}

/// Total PJRT executions since process start.
pub fn execution_count() -> u64 {
    EXECUTIONS.load(Ordering::Relaxed)
}

/// What a worker needs from a compute backend. Implemented by the real
/// [`StepRuntime`] (PJRT) and by [`MockRuntime`] (a quadratic model) so the
/// coordinator/aggregation stack is testable without artifacts.
pub trait ComputeBackend {
    /// fwd+bwd on one batch: loss + grads.
    fn train(&self, params: &ParamSet, batch: &Batch) -> Result<TrainOut>;
    /// eval on one batch: loss + top-1 correct count.
    fn eval(&self, params: &ParamSet, batch: &Batch) -> Result<EvalOut>;
    /// Tokens per batch (accuracy denominator).
    fn tokens_per_batch(&self) -> u32;
    /// A `Sync` view of this backend, when it is safe to call from
    /// several threads at once. The coordinator parallelizes local
    /// training across workers only when this returns `Some`; the
    /// default `None` keeps backends with thread-affine state (PJRT
    /// clients) on the serial path without imposing a `Sync` bound on
    /// the whole trait.
    fn sync_view(&self) -> Option<&(dyn ComputeBackend + Sync)> {
        None
    }
}

impl ComputeBackend for StepRuntime {
    fn train(&self, params: &ParamSet, batch: &Batch) -> Result<TrainOut> {
        self.train_step(params, batch)
    }

    fn eval(&self, params: &ParamSet, batch: &Batch) -> Result<EvalOut> {
        self.eval_step(params, batch)
    }

    fn tokens_per_batch(&self) -> u32 {
        StepRuntime::tokens_per_batch(self)
    }
}

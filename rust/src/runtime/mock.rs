//! A runtime-free compute backend for tests and fast benches.
//!
//! Models each platform's local objective as a quadratic bowl whose optimum
//! is derived (deterministically) from the batch contents:
//!
//!   loss(p; batch) = 0.5 * mean_i (p_i - t_i)^2 + floor
//!   grad = (p - t) / n
//!
//! Different data shards → different targets `t` → genuine non-IID client
//! drift, which is exactly the failure mode the paper's aggregation
//! algorithms (formulas 1–4) are designed around. The coordinator,
//! schedulers and aggregators are tested against this backend without any
//! PJRT artifacts; the integration tests swap in the real [`StepRuntime`].

use anyhow::Result;

use crate::model::ParamSet;
use crate::runtime::{Batch, ComputeBackend, EvalOut, TrainOut};

/// Quadratic-bowl backend. `heterogeneity` scales how far shard targets
/// spread apart (0 = IID, all shards share one optimum).
#[derive(Clone, Debug)]
pub struct MockRuntime {
    pub heterogeneity: f32,
    pub tokens_per_batch: u32,
    /// irreducible loss floor, so eval losses look like LM losses
    pub floor: f32,
}

impl Default for MockRuntime {
    fn default() -> Self {
        MockRuntime { heterogeneity: 1.0, tokens_per_batch: 512, floor: 0.0 }
    }
}

impl MockRuntime {
    pub fn new(heterogeneity: f32) -> Self {
        MockRuntime { heterogeneity, ..Default::default() }
    }

    /// Deterministic per-batch target offset in [-h, h].
    fn target_offset(&self, batch: &Batch) -> f32 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in batch.tokens.iter().take(64) {
            h ^= t as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        let unit = (h >> 11) as f32 / (1u64 << 53) as f32; // [0, 1)
        (unit * 2.0 - 1.0) * self.heterogeneity
    }

    fn loss_and_grad(&self, params: &ParamSet, batch: &Batch) -> (f32, ParamSet) {
        let t = self.target_offset(batch);
        let n = params.numel() as f32;
        let mut grads = Vec::with_capacity(params.leaves.len());
        let mut loss = 0.0f64;
        for leaf in &params.leaves {
            let mut g = Vec::with_capacity(leaf.len());
            for &p in leaf {
                let d = p - t;
                loss += 0.5 * (d as f64) * (d as f64);
                g.push(d / n);
            }
            grads.push(g);
        }
        ((loss / n as f64) as f32 + self.floor, ParamSet { leaves: grads })
    }
}

impl ComputeBackend for MockRuntime {
    fn train(&self, params: &ParamSet, batch: &Batch) -> Result<TrainOut> {
        let (loss, grads) = self.loss_and_grad(params, batch);
        Ok(TrainOut { loss, grads, exec_secs: 0.0 })
    }

    fn eval(&self, params: &ParamSet, batch: &Batch) -> Result<EvalOut> {
        let (loss, _) = self.loss_and_grad(params, batch);
        // map loss to a plausible token accuracy: acc = exp(-loss)
        let acc = (-loss as f64).exp().clamp(0.0, 1.0);
        Ok(EvalOut {
            loss,
            n_correct: (acc * self.tokens_per_batch as f64).round() as u32,
            n_total: self.tokens_per_batch,
        })
    }

    fn tokens_per_batch(&self) -> u32 {
        self.tokens_per_batch
    }

    fn sync_view(&self) -> Option<&(dyn ComputeBackend + Sync)> {
        // plain data, no interior mutability: safe to share across the
        // per-worker training threads
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, v: f32) -> ParamSet {
        ParamSet { leaves: vec![vec![v; n]] }
    }

    fn batch(seed: i32) -> Batch {
        Batch { tokens: vec![seed; 8], targets: vec![seed; 8] }
    }

    #[test]
    fn gradient_descends() {
        let rt = MockRuntime::new(0.5);
        let mut p = params(16, 2.0);
        let b = batch(7);
        let l0 = rt.train(&p, &b).unwrap().loss;
        for _ in 0..200 {
            let out = rt.train(&p, &b).unwrap();
            p.axpy(-10.0, &out.grads);
        }
        let l1 = rt.train(&p, &b).unwrap().loss;
        assert!(l1 < l0 * 0.01, "l0={l0} l1={l1}");
    }

    #[test]
    fn different_shards_different_optima() {
        let rt = MockRuntime::new(1.0);
        let t1 = rt.target_offset(&batch(1));
        let t2 = rt.target_offset(&batch(2));
        assert!((t1 - t2).abs() > 1e-4);
        // IID case collapses
        let rt0 = MockRuntime::new(0.0);
        assert_eq!(rt0.target_offset(&batch(1)), 0.0);
    }

    #[test]
    fn eval_accuracy_tracks_loss() {
        let rt = MockRuntime::new(0.5);
        let b = batch(3);
        let near = rt.eval(&params(8, rt.target_offset(&b)), &b).unwrap();
        let far = rt.eval(&params(8, 5.0), &b).unwrap();
        assert!(near.n_correct > far.n_correct);
        assert_eq!(near.n_total, 512);
    }
}

//! API-compatible stand-in for the PJRT runtime when the `pjrt` feature
//! (and with it the vendored `xla` crate) is disabled.
//!
//! Constructors fail with a clear message; everything that would execute
//! artifacts is unreachable. The quadratic [`crate::runtime::MockRuntime`]
//! covers tests and benches, and the artifact-gated integration tests
//! skip themselves when `artifacts/` is absent — which it always is
//! without the real runtime. Types mirror `exec.rs` exactly so the rest
//! of the crate compiles identically under both configurations.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::{Manifest, ParamSet};

/// One training batch: token ids and next-token targets, both
/// `(batch_size, seq_len)` row-major i32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Output of one train step.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub loss: f32,
    pub grads: ParamSet,
    /// host-side wall-clock of the PJRT execution (profiling)
    pub exec_secs: f64,
}

/// Output of one eval step.
#[derive(Clone, Debug)]
pub struct EvalOut {
    pub loss: f32,
    pub n_correct: u32,
    pub n_total: u32,
}

const NO_PJRT: &str = "crossfed was built without the `pjrt` feature; \
rebuild with `--features pjrt` (vendored xla crate) to execute artifacts";

/// Stub runtime: never constructible, so the execution methods are
/// unreachable by design.
pub struct StepRuntime {
    manifest: Manifest,
}

impl StepRuntime {
    pub fn load(_manifest: &Manifest) -> Result<StepRuntime> {
        bail!(NO_PJRT)
    }

    pub fn load_preset(_artifacts_dir: &Path, _preset: &str) -> Result<StepRuntime> {
        bail!(NO_PJRT)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn tokens_per_batch(&self) -> u32 {
        (self.manifest.model.batch_size * self.manifest.model.seq_len) as u32
    }

    pub fn train_step(&self, _params: &ParamSet, _batch: &Batch) -> Result<TrainOut> {
        bail!(NO_PJRT)
    }

    pub fn eval_step(&self, _params: &ParamSet, _batch: &Batch) -> Result<EvalOut> {
        bail!(NO_PJRT)
    }
}

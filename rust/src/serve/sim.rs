//! Event-driven cross-cloud serving simulator.
//!
//! Runs on the same arena [`EventEngine`] the training coordinator uses,
//! against the routed CSR [`Wan`]: requests arrive at each cloud's front
//! door on its diurnal stream, the [`Router`] picks a replica, the
//! replica batches FIFO, and completed batches record latency and
//! staleness. Checkpoint publishes push a fresh model version from the
//! source cloud to every replica over cold WAN connections.
//!
//! Millions of requests make per-request [`Wan::transfer`] calls (and
//! their jitter RNG draws) prohibitive, so every (cloud, cloud) path is
//! profiled ONCE up front — routed hop by hop with one dedicated RNG —
//! and each request replays the frozen profile: fixed seconds plus fixed
//! per-(cloud, class) wire-byte charges. The simulation is therefore a
//! pure function of the config seed: single event stream, fixed-order
//! float accumulation, bit-identical across repeats and thread counts.

use anyhow::{ensure, Context, Result};

use crate::cluster::ClusterSpec;
use crate::coordinator::engine::EventEngine;
use crate::cost::CostLedger;
use crate::netsim::{LinkClass, Wan};
use crate::serve::replica::{QueuedRequest, Replica};
use crate::serve::router::Router;
use crate::serve::traffic::ArrivalStream;
use crate::serve::{ServeConfig, ServeResult};
use crate::util::rng::Pcg64;

/// Same-cloud front-door → replica round trip (no WAN hop to price).
const LOCAL_NET_SECS: f64 = 0.004;

/// Dedicated RNG stream tag for path profiling ("SRVP").
const PROFILE_STREAM: u64 = 0x5352_5650;

/// What the simulation schedules.
enum Ev {
    /// a request hits cloud `cloud`'s front door
    Arrive { cloud: u32 },
    /// replica `replica`'s in-flight batch completes
    BatchDone { replica: u32 },
    /// training publishes checkpoint `version` at the source cloud
    Publish { version: u64 },
    /// the `version` weights finish transferring to replica `replica`
    Refreshed { replica: u32, version: u64 },
    /// hourly ledger observation window
    Tick,
}

/// One frozen network path: end-to-end seconds and the per-hop
/// (source cloud, link-class index, wire bytes) egress charges.
#[derive(Clone, Debug, Default)]
struct PathProfile {
    secs: f64,
    charges: Vec<(usize, usize, u64)>,
}

/// Profile gateway `src_gw` → `dst_gw` for a `payload`-byte transfer.
#[allow(clippy::too_many_arguments)]
fn profile_path(
    wan: &Wan,
    cluster: &ClusterSpec,
    src_gw: usize,
    dst_gw: usize,
    payload: u64,
    cfg: &ServeConfig,
    warm: bool,
    rng: &mut Pcg64,
) -> Result<PathProfile> {
    let mut p = PathProfile::default();
    for (a, b) in wan.route(src_gw, dst_gw)? {
        let link = wan.link(a, b).context("routed hop must have a link")?;
        let class = wan.link_class(a, b).context("routed hop must have a class")?;
        let st = link.transfer(payload, cfg.protocol, warm, cfg.streams, rng);
        p.secs += st.time_s;
        p.charges.push((cluster.cloud_of(a), class.index(), st.wire_bytes));
    }
    Ok(p)
}

/// Replay a frozen profile's egress charges into the byte ledgers.
fn charge(p: &PathProfile, bytes: &mut [[u64; 3]], wire: &mut u64) {
    for &(c, k, b) in &p.charges {
        bytes[c][k] += b;
        *wire += b;
    }
}

/// Run the serving simulation to completion (arrivals stop at
/// `duration_secs`; the engine then drains in-flight batches).
pub fn run(cfg: &ServeConfig, cluster: &ClusterSpec) -> Result<ServeResult> {
    cfg.validate()?;
    let n_clouds = cluster.n_clouds();
    ensure!(n_clouds >= 1, "serving needs at least one cloud");
    ensure!(cfg.source_cloud < n_clouds, "source cloud {} out of {n_clouds}", cfg.source_cloud);

    let wan = Wan::from_cluster(cluster, cfg.seed);
    let mut prof_rng = Pcg64::new(cfg.seed, PROFILE_STREAM);

    // ---- freeze every (cloud, cloud) path once ---------------------------
    let local = PathProfile { secs: LOCAL_NET_SECS, charges: Vec::new() };
    let mut req_path = vec![vec![PathProfile::default(); n_clouds]; n_clouds];
    let mut resp_path = vec![vec![PathProfile::default(); n_clouds]; n_clouds];
    for s in 0..n_clouds {
        for d in 0..n_clouds {
            if s == d {
                // request + response share the fixed local round trip
                req_path[s][d] = local.clone();
                resp_path[s][d] = PathProfile { secs: 0.0, charges: Vec::new() };
                continue;
            }
            let (gs, gd) = (cluster.gateway(s), cluster.gateway(d));
            req_path[s][d] = profile_path(
                &wan,
                cluster,
                gs,
                gd,
                cfg.req_bytes,
                cfg,
                true,
                &mut prof_rng,
            )?;
            resp_path[s][d] = profile_path(
                &wan,
                cluster,
                gs,
                gd,
                cfg.resp_bytes,
                cfg,
                true,
                &mut prof_rng,
            )?;
        }
    }
    // checkpoint pushes: cold connections, model-sized payloads
    let mut refresh_path = Vec::with_capacity(n_clouds);
    for r in 0..n_clouds {
        if r == cfg.source_cloud {
            // staging copy inside the source cloud (25 Gbps local fabric)
            refresh_path.push(PathProfile {
                secs: cfg.model_bytes as f64 * 8.0 / 25e9,
                charges: Vec::new(),
            });
        } else {
            refresh_path.push(profile_path(
                &wan,
                cluster,
                cluster.gateway(cfg.source_cloud),
                cluster.gateway(r),
                cfg.model_bytes,
                cfg,
                false,
                &mut prof_rng,
            )?);
        }
    }

    // ---- replicas: one per cloud, hosted at the gateway ------------------
    let mut replicas: Vec<Replica> = (0..n_clouds)
        .map(|c| {
            let node = cluster.gateway(c);
            let speed = cluster.platforms[node].compute_speed;
            let mut r = Replica::new(c, node, speed, cfg.max_batch);
            r.version = cfg.initial_version;
            r
        })
        .collect();

    // ---- router scoring tables from the frozen profiles ------------------
    let book = &cfg.price_book;
    let charge_usd = |p: &PathProfile| -> f64 {
        let mut usd = 0.0;
        for &(c, k, b) in &p.charges {
            usd += b as f64 / 1e9 * book.egress_rate(c, LinkClass::ALL[k]).marginal_rate(0.0);
        }
        usd
    };
    let mut net_secs = vec![vec![0.0; n_clouds]; n_clouds];
    let mut egress_usd = vec![vec![0.0; n_clouds]; n_clouds];
    for s in 0..n_clouds {
        for r in 0..n_clouds {
            net_secs[s][r] = req_path[s][r].secs + resp_path[r][s].secs;
            egress_usd[s][r] = charge_usd(&req_path[s][r]) + charge_usd(&resp_path[r][s]);
        }
    }
    let mut compute_usd = vec![0.0; n_clouds];
    for (usd, r) in compute_usd.iter_mut().zip(replicas.iter()) {
        *usd = cfg.service.marginal_secs(r.speed) / 3600.0 * book.compute_rate(r.cloud);
    }
    let router = Router {
        policy: cfg.route,
        net_secs,
        egress_usd,
        compute_usd,
        lat_ref_secs: cfg.lat_ref_secs,
        usd_ref: cfg.usd_ref,
    };

    // ---- event loop ------------------------------------------------------
    let mut engine: EventEngine<Ev> = EventEngine::new(0.0);
    let mut streams: Vec<ArrivalStream> = (0..n_clouds)
        .map(|c| ArrivalStream::new(&cfg.traffic, c, n_clouds, cfg.seed))
        .collect();
    for (c, s) in streams.iter_mut().enumerate() {
        let t = s.next(0.0);
        if t <= cfg.duration_secs {
            engine.at(t, Ev::Arrive { cloud: c as u32 });
        }
    }
    if cfg.refresh_period_secs > 0.0 && cfg.refresh_period_secs <= cfg.duration_secs {
        engine.at(cfg.refresh_period_secs, Ev::Publish { version: cfg.initial_version + 1 });
    }
    if cfg.tick_secs <= cfg.duration_secs {
        engine.at(cfg.tick_secs, Ev::Tick);
    }

    let mut ledger = CostLedger::new(book.clone(), n_clouds);
    let mut bytes_by_cloud_class = vec![[0u64; 3]; n_clouds];
    let mut wire_bytes: u64 = 0;
    // version -> publish time (index: version - initial_version)
    let mut published_at: Vec<f64> = vec![0.0];
    let mut latencies: Vec<f64> = Vec::new();
    let mut requests: u64 = 0;
    let mut requests_by_replica = vec![0u64; n_clouds];
    let mut staleness_sum = 0.0;
    let mut refreshes: u64 = 0;

    while let Some(ev) = engine.pop() {
        let now = engine.now();
        match ev {
            Ev::Arrive { cloud } => {
                let c = cloud as usize;
                requests += 1;
                let r = router.pick(c, &replicas, &cfg.service);
                requests_by_replica[r] += 1;
                charge(&req_path[c][r], &mut bytes_by_cloud_class, &mut wire_bytes);
                replicas[r].enqueue(QueuedRequest { src_cloud: c, arrived: now });
                if replicas[r].idle() {
                    let secs = replicas[r].start_batch(&cfg.service);
                    engine.after(secs, Ev::BatchDone { replica: r as u32 });
                }
                let t = streams[c].next(now);
                if t <= cfg.duration_secs {
                    engine.at(t, Ev::Arrive { cloud });
                }
            }
            Ev::BatchDone { replica } => {
                let r = replica as usize;
                let version_age = now - replicas[r].version_time;
                let done = replicas[r].finish_batch();
                for q in &done {
                    // total latency = uplink + queue/service + downlink
                    let lat = req_path[q.src_cloud][r].secs
                        + (now - q.arrived)
                        + resp_path[r][q.src_cloud].secs;
                    latencies.push(lat);
                    charge(&resp_path[r][q.src_cloud], &mut bytes_by_cloud_class, &mut wire_bytes);
                    staleness_sum += version_age;
                    replicas[r].staleness_sum += version_age;
                }
                if !replicas[r].queue.is_empty() {
                    let secs = replicas[r].start_batch(&cfg.service);
                    engine.after(secs, Ev::BatchDone { replica });
                }
            }
            Ev::Publish { version } => {
                published_at.push(now);
                for r in 0..n_clouds {
                    charge(&refresh_path[r], &mut bytes_by_cloud_class, &mut wire_bytes);
                    let secs = refresh_path[r].secs;
                    engine.after(secs, Ev::Refreshed { replica: r as u32, version });
                }
                let next = now + cfg.refresh_period_secs;
                if next <= cfg.duration_secs {
                    engine.at(next, Ev::Publish { version: version + 1 });
                }
            }
            Ev::Refreshed { replica, version } => {
                let r = replica as usize;
                if version > replicas[r].version {
                    let idx = (version - cfg.initial_version) as usize;
                    replicas[r].version = version;
                    replicas[r].version_time = published_at[idx];
                    refreshes += 1;
                }
            }
            Ev::Tick => {
                let mut platform_secs = vec![0.0; cluster.n()];
                for rep in replicas.iter_mut() {
                    platform_secs[rep.node] += rep.window_busy_secs;
                    rep.window_busy_secs = 0.0;
                }
                ledger.observe(&bytes_by_cloud_class, &platform_secs, cluster);
                let next = now + cfg.tick_secs;
                if next <= cfg.duration_secs {
                    engine.at(next, Ev::Tick);
                }
            }
        }
    }

    // tail window: bytes and busy-seconds since the last tick
    let mut platform_secs = vec![0.0; cluster.n()];
    for rep in replicas.iter_mut() {
        platform_secs[rep.node] += rep.window_busy_secs;
        rep.window_busy_secs = 0.0;
    }
    ledger.observe(&bytes_by_cloud_class, &platform_secs, cluster);

    // ---- aggregate -------------------------------------------------------
    latencies.sort_by(f64::total_cmp);
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() as f64 - 1.0) * p).round() as usize;
        latencies[idx]
    };
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let max_lat = latencies.last().copied().unwrap_or(0.0);
    let depth_sum: u64 = replicas.iter().map(|r| r.depth_sum).sum();
    let max_queue_depth = replicas.iter().map(|r| r.max_depth).max().unwrap_or(0);
    let served: u64 = replicas.iter().map(|r| r.served).sum();

    let mut wire_class = [0u64; 3];
    for per_cloud in &bytes_by_cloud_class {
        for (w, b) in wire_class.iter_mut().zip(per_cloud.iter()) {
            *w += *b;
        }
    }

    Ok(ServeResult {
        name: cfg.name.clone(),
        policy: cfg.route.name(),
        requests,
        sim_secs: engine.now(),
        events: engine.scheduled_total(),
        p50_ms: pct(0.50) * 1e3,
        p99_ms: pct(0.99) * 1e3,
        mean_ms: mean * 1e3,
        max_ms: max_lat * 1e3,
        mean_queue_depth: if served == 0 {
            0.0
        } else {
            depth_sum as f64 / served as f64
        },
        max_queue_depth,
        requests_by_replica,
        staleness_mean_secs: if served == 0 {
            0.0
        } else {
            staleness_sum / served as f64
        },
        refreshes,
        wire_bytes,
        wire_bytes_class: wire_class,
        cost: ledger.cumulative().clone(),
    })
}

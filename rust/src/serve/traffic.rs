//! Seeded request-population generator: millions of users spread across
//! the clouds, each cloud's front door following a per-region diurnal
//! sinusoid, arrivals drawn as a non-homogeneous Poisson process by
//! thinning — deterministic per (seed, cloud) stream.

use crate::util::rng::Pcg64;

/// Seconds in one simulated day (the diurnal period).
pub const SECS_PER_DAY: f64 = 86_400.0;

/// Dedicated RNG stream tag for arrival sampling ("SRVA").
const ARRIVAL_STREAM: u64 = 0x5352_5641;

/// The request population hitting the serving fleet.
///
/// Each cloud is a regional front door; its users generate requests at
///
/// ```text
/// rate_c(t) = base_c · (1 + amplitude · sin(2π t / day + phase_c))
/// ```
///
/// where `base_c = users · share_c · reqs_per_user_day / 86 400` and
/// `phase_c = 2π c / n_clouds` staggers the peaks around the globe. Over
/// a whole day the sinusoid integrates to zero, so the arrival mass is
/// exactly `users · reqs_per_user_day` in expectation regardless of the
/// amplitude (pinned by the unit tests below).
#[derive(Clone, Debug)]
pub struct TrafficSpec {
    /// total user population across every cloud
    pub users: u64,
    /// mean requests per user per day
    pub reqs_per_user_day: f64,
    /// diurnal swing in [0, 1): peak/trough = (1+a)/(1-a)
    pub amplitude: f64,
    /// population skew: cloud `c` weighs `1/(1 + skew·c)` before
    /// normalization (0 = uniform; the default front door, cloud 0, is
    /// the biggest market)
    pub skew: f64,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            users: 1_000_000,
            reqs_per_user_day: 2.0,
            amplitude: 0.6,
            skew: 0.35,
        }
    }
}

impl TrafficSpec {
    /// Cloud `c`'s share of the user population (sums to 1 over clouds).
    pub fn pop_share(&self, cloud: usize, n_clouds: usize) -> f64 {
        assert!(cloud < n_clouds, "cloud {cloud} out of {n_clouds}");
        let w = |c: usize| 1.0 / (1.0 + self.skew * c as f64);
        w(cloud) / (0..n_clouds).map(w).sum::<f64>()
    }

    /// Cloud `c`'s mean arrival rate (requests/sec, diurnal-averaged).
    pub fn base_rps(&self, cloud: usize, n_clouds: usize) -> f64 {
        let day_reqs = self.users as f64 * self.reqs_per_user_day;
        day_reqs * self.pop_share(cloud, n_clouds) / SECS_PER_DAY
    }

    /// Instantaneous arrival rate of cloud `c` at simulated time `t`.
    pub fn rate(&self, cloud: usize, n_clouds: usize, t_secs: f64) -> f64 {
        let phase = std::f64::consts::TAU * cloud as f64 / n_clouds as f64;
        let swing = (std::f64::consts::TAU * t_secs / SECS_PER_DAY + phase).sin();
        self.base_rps(cloud, n_clouds) * (1.0 + self.amplitude * swing)
    }

    /// Cloud `c`'s peak arrival rate (the thinning envelope).
    pub fn peak_rps(&self, cloud: usize, n_clouds: usize) -> f64 {
        self.base_rps(cloud, n_clouds) * (1.0 + self.amplitude)
    }

    /// Expected total requests over `duration_secs` across all clouds
    /// (exact for whole days; the sinusoid's partial-day residual is
    /// bounded by `amplitude · base · day / 2π` per cloud).
    pub fn expected_requests(&self, duration_secs: f64) -> f64 {
        self.users as f64 * self.reqs_per_user_day * duration_secs / SECS_PER_DAY
    }
}

/// One cloud's deterministic arrival stream: a non-homogeneous Poisson
/// process realized by thinning against the peak-rate envelope. Each
/// stream owns a dedicated [`Pcg64`] stream keyed by (seed, cloud), so
/// the sequence is a pure function of the experiment seed — independent
/// of host thread count and of every other cloud's stream.
#[derive(Clone, Debug)]
pub struct ArrivalStream {
    cloud: usize,
    n_clouds: usize,
    spec: TrafficSpec,
    peak: f64,
    rng: Pcg64,
}

impl ArrivalStream {
    pub fn new(spec: &TrafficSpec, cloud: usize, n_clouds: usize, seed: u64) -> ArrivalStream {
        let peak = spec.peak_rps(cloud, n_clouds);
        assert!(peak > 0.0, "cloud {cloud} has zero traffic");
        ArrivalStream {
            cloud,
            n_clouds,
            spec: spec.clone(),
            peak,
            rng: Pcg64::new(seed, ARRIVAL_STREAM ^ cloud as u64),
        }
    }

    /// The next arrival strictly after `now` (thinning: candidate gaps
    /// are Exp(peak); a candidate at `t` survives with probability
    /// `rate(t)/peak`).
    pub fn next(&mut self, now: f64) -> f64 {
        let mut t = now;
        loop {
            t += self.rng.exponential(self.peak);
            let accept = self.rng.uniform() * self.peak;
            if accept <= self.spec.rate(self.cloud, self.n_clouds, t) {
                return t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TrafficSpec {
        TrafficSpec {
            users: 500_000,
            reqs_per_user_day: 1.5,
            amplitude: 0.6,
            skew: 0.35,
        }
    }

    #[test]
    fn population_shares_sum_to_one_and_skew_orders_them() {
        let s = spec();
        let n = 6;
        let shares: Vec<f64> = (0..n).map(|c| s.pop_share(c, n)).collect();
        let total: f64 = shares.iter().sum();
        assert!((total - 1.0).abs() < 1e-12, "{total}");
        for c in 1..n {
            assert!(shares[c] < shares[c - 1], "skew must order shares");
        }
        let uniform = TrafficSpec { skew: 0.0, ..s };
        for c in 0..n {
            assert!((uniform.pop_share(c, n) - 1.0 / n as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn arrival_mass_is_conserved_over_a_day() {
        // ∫ rate dt over one full day == base · day for every cloud: the
        // sinusoid redistributes load across hours, it never adds any
        let s = spec();
        let n = 4;
        for cloud in 0..n {
            let dt = 10.0;
            let steps = (SECS_PER_DAY / dt) as usize;
            let mass: f64 = (0..steps)
                .map(|i| s.rate(cloud, n, (i as f64 + 0.5) * dt) * dt)
                .sum();
            let expect = s.base_rps(cloud, n) * SECS_PER_DAY;
            assert!((mass - expect).abs() / expect < 1e-3, "cloud {cloud}: {mass} vs {expect}");
        }
        // and the all-cloud total is the advertised population mass
        let total: f64 = (0..n).map(|c| s.base_rps(c, n) * SECS_PER_DAY).sum();
        assert!((total - s.expected_requests(SECS_PER_DAY)).abs() < 1e-6 * total);
    }

    #[test]
    fn peak_to_trough_ratio_matches_the_amplitude() {
        let s = spec();
        let n = 3;
        let rates: Vec<f64> = (0..8640).map(|i| s.rate(1, n, i as f64 * 10.0)).collect();
        let peak = rates.iter().cloned().fold(f64::MIN, f64::max);
        let trough = rates.iter().cloned().fold(f64::MAX, f64::min);
        let want = (1.0 + s.amplitude) / (1.0 - s.amplitude);
        assert!((peak / trough - want).abs() < 0.01, "{} vs {want}", peak / trough);
        assert!(peak <= s.peak_rps(1, n) + 1e-9, "envelope must dominate");
    }

    #[test]
    fn arrivals_are_seed_stable_and_strictly_increasing() {
        let s = spec();
        let mut a = ArrivalStream::new(&s, 2, 4, 42);
        let mut b = ArrivalStream::new(&s, 2, 4, 42);
        let mut c = ArrivalStream::new(&s, 2, 4, 43);
        let mut t_a = 0.0;
        let mut t_b = 0.0;
        let mut t_c = 0.0;
        let mut diverged = false;
        for _ in 0..200 {
            let prev = t_a;
            t_a = a.next(t_a);
            t_b = b.next(t_b);
            t_c = c.next(t_c);
            assert_eq!(t_a.to_bits(), t_b.to_bits(), "same seed, same stream");
            assert!(t_a > prev, "arrivals must move forward");
            diverged |= t_a.to_bits() != t_c.to_bits();
        }
        assert!(diverged, "different seeds must give different streams");
    }

    #[test]
    fn empirical_arrival_count_matches_the_mass() {
        // one simulated day on one cloud: the realized Poisson count
        // lands within 5 standard deviations of ∫ rate dt
        let s = TrafficSpec {
            users: 100_000,
            reqs_per_user_day: 1.0,
            amplitude: 0.8,
            skew: 0.0,
        };
        let n = 2;
        let mut stream = ArrivalStream::new(&s, 0, n, 7);
        let mut t = 0.0;
        let mut count = 0u64;
        loop {
            t = stream.next(t);
            if t > SECS_PER_DAY {
                break;
            }
            count += 1;
        }
        let expect = s.base_rps(0, n) * SECS_PER_DAY;
        let sd = expect.sqrt();
        assert!((count as f64 - expect).abs() < 5.0 * sd, "{count} vs {expect} (sd {sd:.0})");
    }
}

//! Cross-cloud inference serving: millions of users against the trained
//! model (ROADMAP item 4).
//!
//! Training pools clouds to *build* the model; this module pools the
//! same clouds to *serve* it. A seeded population ([`TrafficSpec`])
//! generates diurnal request streams at each cloud's front door, one
//! model [`Replica`] per cloud batches them FIFO with service times
//! derived from the parameter count, and a pluggable [`Router`] decides
//! — per request — whether to stay local (latency) or ship the request
//! to the cheapest cloud (egress + compute dollars), mirroring the
//! training-side [`crate::cost::placement`] scoring. Checkpoint
//! publishes close the train→deploy loop: fresh weights fan out from
//! the training leader's cloud over cold WAN connections and replicas
//! report how stale the version they served was.
//!
//! Everything runs on the coordinator's arena event engine and the
//! routed CSR [`crate::netsim::Wan`]; dollars flow through the same
//! [`crate::cost::CostLedger`] as training. Results are bit-identical
//! across repeats and thread counts.

pub mod replica;
pub mod router;
pub mod sim;
pub mod traffic;

pub use replica::{QueuedRequest, Replica, ServiceModel};
pub use router::{RoutePolicy, Router};
pub use sim::run;
pub use traffic::{ArrivalStream, TrafficSpec, SECS_PER_DAY};

use anyhow::{ensure, Result};

use crate::checkpoint::Checkpoint;
use crate::compress::LosslessStage;
use crate::config::ExperimentConfig;
use crate::cost::{CostBreakdown, PriceBook};
use crate::netsim::Protocol;
use crate::util::json::Json;

/// Everything one serving run needs. Defaults describe a day of
/// paper-scale traffic against a 1.3B-parameter model.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub name: String,
    pub seed: u64,
    pub traffic: TrafficSpec,
    /// simulated wall-clock to generate arrivals for, seconds
    pub duration_secs: f64,
    pub route: RoutePolicy,
    /// request payload (prompt) bytes
    pub req_bytes: u64,
    /// response payload (completion) bytes
    pub resp_bytes: u64,
    pub service: ServiceModel,
    /// replica batch capacity
    pub max_batch: usize,
    /// training publishes a fresh checkpoint this often (0 = never)
    pub refresh_period_secs: f64,
    /// serialized model bytes pushed per refresh
    pub model_bytes: u64,
    /// lossless wire stage the publisher applies to refresh payloads
    /// (the training run's `cfg.lossless`; sizes flow through
    /// [`crate::transport::dense_payload_bytes`])
    pub lossless: LosslessStage,
    /// cloud the training leader publishes from
    pub source_cloud: usize,
    pub protocol: Protocol,
    pub streams: usize,
    pub price_book: PriceBook,
    /// ledger observation window (compute + egress billing cadence)
    pub tick_secs: f64,
    /// latency normalizer for blended routing, seconds
    pub lat_ref_secs: f64,
    /// dollar normalizer for blended routing, $ per request
    pub usd_ref: f64,
    /// version replicas start on (a restored checkpoint's
    /// `global_version`)
    pub initial_version: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            name: "serve".into(),
            seed: 42,
            traffic: TrafficSpec::default(),
            duration_secs: SECS_PER_DAY,
            route: RoutePolicy::Latency,
            req_bytes: 2_048,
            resp_bytes: 8_192,
            service: ServiceModel::default(),
            max_batch: 16,
            refresh_period_secs: 4.0 * 3600.0,
            model_bytes: 5_200_000_000,
            lossless: LosslessStage::None,
            source_cloud: 0,
            protocol: Protocol::Grpc,
            streams: 16,
            price_book: PriceBook::paper_default(),
            tick_secs: 3600.0,
            lat_ref_secs: 0.25,
            usd_ref: 3e-5,
            initial_version: 0,
        }
    }
}

impl ServeConfig {
    /// Borrow the training experiment's identity: seed, transport,
    /// price book and name, so a serve run prices and transfers exactly
    /// like the training run it deploys.
    pub fn from_experiment(exp: &ExperimentConfig) -> ServeConfig {
        ServeConfig {
            name: format!("{}-serve", exp.name),
            seed: exp.seed,
            protocol: exp.protocol,
            streams: exp.streams,
            price_book: exp.price_book.clone(),
            lossless: exp.lossless,
            ..ServeConfig::default()
        }
    }

    /// Serve the model a training checkpoint actually holds: parameter
    /// count (service times), serialized size (refresh payloads) and
    /// version lineage all come from the checkpoint.
    pub fn with_checkpoint(mut self, ckpt: &Checkpoint) -> ServeConfig {
        self.service.n_params = ckpt.params.numel() as u64;
        // the same payload-size accessor the training broadcast uses,
        // so a lossless stage reprices the refresh push identically
        self.model_bytes =
            crate::transport::dense_payload_bytes(&ckpt.params, self.lossless);
        self.initial_version = ckpt.global_version;
        self.name = format!("{}@r{}", self.name, ckpt.round);
        self
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.duration_secs > 0.0, "duration must be positive");
        ensure!(self.tick_secs > 0.0, "tick must be positive");
        ensure!(self.traffic.users >= 1, "need at least one user");
        ensure!(
            self.traffic.reqs_per_user_day > 0.0,
            "requests per user per day must be positive"
        );
        ensure!(
            (0.0..1.0).contains(&self.traffic.amplitude),
            "amplitude must be in [0, 1)"
        );
        ensure!(self.traffic.skew >= 0.0, "skew must be non-negative");
        ensure!(self.req_bytes >= 1, "request payload must be non-empty");
        ensure!(self.resp_bytes >= 1, "response payload must be non-empty");
        ensure!(self.max_batch >= 1, "batch capacity must be positive");
        ensure!(self.service.n_params >= 1, "model needs parameters");
        ensure!(
            self.service.flops_per_sec > 0.0,
            "replica FLOP/s must be positive"
        );
        ensure!(
            self.service.batch_marginal > 0.0
                && self.service.batch_marginal <= 1.0,
            "batch marginal must be in (0, 1]"
        );
        ensure!(
            self.refresh_period_secs >= 0.0,
            "refresh period must be non-negative"
        );
        ensure!(self.lat_ref_secs > 0.0, "latency normalizer must be positive");
        ensure!(self.usd_ref > 0.0, "dollar normalizer must be positive");
        self.price_book.validate()?;
        Ok(())
    }
}

/// What one serving run measured.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub name: String,
    /// the routing policy's canonical name
    pub policy: String,
    /// requests generated (== requests served; the engine drains)
    pub requests: u64,
    /// simulated seconds until the engine drained
    pub sim_secs: f64,
    /// events the engine scheduled (throughput denominator)
    pub events: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// mean queue depth sampled at every enqueue
    pub mean_queue_depth: f64,
    /// deepest queue any replica saw
    pub max_queue_depth: usize,
    /// requests routed to each replica (index = cloud)
    pub requests_by_replica: Vec<u64>,
    /// mean checkpoint age at serve time, seconds
    pub staleness_mean_secs: f64,
    /// refresh transfers applied across replicas
    pub refreshes: u64,
    pub wire_bytes: u64,
    pub wire_bytes_class: [u64; 3],
    pub cost: CostBreakdown,
}

impl ServeResult {
    pub fn cost_usd(&self) -> f64 {
        self.cost.total_usd()
    }

    /// Dollars per million requests — the serving-economics headline.
    pub fn usd_per_million(&self) -> f64 {
        self.cost.total_usd() / (self.requests.max(1) as f64) * 1e6
    }

    /// The replica that absorbed the most requests (lowest cloud id on
    /// ties) — the effective placement a policy converges to.
    pub fn busiest_replica(&self) -> usize {
        let mut best = 0;
        for (r, &n) in self.requests_by_replica.iter().enumerate().skip(1) {
            if n > self.requests_by_replica[best] {
                best = r;
            }
        }
        best
    }

    /// The blended objective `w·lat/lat_ref + (1−w)·$/usd_ref` this run
    /// achieved — the yardstick for "blended dominates both".
    pub fn objective(&self, w: f64, lat_ref_ms: f64, usd_ref_per_m: f64) -> f64 {
        w * self.mean_ms / lat_ref_ms
            + (1.0 - w) * self.usd_per_million() / usd_ref_per_m
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("policy", Json::str(self.policy.clone())),
            ("requests", Json::num(self.requests as f64)),
            ("sim_secs", Json::num(self.sim_secs)),
            ("events", Json::num(self.events as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("max_ms", Json::num(self.max_ms)),
            ("mean_queue_depth", Json::num(self.mean_queue_depth)),
            ("max_queue_depth", Json::num(self.max_queue_depth as f64)),
            (
                "requests_by_replica",
                Json::arr(
                    self.requests_by_replica
                        .iter()
                        .map(|&n| Json::num(n as f64)),
                ),
            ),
            ("staleness_mean_secs", Json::num(self.staleness_mean_secs)),
            ("refreshes", Json::num(self.refreshes as f64)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
            (
                "wire_bytes_class",
                Json::arr(
                    self.wire_bytes_class.iter().map(|&b| Json::num(b as f64)),
                ),
            ),
            ("usd_per_million", Json::num(self.usd_per_million())),
            ("cost", self.cost.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;

    #[test]
    fn default_config_validates() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_are_rejected() {
        let mut c = ServeConfig { duration_secs: 0.0, ..Default::default() };
        assert!(c.validate().is_err());
        c.duration_secs = 10.0;
        c.traffic.amplitude = 1.0;
        assert!(c.validate().is_err());
        c.traffic.amplitude = 0.5;
        c.max_batch = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn checkpoint_wiring_scales_the_service_model() {
        let ckpt = Checkpoint {
            params: ParamSet { leaves: vec![vec![0.5; 64], vec![1.0; 32]] },
            round: 7,
            global_version: 21,
            sim_secs: 123.0,
            wire_bytes: 456,
            experiment: "paper-base".into(),
        };
        let cfg = ServeConfig::default().with_checkpoint(&ckpt);
        assert_eq!(cfg.service.n_params, 96);
        assert_eq!(cfg.model_bytes, 96 * 4);
        assert_eq!(cfg.initial_version, 21);
        assert!(cfg.name.ends_with("@r7"));
        cfg.validate().unwrap();

        // a lossless stage reprices the refresh payload through the
        // same accessor the training broadcast uses — smaller on this
        // constant-leaf checkpoint, and exactly the transport's number
        let mut staged = ServeConfig::default();
        staged.lossless = LosslessStage::Auto;
        let staged = staged.with_checkpoint(&ckpt);
        assert_eq!(
            staged.model_bytes,
            crate::transport::dense_payload_bytes(
                &ckpt.params,
                LosslessStage::Auto
            )
        );
        assert!(staged.model_bytes < 96 * 4, "{}", staged.model_bytes);
        staged.validate().unwrap();
    }

    #[test]
    fn objective_blends_latency_and_dollars() {
        let mut r = ServeResult {
            name: "x".into(),
            policy: "latency".into(),
            requests: 1_000_000,
            sim_secs: 1.0,
            events: 1,
            p50_ms: 100.0,
            p99_ms: 200.0,
            mean_ms: 100.0,
            max_ms: 300.0,
            mean_queue_depth: 0.0,
            max_queue_depth: 0,
            requests_by_replica: vec![10, 30, 30],
            staleness_mean_secs: 0.0,
            refreshes: 0,
            wire_bytes: 0,
            wire_bytes_class: [0; 3],
            cost: CostBreakdown::zero(3),
        };
        r.cost.compute_usd[0] = 30.0;
        // $30 over 1M requests = $30/M; objective at the refs is 1.0
        assert!((r.usd_per_million() - 30.0).abs() < 1e-9);
        let j = r.objective(0.5, 100.0, 30.0);
        assert!((j - 1.0).abs() < 1e-12, "{j}");
        // ties in requests_by_replica resolve to the lowest replica id
        assert_eq!(r.busiest_replica(), 1);
        let json = r.to_json().to_string();
        assert!(json.contains("\"usd_per_million\""));
    }
}

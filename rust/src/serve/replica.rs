//! Per-cloud model replicas: a FIFO queue with greedy dynamic batching
//! and batch-size-dependent service times derived from the model's
//! parameter count.

use std::collections::VecDeque;

/// Inference cost model. One request generates `gen_tokens` tokens at
/// ~2 FLOPs per parameter per token; a replica sustains
/// `flops_per_sec · compute_speed` effective FLOP/s. Batching amortizes:
/// each request beyond the first costs only `batch_marginal` of a solo
/// request (weights are read once per batch), plus a fixed per-batch
/// scheduling overhead — the standard continuous-batching shape.
#[derive(Clone, Copy, Debug)]
pub struct ServiceModel {
    /// parameter count of the served model (checkpoint-derived)
    pub n_params: u64,
    /// decoded tokens per request
    pub gen_tokens: u64,
    /// effective accelerator FLOP/s at `compute_speed` 1.0
    pub flops_per_sec: f64,
    /// marginal cost of each extra request in a batch, in (0, 1]
    pub batch_marginal: f64,
    /// fixed per-batch overhead (scheduling, KV setup), seconds
    pub batch_overhead_secs: f64,
}

impl Default for ServiceModel {
    fn default() -> Self {
        ServiceModel {
            n_params: 1_300_000_000,
            gen_tokens: 64,
            flops_per_sec: 2e12,
            batch_marginal: 0.55,
            batch_overhead_secs: 0.015,
        }
    }
}

impl ServiceModel {
    /// Seconds one solo request's decode takes on a `speed`-rated node.
    pub fn per_request_secs(&self, speed: f64) -> f64 {
        assert!(speed > 0.0, "compute speed must be positive");
        2.0 * self.n_params as f64 * self.gen_tokens as f64
            / (self.flops_per_sec * speed)
    }

    /// Seconds a batch of `batch` requests occupies the replica.
    pub fn batch_secs(&self, batch: usize, speed: f64) -> f64 {
        assert!(batch >= 1, "empty batches don't run");
        let one = self.per_request_secs(speed);
        self.batch_overhead_secs
            + one * (1.0 + (batch - 1) as f64 * self.batch_marginal)
    }

    /// Marginal replica-seconds one request adds to a full batch — the
    /// router's compute-cost and expected-wait unit.
    pub fn marginal_secs(&self, speed: f64) -> f64 {
        self.per_request_secs(speed) * self.batch_marginal
    }
}

/// One queued request (its front-door cloud and front-door arrival time).
#[derive(Clone, Copy, Debug)]
pub struct QueuedRequest {
    pub src_cloud: usize,
    pub arrived: f64,
}

/// One model replica: FIFO queue, greedy dynamic batching (when the
/// replica frees up it takes up to `max_batch` queued requests as the
/// next batch), per-window busy-seconds for compute billing, and the
/// checkpoint version it currently serves.
#[derive(Clone, Debug)]
pub struct Replica {
    /// the cloud this replica lives in
    pub cloud: usize,
    /// the hosting node (the cloud's gateway)
    pub node: usize,
    /// the node's compute speed (cluster profile)
    pub speed: f64,
    pub max_batch: usize,
    pub queue: VecDeque<QueuedRequest>,
    /// requests in the batch currently on the accelerator
    pub serving: Vec<QueuedRequest>,
    /// total requests completed
    pub served: u64,
    /// cumulative accelerator seconds (compute billing numerator)
    pub busy_secs: f64,
    /// busy seconds since the last ledger observation window
    pub window_busy_secs: f64,
    /// high-water queue depth (excluding the in-flight batch)
    pub max_depth: usize,
    /// Σ queue depth sampled at every enqueue (mean-depth numerator)
    pub depth_sum: u64,
    /// checkpoint version currently served
    pub version: u64,
    /// simulated time that version was published
    pub version_time: f64,
    /// Σ (request completion staleness) over served requests
    pub staleness_sum: f64,
}

impl Replica {
    pub fn new(cloud: usize, node: usize, speed: f64, max_batch: usize) -> Replica {
        assert!(max_batch >= 1, "replica needs a batch capacity");
        Replica {
            cloud,
            node,
            speed,
            max_batch,
            queue: VecDeque::new(),
            serving: Vec::new(),
            served: 0,
            busy_secs: 0.0,
            window_busy_secs: 0.0,
            max_depth: 0,
            depth_sum: 0,
            version: 0,
            version_time: 0.0,
            staleness_sum: 0.0,
        }
    }

    pub fn idle(&self) -> bool {
        self.serving.is_empty()
    }

    /// Queue one request, tracking depth statistics.
    pub fn enqueue(&mut self, req: QueuedRequest) {
        self.queue.push_back(req);
        self.max_depth = self.max_depth.max(self.queue.len());
        self.depth_sum += self.queue.len() as u64;
    }

    /// Move up to `max_batch` queued requests onto the accelerator and
    /// return the batch's service time. Call only when idle and the
    /// queue is non-empty.
    pub fn start_batch(&mut self, model: &ServiceModel) -> f64 {
        debug_assert!(self.idle(), "replica already serving");
        debug_assert!(!self.queue.is_empty(), "nothing to serve");
        let take = self.queue.len().min(self.max_batch);
        self.serving.extend(self.queue.drain(..take));
        let secs = model.batch_secs(self.serving.len(), self.speed);
        self.busy_secs += secs;
        self.window_busy_secs += secs;
        secs
    }

    /// Finish the in-flight batch, returning its requests for latency
    /// accounting.
    pub fn finish_batch(&mut self) -> Vec<QueuedRequest> {
        debug_assert!(!self.serving.is_empty(), "no batch in flight");
        self.served += self.serving.len() as u64;
        std::mem::take(&mut self.serving)
    }

    /// The router's wait estimate: everything queued or on the
    /// accelerator ahead of a new request, in marginal service units.
    pub fn backlog(&self) -> usize {
        self.queue.len() + self.serving.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_times_scale_with_params_and_speed() {
        let m = ServiceModel::default();
        // 2 · 1.3e9 · 64 / 2e12 = 83.2 ms per solo request
        assert!((m.per_request_secs(1.0) - 0.0832).abs() < 1e-9);
        // a slower node is proportionally slower
        assert!(
            (m.per_request_secs(0.5) - 2.0 * m.per_request_secs(1.0)).abs()
                < 1e-12
        );
        let big = ServiceModel { n_params: 2 * m.n_params, ..m };
        assert!(
            (big.per_request_secs(1.0) - 2.0 * m.per_request_secs(1.0)).abs()
                < 1e-12
        );
    }

    #[test]
    fn batching_amortizes_but_never_wins_below_marginal() {
        let m = ServiceModel::default();
        let solo = m.batch_secs(1, 1.0);
        let batch8 = m.batch_secs(8, 1.0);
        // 8 requests in one batch beat 8 solo batches...
        assert!(batch8 < 8.0 * solo);
        // ...but still cost at least the marginal floor
        assert!(batch8 > m.per_request_secs(1.0) * 8.0 * m.batch_marginal);
        // batch time is monotone in batch size
        for b in 2..=16 {
            assert!(m.batch_secs(b, 1.0) > m.batch_secs(b - 1, 1.0));
        }
    }

    #[test]
    fn replica_fifo_batching_lifecycle() {
        let m = ServiceModel::default();
        let mut r = Replica::new(0, 0, 1.0, 4);
        for i in 0..6 {
            r.enqueue(QueuedRequest { src_cloud: i % 2, arrived: i as f64 });
        }
        assert_eq!(r.max_depth, 6);
        assert!(r.idle());
        let secs = r.start_batch(&m);
        assert!((secs - m.batch_secs(4, 1.0)).abs() < 1e-12);
        assert_eq!(r.serving.len(), 4);
        assert_eq!(r.queue.len(), 2);
        assert_eq!(r.backlog(), 6);
        let done = r.finish_batch();
        // FIFO: the first four arrivals complete first, in order
        let arrivals: Vec<f64> = done.iter().map(|q| q.arrived).collect();
        assert_eq!(arrivals, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(r.served, 4);
        assert!(r.idle());
        let secs2 = r.start_batch(&m);
        assert!((secs2 - m.batch_secs(2, 1.0)).abs() < 1e-12);
        assert!((r.busy_secs - (secs + secs2)).abs() < 1e-12);
    }
}

//! Pluggable request router: score every replica by expected latency
//! and by marginal dollars, pick per policy.
//!
//! The cost side mirrors [`crate::cost::placement`]: bytes leaving a
//! cloud are priced at that cloud's *first-tier* marginal egress rate
//! for the crossed link class, and compute at the replica cloud's
//! $/node-hour — volume tiers and framing scale every candidate alike,
//! so they cannot flip the argmin (the realized bill stays the
//! [`crate::cost::CostLedger`]'s job). The latency side is the static
//! network round trip (precomputed from the routed WAN) plus a
//! backlog-proportional queue-wait estimate, so latency routing load-
//! balances while cost routing deliberately concentrates on cheap
//! clouds.

use anyhow::{bail, Context, Result};

use crate::serve::replica::{Replica, ServiceModel};

/// The `--route` knob.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoutePolicy {
    /// minimize expected request latency (net + queue + service)
    Latency,
    /// minimize marginal dollars (egress + compute)
    Cost,
    /// minimize `w·latency/lat_ref + (1−w)·cost/usd_ref`
    Blended(f64),
}

impl RoutePolicy {
    /// Parse `"latency"`, `"cost"` or `"blended:W"` with `W ∈ [0, 1]`.
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        let s = s.trim();
        match s {
            "latency" => return Ok(RoutePolicy::Latency),
            "cost" => return Ok(RoutePolicy::Cost),
            _ => {}
        }
        if let Some(w) = s.strip_prefix("blended:") {
            let w: f64 = w.parse().with_context(|| format!("route {s:?}: bad weight"))?;
            if !(0.0..=1.0).contains(&w) {
                bail!("route {s:?}: weight must be in [0, 1]");
            }
            return Ok(RoutePolicy::Blended(w));
        }
        bail!("unknown route {s:?} (expected latency | cost | blended:W)")
    }

    /// Canonical name (round-trips through [`RoutePolicy::parse`]).
    pub fn name(&self) -> String {
        match self {
            RoutePolicy::Latency => "latency".into(),
            RoutePolicy::Cost => "cost".into(),
            RoutePolicy::Blended(w) => format!("blended:{w}"),
        }
    }
}

/// Static per-(front-door cloud, replica) scoring tables plus the
/// policy. Built once by the sim from the routed WAN and the price
/// book; `pick` is then O(replicas) per request with no allocation.
#[derive(Clone, Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    /// `net_secs[src][r]`: request + response network seconds between
    /// cloud `src`'s front door and replica `r` (0-adjacent for local)
    pub net_secs: Vec<Vec<f64>>,
    /// `egress_usd[src][r]`: marginal egress dollars one request +
    /// response pays on that path (0 for local)
    pub egress_usd: Vec<Vec<f64>>,
    /// `compute_usd[r]`: marginal compute dollars per request at
    /// replica `r` (batch-marginal seconds × the cloud's $/h)
    pub compute_usd: Vec<f64>,
    /// latency normalizer for blended scoring, seconds
    pub lat_ref_secs: f64,
    /// dollar normalizer for blended scoring, $ per request
    pub usd_ref: f64,
}

impl Router {
    /// Expected latency of sending one request from `src` to replica
    /// `r` right now: network round trip + backlog drain + own service.
    pub fn latency_estimate(
        &self,
        src: usize,
        r: usize,
        replica: &Replica,
        model: &ServiceModel,
    ) -> f64 {
        self.net_secs[src][r]
            + replica.backlog() as f64 * model.marginal_secs(replica.speed)
            + model.batch_secs(1, replica.speed)
    }

    /// Marginal dollars of serving one request from `src` at replica
    /// `r` (queue-independent, so cost routing is a static placement).
    pub fn cost_estimate(&self, src: usize, r: usize) -> f64 {
        self.egress_usd[src][r] + self.compute_usd[r]
    }

    /// Pick the replica for a request arriving at cloud `src`.
    /// Strictly-less argmin: ties resolve to the lowest replica id,
    /// deterministic across runs and platforms (the
    /// [`crate::cost::choose_leader`] convention).
    pub fn pick(&self, src: usize, replicas: &[Replica], model: &ServiceModel) -> usize {
        let score = |r: usize| -> f64 {
            match self.policy {
                RoutePolicy::Latency => self.latency_estimate(src, r, &replicas[r], model),
                RoutePolicy::Cost => self.cost_estimate(src, r),
                RoutePolicy::Blended(w) => {
                    let lat = self.latency_estimate(src, r, &replicas[r], model);
                    let usd = self.cost_estimate(src, r);
                    w * lat / self.lat_ref_secs + (1.0 - w) * usd / self.usd_ref
                }
            }
        };
        let mut best = 0;
        let mut best_score = score(0);
        for r in 1..replicas.len() {
            let s = score(r);
            if s < best_score {
                best = r;
                best_score = s;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_round_trips() {
        assert_eq!(RoutePolicy::parse("latency").unwrap(), RoutePolicy::Latency);
        assert_eq!(RoutePolicy::parse("cost").unwrap(), RoutePolicy::Cost);
        assert_eq!(RoutePolicy::parse("blended:0.5").unwrap(), RoutePolicy::Blended(0.5));
        assert!(RoutePolicy::parse("blended:1.5").is_err());
        assert!(RoutePolicy::parse("blended:x").is_err());
        assert!(RoutePolicy::parse("teleport").is_err());
        for p in [RoutePolicy::Latency, RoutePolicy::Cost, RoutePolicy::Blended(0.25)] {
            assert_eq!(RoutePolicy::parse(&p.name()).unwrap(), p);
        }
    }

    fn router(policy: RoutePolicy) -> Router {
        Router {
            policy,
            // src 0: replica 0 local, replica 1 is 100 ms away
            net_secs: vec![vec![0.004, 0.1], vec![0.1, 0.004]],
            egress_usd: vec![vec![0.0, 2e-6], vec![2e-6, 0.0]],
            // replica 0 expensive, replica 1 cheap
            compute_usd: vec![5e-5, 1e-5],
            lat_ref_secs: 0.15,
            usd_ref: 3e-5,
        }
    }

    fn replicas() -> Vec<Replica> {
        vec![Replica::new(0, 0, 1.0, 8), Replica::new(1, 1, 1.0, 8)]
    }

    #[test]
    fn latency_prefers_local_cost_prefers_cheap() {
        let model = ServiceModel::default();
        let reps = replicas();
        assert_eq!(router(RoutePolicy::Latency).pick(0, &reps, &model), 0);
        assert_eq!(router(RoutePolicy::Cost).pick(0, &reps, &model), 1);
        // pure-latency blend is latency; pure-cost blend is cost
        assert_eq!(router(RoutePolicy::Blended(1.0)).pick(0, &reps, &model), 0);
        assert_eq!(router(RoutePolicy::Blended(0.0)).pick(0, &reps, &model), 1);
    }

    #[test]
    fn latency_routing_sheds_load_off_a_deep_queue() {
        let model = ServiceModel::default();
        let mut reps = replicas();
        // pile a backlog onto the local replica until the 100 ms hop to
        // the idle one is the faster choice
        let r = router(RoutePolicy::Latency);
        for i in 0..4 {
            reps[0].enqueue(crate::serve::replica::QueuedRequest {
                src_cloud: 0,
                arrived: i as f64,
            });
        }
        assert_eq!(r.pick(0, &reps, &model), 1);
        // cost routing ignores the queue entirely
        assert_eq!(router(RoutePolicy::Cost).pick(0, &reps, &model), 1);
    }

    #[test]
    fn ties_resolve_to_the_lowest_replica_id() {
        let model = ServiceModel::default();
        let reps = replicas();
        let mut r = router(RoutePolicy::Cost);
        r.egress_usd = vec![vec![0.0, 0.0]; 2];
        r.compute_usd = vec![1e-5, 1e-5];
        assert_eq!(r.pick(0, &reps, &model), 0);
        assert_eq!(r.pick(1, &reps, &model), 0);
    }
}

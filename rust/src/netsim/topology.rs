//! Routed WAN topology between cloud worker nodes + the leader.
//!
//! Nodes 0..n-1 are the cluster's worker nodes; the aggregation leader is
//! co-located with one of them — the gateway of the placement decision's
//! cloud (the paper's setup has the global model hosted on one of the
//! clouds; see [`crate::cost::placement`]). Links are asymmetric-capable
//! (directed) and carry a [`LinkClass`]:
//!
//! * [`LinkClass::IntraAz`] — nodes inside the same cloud (AZ-level
//!   peers): fat, sub-millisecond.
//! * [`LinkClass::IntraRegion`] — gateways of different clouds in the
//!   same region: quick cross-AZ class links.
//! * [`LinkClass::InterRegion`] — gateways across regions: the paper's
//!   WAN bottleneck.
//!
//! Only the *gateway* node of each cloud (its first member) has links to
//! other clouds; a transfer between two arbitrary workers is routed
//! `src → gw(src) → gw(dst) → dst` (degenerate hops skipped) and priced
//! per hop, store-and-forward. The per-link byte ledger therefore tells
//! exactly how many bytes crossed each class of link — the measurement
//! behind the hierarchical-vs-star comparison.

use std::collections::HashMap;

use crate::cluster::ClusterSpec;
use crate::netsim::link::{Link, TransferStats};
use crate::netsim::protocol::Protocol;
use crate::util::rng::Pcg64;

/// RNG stream id for network noise (distinct from data/DP streams).
const WAN_STREAM: u64 = 0x57414e;

/// Why a route or transfer could not be serviced. Failures are *data*
/// (not panics) so the coordinator can detect a dead gateway and fail
/// over instead of tearing the run down.
#[derive(Debug, thiserror::Error, Clone, Copy, PartialEq, Eq)]
pub enum NetError {
    #[error("no route {src}->{dst}: link ({a},{b}) does not exist")]
    MissingLink { src: usize, dst: usize, a: usize, b: usize },
    #[error("node {node} WAN egress is down")]
    NodeDown { node: usize },
}

/// What kind of path segment a link is (for per-class byte accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// same cloud, different AZ-level node
    IntraAz,
    /// different clouds, same region (gateway-to-gateway)
    IntraRegion,
    /// different regions (gateway-to-gateway) — the WAN bottleneck
    InterRegion,
}

impl LinkClass {
    /// Every class, in [`LinkClass::index`] order (dense array keys for
    /// the per-class ledgers and price books).
    pub const ALL: [LinkClass; 3] =
        [LinkClass::IntraAz, LinkClass::IntraRegion, LinkClass::InterRegion];

    /// Dense index into `[T; 3]` tables keyed by class.
    pub fn index(self) -> usize {
        match self {
            LinkClass::IntraAz => 0,
            LinkClass::IntraRegion => 1,
            LinkClass::InterRegion => 2,
        }
    }

    /// Canonical name (price-book JSON, report tables).
    pub fn name(self) -> &'static str {
        match self {
            LinkClass::IntraAz => "intra-az",
            LinkClass::IntraRegion => "intra-region",
            LinkClass::InterRegion => "inter-region",
        }
    }

    /// Inverse of [`LinkClass::name`].
    pub fn parse(s: &str) -> Option<LinkClass> {
        LinkClass::ALL.into_iter().find(|c| c.name() == s)
    }
}

/// Directed routed WAN with connection-warmth tracking and per-link
/// byte accounting.
#[derive(Clone, Debug)]
pub struct Wan {
    n: usize,
    /// links[(src, dst)]
    links: HashMap<(usize, usize), Link>,
    /// link class per (src, dst). Grows monotonically: entries survive a
    /// link's removal (gateway re-election) so the per-class byte ledger
    /// keeps counting bytes that crossed a since-torn-down link. A pair's
    /// class can never change — mesh links connect gateways of different
    /// clouds, intra-AZ links members of one cloud — so stale entries are
    /// always accurate. Liveness is `links`' job, not this map's.
    classes: HashMap<(usize, usize), LinkClass>,
    /// owning cloud per node (identity for flat meshes)
    cloud_of: Vec<usize>,
    /// gateway node per cloud
    gateways: Vec<usize>,
    /// nodes whose WAN egress has failed ([`Wan::fail_node`]): their
    /// non-intra-AZ links are dead and routes refuse to transit them
    down: Vec<bool>,
    /// protocol connections already established (src, dst, proto)
    warm: HashMap<(usize, usize, Protocol), bool>,
    /// cumulative wire bytes per (src, dst)
    ledger: HashMap<(usize, usize), u64>,
    rng: Pcg64,
}

impl Wan {
    /// Uniform mesh: every pair gets the same link spec (class
    /// [`LinkClass::InterRegion`]); every node is its own cloud, so all
    /// routes are single-hop.
    pub fn uniform(n: usize, link: Link, seed: u64) -> Wan {
        let mut links = HashMap::new();
        let mut classes = HashMap::new();
        for s in 0..n {
            for d in 0..n {
                if s != d {
                    links.insert((s, d), link.clone());
                    classes.insert((s, d), LinkClass::InterRegion);
                }
            }
        }
        Wan {
            n,
            links,
            classes,
            cloud_of: (0..n).collect(),
            gateways: (0..n).collect(),
            down: vec![false; n],
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    /// Link presets per class (bandwidth bps, rtt s, jitter, loss).
    fn class_link(class: LinkClass) -> Link {
        match class {
            // same cloud, AZ-to-AZ: very fat and near-instant
            LinkClass::IntraAz => Link {
                bandwidth_bps: 25e9,
                rtt_s: 0.0005,
                jitter: 0.01,
                loss_rate: 0.00001,
            },
            // same region, cross-cloud: fat and quick
            LinkClass::IntraRegion => Link {
                bandwidth_bps: 5e9,
                rtt_s: 0.002,
                jitter: 0.03,
                loss_rate: 0.0001,
            },
            // inter-region WAN: the paper's bottleneck
            LinkClass::InterRegion => Link {
                bandwidth_bps: 1e9,
                rtt_s: 0.080,
                jitter: 0.08,
                loss_rate: 0.002,
            },
        }
    }

    /// Routed topology shaped by the cluster's clouds and regions:
    /// full intra-cloud mesh per cloud, plus a gateway-to-gateway mesh
    /// between clouds (intra- or inter-region per the cloud regions).
    /// With single-node clouds this degenerates to the flat star/mesh of
    /// the paper's 3-platform setup.
    pub fn from_cluster(cluster: &ClusterSpec, seed: u64) -> Wan {
        let n = cluster.n();
        let cloud_of: Vec<usize> = (0..n).map(|i| cluster.cloud_of(i)).collect();
        let n_clouds = cluster.n_clouds();
        let gateways: Vec<usize> = (0..n_clouds).map(|c| cluster.gateway(c)).collect();

        let mut links = HashMap::new();
        let mut classes = HashMap::new();
        let mut add = |s: usize, d: usize, class: LinkClass| {
            links.insert((s, d), Wan::class_link(class));
            classes.insert((s, d), class);
        };

        // intra-cloud mesh
        for s in 0..n {
            for d in 0..n {
                if s != d && cloud_of[s] == cloud_of[d] {
                    add(s, d, LinkClass::IntraAz);
                }
            }
        }
        // gateway-to-gateway mesh between clouds
        for a in 0..n_clouds {
            for b in 0..n_clouds {
                if a == b {
                    continue;
                }
                let (ga, gb) = (gateways[a], gateways[b]);
                let same_region = cluster.platforms[ga].region
                    == cluster.platforms[gb].region;
                let class = if same_region {
                    LinkClass::IntraRegion
                } else {
                    LinkClass::InterRegion
                };
                add(ga, gb, class);
            }
        }

        Wan {
            n,
            links,
            classes,
            cloud_of,
            gateways,
            down: vec![false; n],
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Mutable access for ablations (e.g. degrade one link mid-run).
    pub fn link_mut(&mut self, src: usize, dst: usize) -> Option<&mut Link> {
        self.links.get_mut(&(src, dst))
    }

    pub fn link(&self, src: usize, dst: usize) -> Option<&Link> {
        self.links.get(&(src, dst))
    }

    /// Class of the direct link (src, dst), if one currently exists.
    pub fn link_class(&self, src: usize, dst: usize) -> Option<LinkClass> {
        if !self.links.contains_key(&(src, dst)) {
            return None;
        }
        self.classes.get(&(src, dst)).copied()
    }

    /// Whether the direct link (src, dst) exists and is in service.
    /// Intra-AZ fabric survives a WAN-egress failure ([`Wan::fail_node`]);
    /// every other class needs both endpoints' egress up.
    fn link_up(&self, src: usize, dst: usize) -> bool {
        match self.link_class(src, dst) {
            None => false,
            Some(LinkClass::IntraAz) => true,
            Some(_) => !self.down[src] && !self.down[dst],
        }
    }

    /// The hop sequence a transfer src→dst takes: the direct link when
    /// one exists and is up, otherwise via the clouds' gateways
    /// (degenerate hops skipped). Every returned hop has a live link;
    /// a missing link or a dead gateway is an error, not a panic, so
    /// callers can fail over.
    pub fn route(&self, src: usize, dst: usize) -> Result<Vec<(usize, usize)>, NetError> {
        assert!(src != dst, "loopback transfers are free; don't route them");
        if self.link_up(src, dst) {
            return Ok(vec![(src, dst)]);
        }
        let gs = self.gateways[self.cloud_of[src]];
        let gd = self.gateways[self.cloud_of[dst]];
        let mut hops = Vec::with_capacity(3);
        if src != gs {
            hops.push((src, gs));
        }
        if gs != gd {
            hops.push((gs, gd));
        }
        if gd != dst {
            hops.push((gd, dst));
        }
        for &(a, b) in &hops {
            if !self.links.contains_key(&(a, b)) {
                return Err(NetError::MissingLink { src, dst, a, b });
            }
            if !self.link_up(a, b) {
                let node = if self.down[a] { a } else { b };
                return Err(NetError::NodeDown { node });
            }
        }
        Ok(hops)
    }

    /// Simulate a transfer along the route src→dst (store-and-forward per
    /// hop); updates warmth and the byte ledger per traversed link.
    /// Returns combined stats: times and bytes summed over hops.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> Result<TransferStats, NetError> {
        assert!(src != dst, "loopback transfers are free; don't simulate them");
        let hops = self.route(src, dst)?;
        let mut total = TransferStats { time_s: 0.0, wire_bytes: 0, handshake_s: 0.0 };
        for (s, d) in hops {
            let st = self.transfer_hop(s, d, payload_bytes, protocol, streams)?;
            total.time_s += st.time_s;
            total.wire_bytes += st.wire_bytes;
            total.handshake_s += st.handshake_s;
        }
        Ok(total)
    }

    /// One direct-link hop (the pre-routing `transfer` semantics).
    fn transfer_hop(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> Result<TransferStats, NetError> {
        let link = match self.links.get(&(src, dst)) {
            Some(l) => l.clone(),
            None => {
                return Err(NetError::MissingLink { src, dst, a: src, b: dst })
            }
        };
        if !self.link_up(src, dst) {
            let node = if self.down[src] { src } else { dst };
            return Err(NetError::NodeDown { node });
        }
        let warm = *self.warm.get(&(src, dst, protocol)).unwrap_or(&false);
        let stats =
            link.transfer(payload_bytes, protocol, warm, streams, &mut self.rng);
        self.warm.insert((src, dst, protocol), true);
        *self.ledger.entry((src, dst)).or_insert(0) += stats.wire_bytes;
        Ok(stats)
    }

    /// Fail `node`'s WAN egress: its non-intra-AZ links go out of
    /// service and routes refuse to transit it. The AZ fabric inside its
    /// cloud keeps working (it is a separate substrate from the WAN
    /// egress), which is what lets a standby gateway take over without
    /// losing the node's in-flight training state. Warm connections
    /// touching the node are dropped.
    pub fn fail_node(&mut self, node: usize) {
        assert!(node < self.n);
        self.down[node] = true;
        self.warm.retain(|&(s, d, _), _| s != node && d != node);
    }

    /// Bring `node`'s WAN egress back (connections stay cold until
    /// re-established).
    pub fn restore_node(&mut self, node: usize) {
        assert!(node < self.n);
        self.down[node] = false;
    }

    /// Whether `node`'s WAN egress is failed.
    pub fn node_down(&self, node: usize) -> bool {
        self.down[node]
    }

    /// Current gateway node of `cloud` (as this topology routes it).
    pub fn gateway(&self, cloud: usize) -> usize {
        self.gateways[cloud]
    }

    /// Re-elect `new_gw` as `cloud`'s gateway: the old gateway's mesh
    /// links are torn down and the new gateway inherits a fresh link of
    /// the same class to every other cloud's gateway (all members of a
    /// cloud share a region, so the class carries over). All warm
    /// connections are dropped — failover forces cold handshakes, which
    /// is exactly the cost a real re-election pays.
    pub fn reelect_gateway(&mut self, cloud: usize, new_gw: usize) {
        assert!(new_gw < self.n, "gateway {new_gw} out of range");
        assert_eq!(
            self.cloud_of[new_gw], cloud,
            "node {new_gw} is not a member of cloud {cloud}"
        );
        let old = self.gateways[cloud];
        if old == new_gw {
            return;
        }
        let peer_gateways: Vec<usize> = self
            .gateways
            .iter()
            .enumerate()
            .filter(|&(c, _)| c != cloud)
            .map(|(_, &g)| g)
            .collect();
        for g in peer_gateways {
            // class entries are kept (the per-class ledger still counts
            // bytes that crossed the old mesh); only the links go away
            let class = *self
                .classes
                .get(&(old, g))
                .expect("gateway mesh link must exist");
            self.links.remove(&(old, g));
            self.links.remove(&(g, old));
            self.links.insert((new_gw, g), Wan::class_link(class));
            self.links.insert((g, new_gw), Wan::class_link(class));
            self.classes.insert((new_gw, g), class);
            self.classes.insert((g, new_gw), class);
        }
        self.gateways[cloud] = new_gw;
        self.reset_connections();
    }

    /// Multiply the bandwidth of the directed link (src, dst) by
    /// `factor` (fault injection: `0.1` = 10× slower).
    pub fn degrade_link(
        &mut self,
        src: usize,
        dst: usize,
        factor: f64,
    ) -> Result<(), NetError> {
        assert!(factor > 0.0 && factor.is_finite(), "bad degrade factor {factor}");
        match self.links.get_mut(&(src, dst)) {
            Some(l) => {
                l.bandwidth_bps *= factor;
                Ok(())
            }
            None => Err(NetError::MissingLink { src, dst, a: src, b: dst }),
        }
    }

    /// Drop all warm connections (e.g. after a simulated failure).
    pub fn reset_connections(&mut self) {
        self.warm.clear();
    }

    /// Total bytes that crossed any link.
    pub fn total_wire_bytes(&self) -> u64 {
        self.ledger.values().sum()
    }

    /// Bytes sent from `src` to `dst` so far (direct link only).
    pub fn wire_bytes(&self, src: usize, dst: usize) -> u64 {
        *self.ledger.get(&(src, dst)).unwrap_or(&0)
    }

    /// Total bytes that crossed links of `class` — e.g. how much update
    /// traffic actually paid the inter-region WAN.
    pub fn wire_bytes_class(&self, class: LinkClass) -> u64 {
        self.ledger
            .iter()
            .filter(|(k, _)| self.classes.get(k) == Some(&class))
            .map(|(_, v)| v)
            .sum()
    }

    /// Convenience: bytes over [`LinkClass::InterRegion`] links.
    pub fn inter_region_bytes(&self) -> u64 {
        self.wire_bytes_class(LinkClass::InterRegion)
    }

    /// Cumulative wire bytes split by (source cloud, link class) —
    /// `out[cloud][class.index()]`. This is the measurement a cloud bill
    /// is computed from: egress is billed to the cloud the bytes *leave*.
    /// Sums are u64 (order-independent), so the split is identical no
    /// matter how the ledger's hash map iterates.
    pub fn wire_bytes_by_cloud_class(&self) -> Vec<[u64; 3]> {
        let n_clouds =
            self.cloud_of.iter().map(|&c| c + 1).max().unwrap_or(0);
        let mut out = vec![[0u64; 3]; n_clouds];
        for (&(s, d), &bytes) in &self.ledger {
            let class = self
                .classes
                .get(&(s, d))
                .expect("ledgered link has a recorded class");
            out[self.cloud_of[s]][class.index()] += bytes;
        }
        out
    }

    /// Zero the ledger (per-round accounting).
    pub fn reset_ledger(&mut self) {
        self.ledger.clear();
    }

    /// Snapshot the WAN's run state for the WAL: links (fault-mutable —
    /// degradations and re-elections change them), class map, gateways,
    /// down flags, warm connections, the byte ledger and the noise RNG.
    /// Maps are walked in sorted key order so the encoding is identical
    /// across runs regardless of hash-map iteration order.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        let mut links: Vec<(&(usize, usize), &Link)> = self.links.iter().collect();
        links.sort_by_key(|(&k, _)| k);
        w.put_usize(links.len());
        for (&(s, d), l) in links {
            w.put_usize(s);
            w.put_usize(d);
            w.put_f64(l.bandwidth_bps);
            w.put_f64(l.rtt_s);
            w.put_f64(l.jitter);
            w.put_f64(l.loss_rate);
        }
        let mut classes: Vec<(&(usize, usize), &LinkClass)> =
            self.classes.iter().collect();
        classes.sort_by_key(|(&k, _)| k);
        w.put_usize(classes.len());
        for (&(s, d), c) in classes {
            w.put_usize(s);
            w.put_usize(d);
            w.put_u8(c.index() as u8);
        }
        w.put_usize(self.gateways.len());
        for &g in &self.gateways {
            w.put_usize(g);
        }
        w.put_usize(self.down.len());
        for &f in &self.down {
            w.put_bool(f);
        }
        let mut warm: Vec<(usize, usize, Protocol)> = self
            .warm
            .iter()
            .filter(|(_, &v)| v)
            .map(|(&k, _)| k)
            .collect();
        warm.sort_by_key(|&(s, d, p)| (s, d, p.name()));
        w.put_usize(warm.len());
        for (s, d, p) in warm {
            w.put_usize(s);
            w.put_usize(d);
            w.put_str(p.name());
        }
        let mut ledger: Vec<(&(usize, usize), &u64)> = self.ledger.iter().collect();
        ledger.sort_by_key(|(&k, _)| k);
        w.put_usize(ledger.len());
        for (&(s, d), &bytes) in ledger {
            w.put_usize(s);
            w.put_usize(d);
            w.put_u64(bytes);
        }
        w.put_u64x4(self.rng.state_words());
    }

    /// Restore state written by [`Wan::wal_encode`]. `self` must have
    /// been built from the same cluster spec (same node/cloud layout).
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        use anyhow::ensure;
        let n_links = r.get_usize()?;
        self.links.clear();
        for _ in 0..n_links {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            ensure!(s < self.n && d < self.n, "WAL WAN link ({s},{d}) out of range");
            let link = Link {
                bandwidth_bps: r.get_f64()?,
                rtt_s: r.get_f64()?,
                jitter: r.get_f64()?,
                loss_rate: r.get_f64()?,
            };
            self.links.insert((s, d), link);
        }
        let n_classes = r.get_usize()?;
        self.classes.clear();
        for _ in 0..n_classes {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            let idx = r.get_u8()? as usize;
            ensure!(idx < LinkClass::ALL.len(), "WAL bad link class {idx}");
            self.classes.insert((s, d), LinkClass::ALL[idx]);
        }
        let n_gw = r.get_usize()?;
        ensure!(
            n_gw == self.gateways.len(),
            "WAL WAN has {n_gw} clouds, run has {}",
            self.gateways.len()
        );
        for g in self.gateways.iter_mut() {
            *g = r.get_usize()?;
        }
        let n_down = r.get_usize()?;
        ensure!(
            n_down == self.down.len(),
            "WAL WAN has {n_down} nodes, run has {}",
            self.down.len()
        );
        for f in self.down.iter_mut() {
            *f = r.get_bool()?;
        }
        let n_warm = r.get_usize()?;
        self.warm.clear();
        for _ in 0..n_warm {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            let name = r.get_str()?;
            let p = Protocol::parse(&name).ok_or_else(|| {
                anyhow::anyhow!("WAL unknown protocol {name:?}")
            })?;
            self.warm.insert((s, d, p), true);
        }
        let n_ledger = r.get_usize()?;
        self.ledger.clear();
        for _ in 0..n_ledger {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            let bytes = r.get_u64()?;
            self.ledger.insert((s, d), bytes);
        }
        self.rng = Pcg64::from_state_words(r.get_u64x4()?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mesh_has_all_pairs() {
        let w = Wan::uniform(3, Link::new(1e9, 0.04), 1);
        for s in 0..3 {
            for d in 0..3 {
                assert_eq!(w.link(s, d).is_some(), s != d);
            }
        }
    }

    #[test]
    fn ledger_accumulates() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 2);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1).unwrap();
        w.transfer(0, 1, 1000, Protocol::Grpc, 1).unwrap();
        w.transfer(1, 0, 500, Protocol::Grpc, 1).unwrap();
        assert!(w.wire_bytes(0, 1) >= 2000);
        assert!(w.wire_bytes(1, 0) >= 500);
        assert_eq!(w.total_wire_bytes(),
                   w.wire_bytes(0, 1) + w.wire_bytes(1, 0));
        w.reset_ledger();
        assert_eq!(w.total_wire_bytes(), 0);
    }

    #[test]
    fn second_transfer_is_warm() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.05), 3);
        let cold = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        let warm = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        assert!(warm.handshake_s < cold.handshake_s);
        w.reset_connections();
        let cold2 = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        assert!((cold2.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn cluster_wan_penalizes_cross_region() {
        let c = crate::cluster::ClusterSpec::paper_default();
        let mut w = Wan::from_cluster(&c, 4);
        // aws(us-east) -> gcp(us-central) is cross-region in this preset
        let t_us = w.transfer(0, 1, 10_000_000, Protocol::Grpc, 8).unwrap();
        // azure is eu-west: same class of link, so just check both are sane
        let t_eu = w.transfer(0, 2, 10_000_000, Protocol::Grpc, 8).unwrap();
        assert!(t_us.time_s > 0.0 && t_eu.time_s > 0.0);
        // all paper-default pairs are gateway-to-gateway across regions
        assert_eq!(w.link_class(0, 1), Some(LinkClass::InterRegion));
        assert_eq!(w.inter_region_bytes(), w.total_wire_bytes());
    }

    #[test]
    fn scaled_cluster_routes_via_gateways() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(4);
        let w = Wan::from_cluster(&c, 7);
        // same cloud: direct intra-AZ link
        assert_eq!(w.route(1, 3).unwrap(), vec![(1, 3)]);
        assert_eq!(w.link_class(1, 3), Some(LinkClass::IntraAz));
        // worker 5 (cloud 1, gw 4) -> leader node 0 (cloud 0, gw 0)
        assert_eq!(w.route(5, 0).unwrap(), vec![(5, 4), (4, 0)]);
        assert_eq!(w.link_class(4, 0), Some(LinkClass::InterRegion));
        // worker to worker across clouds: three hops
        assert_eq!(w.route(5, 9).unwrap(), vec![(5, 4), (4, 8), (8, 9)]);
        // gateways talk directly
        assert_eq!(w.route(4, 8).unwrap(), vec![(4, 8)]);
    }

    #[test]
    fn multi_hop_transfer_ledgers_every_link() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 9);
        // node 3 (cloud 1, gw 2) -> node 0: hops (3,2) intra + (2,0) inter
        let st = w.transfer(3, 0, 1_000_000, Protocol::Grpc, 8).unwrap();
        assert!(w.wire_bytes(3, 2) >= 1_000_000);
        assert!(w.wire_bytes(2, 0) >= 1_000_000);
        assert_eq!(
            st.wire_bytes,
            w.wire_bytes(3, 2) + w.wire_bytes(2, 0)
        );
        // per-class split: exactly one inter-region crossing
        assert_eq!(w.inter_region_bytes(), w.wire_bytes(2, 0));
        assert_eq!(
            w.wire_bytes_class(LinkClass::IntraAz),
            w.wire_bytes(3, 2)
        );
        // the inter-region hop dominates the time
        let intra_only = {
            let mut w2 = Wan::from_cluster(&c, 9);
            w2.transfer(3, 2, 1_000_000, Protocol::Grpc, 8).unwrap()
        };
        assert!(st.time_s > intra_only.time_s);
    }

    #[test]
    fn cloud_class_split_follows_the_ledger() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 21);
        // node 3 (cloud 1, gw 2) -> node 0: intra-az hop src cloud 1,
        // inter-region hop src cloud 1
        w.transfer(3, 0, 1_000_000, Protocol::Grpc, 8).unwrap();
        // node 0 (cloud 0 gateway) -> node 4 (cloud 2 gateway):
        // one inter-region hop src cloud 0
        w.transfer(0, 4, 500_000, Protocol::Grpc, 8).unwrap();
        let split = w.wire_bytes_by_cloud_class();
        assert_eq!(split.len(), 3);
        assert_eq!(split[1][LinkClass::IntraAz.index()], w.wire_bytes(3, 2));
        assert_eq!(split[1][LinkClass::InterRegion.index()], w.wire_bytes(2, 0));
        assert_eq!(split[0][LinkClass::InterRegion.index()], w.wire_bytes(0, 4));
        assert_eq!(split[2], [0, 0, 0]);
        // the split sums back to the flat per-class ledger
        for class in LinkClass::ALL {
            let by_cloud: u64 =
                split.iter().map(|row| row[class.index()]).sum();
            assert_eq!(by_cloud, w.wire_bytes_class(class));
        }
        assert_eq!(LinkClass::parse("inter-region"), Some(LinkClass::InterRegion));
        assert_eq!(LinkClass::parse("x"), None);
    }

    #[test]
    #[should_panic]
    fn loopback_rejected() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 5);
        let _ = w.transfer(1, 1, 10, Protocol::Tcp, 1);
    }

    #[test]
    fn failed_egress_kills_wan_but_not_az_fabric() {
        // scaled(2): cloud 1 = {2, 3}, gateway 2
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 11);
        w.fail_node(2);
        assert!(w.node_down(2));
        // WAN leg through the dead gateway errors out...
        assert_eq!(w.route(3, 0), Err(NetError::NodeDown { node: 2 }));
        assert!(w.transfer(2, 0, 100, Protocol::Grpc, 1).is_err());
        // ...but the intra-AZ fabric still works
        assert_eq!(w.route(3, 2).unwrap(), vec![(3, 2)]);
        assert!(w.transfer(3, 2, 100, Protocol::Grpc, 1).is_ok());
        // restore brings the WAN back
        w.restore_node(2);
        assert!(!w.node_down(2));
        assert!(w.transfer(3, 0, 100, Protocol::Grpc, 1).is_ok());
    }

    #[test]
    fn reelection_rebuilds_the_mesh_and_drops_warmth() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 12);
        // warm the dying gateway's WAN link, then fail it over
        let cold = w.transfer(2, 0, 10_000, Protocol::Grpc, 1).unwrap();
        let inter_before = w.inter_region_bytes();
        assert!(inter_before >= 10_000);
        w.fail_node(2);
        w.reelect_gateway(1, 3);
        assert_eq!(w.gateway(1), 3);
        // bytes that crossed the torn-down mesh stay in the class ledger
        assert_eq!(w.inter_region_bytes(), inter_before);
        // the old mesh links are gone, the new gateway inherits the class
        assert_eq!(w.link_class(2, 0), None);
        assert_eq!(w.link_class(3, 0), Some(LinkClass::InterRegion));
        assert_eq!(w.link_class(3, 4), Some(LinkClass::InterRegion));
        // routes now transit the new gateway
        assert_eq!(w.route(2, 0).unwrap(), vec![(2, 3), (3, 0)]);
        // failover pays a cold handshake again
        let after = w.transfer(3, 0, 10_000, Protocol::Grpc, 1).unwrap();
        assert!((after.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn degrade_link_slows_transfers() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 13);
        w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap(); // warm up
        let before = w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap();
        w.degrade_link(0, 1, 0.01).unwrap();
        let after = w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap();
        assert!(after.time_s > before.time_s * 5.0);
        assert!(w.degrade_link(0, 0, 0.5).is_err()); // no such link
    }
}

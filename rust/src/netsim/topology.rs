//! Full-mesh WAN topology between cloud platforms + the leader.
//!
//! Node 0..n-1 are the platforms; the aggregation leader is co-located
//! with node 0 (the paper's setup has the global model hosted on one of
//! the clouds). Links are asymmetric-capable (directed), built from
//! region distance presets.

use std::collections::HashMap;

use crate::cluster::ClusterSpec;
use crate::netsim::link::{Link, TransferStats};
use crate::netsim::protocol::Protocol;
use crate::util::rng::Pcg64;

/// RNG stream id for network noise (distinct from data/DP streams).
const WAN_STREAM: u64 = 0x57414e;

/// Directed full-mesh WAN with connection-warmth tracking and per-link
/// byte accounting.
#[derive(Clone, Debug)]
pub struct Wan {
    n: usize,
    /// links[(src, dst)]
    links: HashMap<(usize, usize), Link>,
    /// protocol connections already established (src, dst, proto)
    warm: HashMap<(usize, usize, Protocol), bool>,
    /// cumulative wire bytes per (src, dst)
    ledger: HashMap<(usize, usize), u64>,
    rng: Pcg64,
}

impl Wan {
    /// Uniform mesh: every pair gets the same link spec.
    pub fn uniform(n: usize, link: Link, seed: u64) -> Wan {
        let mut links = HashMap::new();
        for s in 0..n {
            for d in 0..n {
                if s != d {
                    links.insert((s, d), link.clone());
                }
            }
        }
        Wan {
            n,
            links,
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    /// WAN shaped by the cluster's regions: same-region pairs get LAN-ish
    /// links, cross-region pairs get transatlantic-ish ones.
    pub fn from_cluster(cluster: &ClusterSpec, seed: u64) -> Wan {
        let n = cluster.n();
        let mut links = HashMap::new();
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                let same_region =
                    cluster.platforms[s].region == cluster.platforms[d].region;
                let link = if same_region {
                    // same region, cross-AZ: fat and quick
                    Link { bandwidth_bps: 5e9, rtt_s: 0.002, jitter: 0.03,
                           loss_rate: 0.0001 }
                } else {
                    // inter-region WAN: the paper's bottleneck
                    Link { bandwidth_bps: 1e9, rtt_s: 0.080, jitter: 0.08,
                           loss_rate: 0.002 }
                };
                links.insert((s, d), link);
            }
        }
        Wan {
            n,
            links,
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Mutable access for ablations (e.g. degrade one link mid-run).
    pub fn link_mut(&mut self, src: usize, dst: usize) -> Option<&mut Link> {
        self.links.get_mut(&(src, dst))
    }

    pub fn link(&self, src: usize, dst: usize) -> Option<&Link> {
        self.links.get(&(src, dst))
    }

    /// Simulate a transfer; updates warmth and the byte ledger.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> TransferStats {
        assert!(src != dst, "loopback transfers are free; don't simulate them");
        let link = self.links.get(&(src, dst)).expect("missing link").clone();
        let warm = *self.warm.get(&(src, dst, protocol)).unwrap_or(&false);
        let stats =
            link.transfer(payload_bytes, protocol, warm, streams, &mut self.rng);
        self.warm.insert((src, dst, protocol), true);
        *self.ledger.entry((src, dst)).or_insert(0) += stats.wire_bytes;
        stats
    }

    /// Drop all warm connections (e.g. after a simulated failure).
    pub fn reset_connections(&mut self) {
        self.warm.clear();
    }

    /// Total bytes that crossed any link.
    pub fn total_wire_bytes(&self) -> u64 {
        self.ledger.values().sum()
    }

    /// Bytes sent from `src` to `dst` so far.
    pub fn wire_bytes(&self, src: usize, dst: usize) -> u64 {
        *self.ledger.get(&(src, dst)).unwrap_or(&0)
    }

    /// Zero the ledger (per-round accounting).
    pub fn reset_ledger(&mut self) {
        self.ledger.clear();
    }
}



#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mesh_has_all_pairs() {
        let w = Wan::uniform(3, Link::new(1e9, 0.04), 1);
        for s in 0..3 {
            for d in 0..3 {
                assert_eq!(w.link(s, d).is_some(), s != d);
            }
        }
    }

    #[test]
    fn ledger_accumulates() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 2);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1);
        w.transfer(1, 0, 500, Protocol::Grpc, 1);
        assert!(w.wire_bytes(0, 1) >= 2000);
        assert!(w.wire_bytes(1, 0) >= 500);
        assert_eq!(w.total_wire_bytes(),
                   w.wire_bytes(0, 1) + w.wire_bytes(1, 0));
        w.reset_ledger();
        assert_eq!(w.total_wire_bytes(), 0);
    }

    #[test]
    fn second_transfer_is_warm() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.05), 3);
        let cold = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        let warm = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        assert!(warm.handshake_s < cold.handshake_s);
        w.reset_connections();
        let cold2 = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        assert!((cold2.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn cluster_wan_penalizes_cross_region() {
        let c = crate::cluster::ClusterSpec::paper_default();
        let mut w = Wan::from_cluster(&c, 4);
        // aws(us-east) -> gcp(us-central) is cross-region in this preset
        let t_us = w.transfer(0, 1, 10_000_000, Protocol::Grpc, 8);
        // azure is eu-west: same class of link, so just check both are sane
        let t_eu = w.transfer(0, 2, 10_000_000, Protocol::Grpc, 8);
        assert!(t_us.time_s > 0.0 && t_eu.time_s > 0.0);
    }

    #[test]
    #[should_panic]
    fn loopback_rejected() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 5);
        w.transfer(1, 1, 10, Protocol::Tcp, 1);
    }
}

//! Routed WAN topology between cloud worker nodes + the leader.
//!
//! Nodes 0..n-1 are the cluster's worker nodes; the aggregation leader is
//! co-located with one of them — the gateway of the placement decision's
//! cloud (the paper's setup has the global model hosted on one of the
//! clouds; see [`crate::cost::placement`]). Links are asymmetric-capable
//! (directed) and carry a [`LinkClass`]:
//!
//! * [`LinkClass::IntraAz`] — nodes inside the same cloud (AZ-level
//!   peers): fat, sub-millisecond.
//! * [`LinkClass::IntraRegion`] — gateways of different clouds in the
//!   same region: quick cross-AZ class links.
//! * [`LinkClass::InterRegion`] — gateways across regions: the paper's
//!   WAN bottleneck.
//!
//! Only the *gateway* node of each cloud (its first member) has links to
//! other clouds; a transfer between two arbitrary workers is routed
//! `src → gw(src) → gw(dst) → dst` (degenerate hops skipped) and priced
//! per hop, store-and-forward. The per-link byte ledger therefore tells
//! exactly how many bytes crossed each class of link — the measurement
//! behind the hierarchical-vs-star comparison.
//!
//! Storage is CSR-style indexed adjacency: per-node sorted neighbor rows
//! over parallel edge arrays (link spec, byte ledger, per-protocol
//! warmth bitmask). A hop is a binary search in one row plus array
//! loads — no hashing — and a planet-scale mesh (millions of directed
//! intra-cloud edges) stays cache-resident. A link's class is a pure
//! function of the endpoint clouds' (construction-time) regions, so no
//! per-pair class table is needed; bytes that crossed since-torn-down
//! links (gateway re-election) move to a small `retired` map so every
//! ledger query stays exact across failovers.

use std::collections::{BTreeMap, HashMap};

use crate::cluster::ClusterSpec;
use crate::netsim::link::{Link, TransferStats};
use crate::netsim::protocol::Protocol;
use crate::util::rng::Pcg64;

/// RNG stream id for network noise (distinct from data/DP streams).
const WAN_STREAM: u64 = 0x57414e;

/// Why a route or transfer could not be serviced. Failures are *data*
/// (not panics) so the coordinator can detect a dead gateway and fail
/// over instead of tearing the run down.
#[derive(Debug, thiserror::Error, Clone, Copy, PartialEq, Eq)]
pub enum NetError {
    #[error("no route {src}->{dst}: link ({a},{b}) does not exist")]
    MissingLink { src: usize, dst: usize, a: usize, b: usize },
    #[error("node {node} WAN egress is down")]
    NodeDown { node: usize },
}

/// What kind of path segment a link is (for per-class byte accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// same cloud, different AZ-level node
    IntraAz,
    /// different clouds, same region (gateway-to-gateway)
    IntraRegion,
    /// different regions (gateway-to-gateway) — the WAN bottleneck
    InterRegion,
}

impl LinkClass {
    /// Every class, in [`LinkClass::index`] order (dense array keys for
    /// the per-class ledgers and price books).
    pub const ALL: [LinkClass; 3] =
        [LinkClass::IntraAz, LinkClass::IntraRegion, LinkClass::InterRegion];

    /// Dense index into `[T; 3]` tables keyed by class.
    pub fn index(self) -> usize {
        match self {
            LinkClass::IntraAz => 0,
            LinkClass::IntraRegion => 1,
            LinkClass::InterRegion => 2,
        }
    }

    /// Canonical name (price-book JSON, report tables).
    pub fn name(self) -> &'static str {
        match self {
            LinkClass::IntraAz => "intra-az",
            LinkClass::IntraRegion => "intra-region",
            LinkClass::InterRegion => "inter-region",
        }
    }

    /// Inverse of [`LinkClass::name`].
    pub fn parse(s: &str) -> Option<LinkClass> {
        LinkClass::ALL.into_iter().find(|c| c.name() == s)
    }
}

/// One directed edge for [`Wan::rebuild`]: `(src, dst, link, ledgered
/// bytes, warm-protocol bitmask)`.
type EdgeRec = (usize, usize, Link, u64, u8);

/// Deferred warmth + ledger effects of read-only
/// [`Wan::transfer_scoped`] calls, merged back serially with
/// [`Wan::apply_scratch`]. This is what lets independent clouds
/// simulate their intra-cloud legs on separate threads against a shared
/// `&Wan` without locking: each thread owns its scratch (and its own
/// noise RNG stream), and the merge runs in fixed cloud order, so every
/// ledger stays bit-identical at any thread count.
#[derive(Clone, Debug, Default)]
pub struct WanScratch {
    /// (src, dst, warm bits newly set, wire bytes) per touched edge
    touched: Vec<(usize, usize, u8, u64)>,
}

/// Directed routed WAN with connection-warmth tracking and per-link
/// byte accounting.
#[derive(Clone, Debug)]
pub struct Wan {
    n: usize,
    /// owning cloud per node (identity for flat meshes)
    cloud_of: Vec<usize>,
    /// interned region id per cloud, captured at construction from each
    /// cloud's gateway platform. A pair's [`LinkClass`] is a pure
    /// function of `cloud_of` + this table (same cloud → intra-AZ, same
    /// region → intra-region, else inter-region) and can never change —
    /// members of a cloud share the original gateway's region — so the
    /// per-class byte ledger keeps counting bytes that crossed a
    /// since-torn-down link across gateway re-elections.
    region_of: Vec<u32>,
    /// gateway node per cloud
    gateways: Vec<usize>,
    /// nodes whose WAN egress has failed ([`Wan::fail_node`]): their
    /// non-intra-AZ links are dead and routes refuse to transit them
    down: Vec<bool>,
    /// CSR row offsets into `col`/`links`/`edge_bytes`/`warm`; len n+1
    row_start: Vec<u32>,
    /// neighbor node per directed edge, sorted within each row
    col: Vec<u32>,
    /// link spec per directed edge (fault-mutable: degradations)
    links: Vec<Link>,
    /// cumulative wire bytes per live directed edge
    edge_bytes: Vec<u64>,
    /// warm-connection bitmask per edge, bit = `1 << Protocol::index()`
    warm: Vec<u8>,
    /// bytes that crossed links later torn down by re-election, keyed
    /// (src, dst) — keeps [`Wan::wire_bytes`] exact after failovers
    retired: BTreeMap<(usize, usize), u64>,
    /// authoritative cumulative wire bytes per (source cloud, class):
    /// incremented at transfer time, never recomputed by scanning edges
    by_cloud_class: Vec<[u64; 3]>,
    rng: Pcg64,
    /// per-cloud noise RNG streams for the parallel hierarchical round
    /// ([`Wan::transfer_scoped`]); unused (and untouched) otherwise
    cloud_rngs: Vec<Pcg64>,
}

impl Wan {
    fn empty(
        n: usize,
        cloud_of: Vec<usize>,
        region_of: Vec<u32>,
        gateways: Vec<usize>,
        seed: u64,
    ) -> Wan {
        let n_clouds = gateways.len();
        let cloud_rngs = (0..n_clouds)
            .map(|c| Pcg64::new(seed, WAN_STREAM ^ ((c as u64 + 1) << 24)))
            .collect();
        Wan {
            n,
            cloud_of,
            region_of,
            gateways,
            down: vec![false; n],
            row_start: vec![0; n + 1],
            col: Vec::new(),
            links: Vec::new(),
            edge_bytes: Vec::new(),
            warm: Vec::new(),
            retired: BTreeMap::new(),
            by_cloud_class: vec![[0u64; 3]; n_clouds],
            rng: Pcg64::new(seed, WAN_STREAM),
            cloud_rngs,
        }
    }

    /// Replace the adjacency with `edges` (sorted here; ledgered bytes
    /// and warmth carry per edge record).
    fn rebuild(&mut self, mut edges: Vec<EdgeRec>) {
        assert!(
            u32::try_from(edges.len()).is_ok(),
            "edge count fits in u32"
        );
        edges.sort_unstable_by_key(|&(s, d, ..)| (s, d));
        self.row_start.clear();
        self.col.clear();
        self.links.clear();
        self.edge_bytes.clear();
        self.warm.clear();
        self.row_start.reserve(self.n + 1);
        self.col.reserve(edges.len());
        self.links.reserve(edges.len());
        self.edge_bytes.reserve(edges.len());
        self.warm.reserve(edges.len());
        let mut row = 0usize;
        self.row_start.push(0);
        for (s, d, link, bytes, warm) in edges {
            debug_assert!(s < self.n && d < self.n && s != d);
            while row < s {
                row += 1;
                self.row_start.push(self.col.len() as u32);
            }
            self.col.push(d as u32);
            self.links.push(link);
            self.edge_bytes.push(bytes);
            self.warm.push(warm);
        }
        while row < self.n {
            row += 1;
            self.row_start.push(self.col.len() as u32);
        }
    }

    /// Dense edge index of the directed link (src, dst), if present:
    /// one binary search in `src`'s neighbor row.
    fn edge_index(&self, src: usize, dst: usize) -> Option<usize> {
        if src >= self.n || dst >= self.n {
            return None;
        }
        let lo = self.row_start[src] as usize;
        let hi = self.row_start[src + 1] as usize;
        self.col[lo..hi]
            .binary_search(&(dst as u32))
            .ok()
            .map(|i| lo + i)
    }

    /// Class of the (src, dst) pair — pure function of clouds/regions,
    /// independent of whether a link currently exists.
    fn class_of(&self, src: usize, dst: usize) -> LinkClass {
        let (cs, cd) = (self.cloud_of[src], self.cloud_of[dst]);
        if cs == cd {
            LinkClass::IntraAz
        } else if self.region_of[cs] == self.region_of[cd] {
            LinkClass::IntraRegion
        } else {
            LinkClass::InterRegion
        }
    }

    /// Uniform mesh: every pair gets the same link spec (class
    /// [`LinkClass::InterRegion`]); every node is its own cloud, so all
    /// routes are single-hop.
    pub fn uniform(n: usize, link: Link, seed: u64) -> Wan {
        let mut wan = Wan::empty(
            n,
            (0..n).collect(),
            (0..n as u32).collect(), // distinct region per cloud
            (0..n).collect(),
            seed,
        );
        let mut edges = Vec::with_capacity(n.saturating_sub(1) * n);
        for s in 0..n {
            for d in 0..n {
                if s != d {
                    edges.push((s, d, link.clone(), 0, 0));
                }
            }
        }
        wan.rebuild(edges);
        wan
    }

    /// Link presets per class (bandwidth bps, rtt s, jitter, loss).
    fn class_link(class: LinkClass) -> Link {
        match class {
            // same cloud, AZ-to-AZ: very fat and near-instant
            LinkClass::IntraAz => Link {
                bandwidth_bps: 25e9,
                rtt_s: 0.0005,
                jitter: 0.01,
                loss_rate: 0.00001,
            },
            // same region, cross-cloud: fat and quick
            LinkClass::IntraRegion => Link {
                bandwidth_bps: 5e9,
                rtt_s: 0.002,
                jitter: 0.03,
                loss_rate: 0.0001,
            },
            // inter-region WAN: the paper's bottleneck
            LinkClass::InterRegion => Link {
                bandwidth_bps: 1e9,
                rtt_s: 0.080,
                jitter: 0.08,
                loss_rate: 0.002,
            },
        }
    }

    /// Routed topology shaped by the cluster's clouds and regions:
    /// full intra-cloud mesh per cloud, plus a gateway-to-gateway mesh
    /// between clouds (intra- or inter-region per the cloud regions).
    /// With single-node clouds this degenerates to the flat star/mesh of
    /// the paper's 3-platform setup.
    pub fn from_cluster(cluster: &ClusterSpec, seed: u64) -> Wan {
        let n = cluster.n();
        let cloud_of: Vec<usize> = (0..n).map(|i| cluster.cloud_of(i)).collect();
        let n_clouds = cluster.n_clouds();
        let gateways: Vec<usize> = (0..n_clouds).map(|c| cluster.gateway(c)).collect();
        // intern each cloud's (gateway) region to a dense id
        let mut region_ids: HashMap<&str, u32> = HashMap::new();
        let region_of: Vec<u32> = (0..n_clouds)
            .map(|c| {
                let r = cluster.platforms[gateways[c]].region.as_str();
                let next = region_ids.len() as u32;
                *region_ids.entry(r).or_insert(next)
            })
            .collect();

        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_clouds];
        for i in 0..n {
            members[cloud_of[i]].push(i);
        }
        let mut wan = Wan::empty(n, cloud_of, region_of, gateways, seed);
        let mut edges: Vec<EdgeRec> = Vec::new();
        // intra-cloud mesh
        for mem in &members {
            for &s in mem {
                for &d in mem {
                    if s != d {
                        edges.push((s, d, Wan::class_link(LinkClass::IntraAz), 0, 0));
                    }
                }
            }
        }
        // gateway-to-gateway mesh between clouds
        for a in 0..n_clouds {
            for b in 0..n_clouds {
                if a == b {
                    continue;
                }
                let (ga, gb) = (wan.gateways[a], wan.gateways[b]);
                let class = wan.class_of(ga, gb);
                edges.push((ga, gb, Wan::class_link(class), 0, 0));
            }
        }
        wan.rebuild(edges);
        wan
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Mutable access for ablations (e.g. degrade one link mid-run).
    pub fn link_mut(&mut self, src: usize, dst: usize) -> Option<&mut Link> {
        self.edge_index(src, dst).map(|e| &mut self.links[e])
    }

    pub fn link(&self, src: usize, dst: usize) -> Option<&Link> {
        self.edge_index(src, dst).map(|e| &self.links[e])
    }

    /// Class of the direct link (src, dst), if one currently exists.
    pub fn link_class(&self, src: usize, dst: usize) -> Option<LinkClass> {
        self.edge_index(src, dst).map(|_| self.class_of(src, dst))
    }

    /// Whether the direct link (src, dst) exists and is in service.
    /// Intra-AZ fabric survives a WAN-egress failure ([`Wan::fail_node`]);
    /// every other class needs both endpoints' egress up.
    fn link_up(&self, src: usize, dst: usize) -> bool {
        match self.link_class(src, dst) {
            None => false,
            Some(LinkClass::IntraAz) => true,
            Some(_) => !self.down[src] && !self.down[dst],
        }
    }

    /// The hop sequence a transfer src→dst takes: the direct link when
    /// one exists and is up, otherwise via the clouds' gateways
    /// (degenerate hops skipped). Every returned hop has a live link;
    /// a missing link or a dead gateway is an error, not a panic, so
    /// callers can fail over.
    pub fn route(&self, src: usize, dst: usize) -> Result<Vec<(usize, usize)>, NetError> {
        assert!(src != dst, "loopback transfers are free; don't route them");
        if self.link_up(src, dst) {
            return Ok(vec![(src, dst)]);
        }
        let gs = self.gateways[self.cloud_of[src]];
        let gd = self.gateways[self.cloud_of[dst]];
        let mut hops = Vec::with_capacity(3);
        if src != gs {
            hops.push((src, gs));
        }
        if gs != gd {
            hops.push((gs, gd));
        }
        if gd != dst {
            hops.push((gd, dst));
        }
        for &(a, b) in &hops {
            if self.edge_index(a, b).is_none() {
                return Err(NetError::MissingLink { src, dst, a, b });
            }
            if !self.link_up(a, b) {
                let node = if self.down[a] { a } else { b };
                return Err(NetError::NodeDown { node });
            }
        }
        Ok(hops)
    }

    /// Simulate a transfer along the route src→dst (store-and-forward per
    /// hop); updates warmth and the byte ledger per traversed link.
    /// Returns combined stats: times and bytes summed over hops.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> Result<TransferStats, NetError> {
        assert!(src != dst, "loopback transfers are free; don't simulate them");
        let hops = self.route(src, dst)?;
        let mut total = TransferStats { time_s: 0.0, wire_bytes: 0, handshake_s: 0.0 };
        for (s, d) in hops {
            let st = self.transfer_hop(s, d, payload_bytes, protocol, streams)?;
            total.time_s += st.time_s;
            total.wire_bytes += st.wire_bytes;
            total.handshake_s += st.handshake_s;
        }
        Ok(total)
    }

    /// One direct-link hop (the pre-routing `transfer` semantics).
    fn transfer_hop(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> Result<TransferStats, NetError> {
        let e = match self.edge_index(src, dst) {
            Some(e) => e,
            None => return Err(NetError::MissingLink { src, dst, a: src, b: dst }),
        };
        if !self.link_up(src, dst) {
            let node = if self.down[src] { src } else { dst };
            return Err(NetError::NodeDown { node });
        }
        let bit = 1u8 << protocol.index();
        let warm = self.warm[e] & bit != 0;
        let stats =
            self.links[e].transfer(payload_bytes, protocol, warm, streams, &mut self.rng);
        self.warm[e] |= bit;
        self.edge_bytes[e] += stats.wire_bytes;
        let class = self.class_of(src, dst);
        self.by_cloud_class[self.cloud_of[src]][class.index()] += stats.wire_bytes;
        Ok(stats)
    }

    /// Read-only variant of [`Wan::transfer`] for the parallel
    /// hierarchical round: noise comes from the caller's `rng` (one
    /// per-cloud stream) and warmth/ledger effects land in `scratch`
    /// instead of `self`, so independent clouds can run concurrently
    /// against a shared `&Wan`. Warmth established earlier in the same
    /// scratch is honored (second transfer over a hop is warm).
    pub(crate) fn transfer_scoped(
        &self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
        rng: &mut Pcg64,
        scratch: &mut WanScratch,
    ) -> Result<TransferStats, NetError> {
        assert!(src != dst, "loopback transfers are free; don't simulate them");
        let hops = self.route(src, dst)?;
        let mut total = TransferStats { time_s: 0.0, wire_bytes: 0, handshake_s: 0.0 };
        let bit = 1u8 << protocol.index();
        for (s, d) in hops {
            let e = self.edge_index(s, d).expect("routed hop has a live link");
            let at = scratch.touched.iter().position(|t| t.0 == s && t.1 == d);
            let warm = self.warm[e] & bit != 0
                || at.is_some_and(|i| scratch.touched[i].2 & bit != 0);
            let st = self.links[e].transfer(payload_bytes, protocol, warm, streams, rng);
            match at {
                Some(i) => {
                    scratch.touched[i].2 |= bit;
                    scratch.touched[i].3 += st.wire_bytes;
                }
                None => scratch.touched.push((s, d, bit, st.wire_bytes)),
            }
            total.time_s += st.time_s;
            total.wire_bytes += st.wire_bytes;
            total.handshake_s += st.handshake_s;
        }
        Ok(total)
    }

    /// Fold a [`WanScratch`] back into warmth + ledgers. Call serially,
    /// in fixed cloud order, after the parallel phase joins.
    pub(crate) fn apply_scratch(&mut self, scratch: &WanScratch) {
        for &(s, d, bits, bytes) in &scratch.touched {
            let e = self.edge_index(s, d).expect("scratch edge has a live link");
            self.warm[e] |= bits;
            self.edge_bytes[e] += bytes;
            let class = self.class_of(s, d);
            self.by_cloud_class[self.cloud_of[s]][class.index()] += bytes;
        }
    }

    /// Move the per-cloud noise RNG streams out (parallel round phase);
    /// pair with [`Wan::restore_cloud_rngs`].
    pub(crate) fn take_cloud_rngs(&mut self) -> Vec<Pcg64> {
        std::mem::take(&mut self.cloud_rngs)
    }

    /// Put the per-cloud noise RNG streams back after a parallel phase.
    pub(crate) fn restore_cloud_rngs(&mut self, rngs: Vec<Pcg64>) {
        self.cloud_rngs = rngs;
    }

    /// Fail `node`'s WAN egress: its non-intra-AZ links go out of
    /// service and routes refuse to transit it. The AZ fabric inside its
    /// cloud keeps working (it is a separate substrate from the WAN
    /// egress), which is what lets a standby gateway take over without
    /// losing the node's in-flight training state. Warm connections
    /// touching the node are dropped.
    pub fn fail_node(&mut self, node: usize) {
        assert!(node < self.n);
        self.down[node] = true;
        let (lo, hi) = (self.row_start[node] as usize, self.row_start[node + 1] as usize);
        for e in lo..hi {
            self.warm[e] = 0;
            // adjacency is symmetric by construction: cool the reverse
            // edge too
            let d = self.col[e] as usize;
            if let Some(re) = self.edge_index(d, node) {
                self.warm[re] = 0;
            }
        }
    }

    /// Bring `node`'s WAN egress back (connections stay cold until
    /// re-established).
    pub fn restore_node(&mut self, node: usize) {
        assert!(node < self.n);
        self.down[node] = false;
    }

    /// Whether `node`'s WAN egress is failed.
    pub fn node_down(&self, node: usize) -> bool {
        self.down[node]
    }

    /// Current gateway node of `cloud` (as this topology routes it).
    pub fn gateway(&self, cloud: usize) -> usize {
        self.gateways[cloud]
    }

    /// Re-elect `new_gw` as `cloud`'s gateway: the old gateway's mesh
    /// links are torn down and the new gateway inherits a fresh link of
    /// the same class to every other cloud's gateway (all members of a
    /// cloud share a region, so the class carries over). All warm
    /// connections are dropped — failover forces cold handshakes, which
    /// is exactly the cost a real re-election pays. Bytes that crossed
    /// the torn-down mesh move to the `retired` ledger so per-pair and
    /// per-class queries stay exact.
    pub fn reelect_gateway(&mut self, cloud: usize, new_gw: usize) {
        assert!(new_gw < self.n, "gateway {new_gw} out of range");
        assert_eq!(
            self.cloud_of[new_gw], cloud,
            "node {new_gw} is not a member of cloud {cloud}"
        );
        let old = self.gateways[cloud];
        if old == new_gw {
            return;
        }
        let mut removed: Vec<(usize, usize)> = Vec::new();
        for (c, &g) in self.gateways.iter().enumerate() {
            if c != cloud {
                self.edge_index(old, g).expect("gateway mesh link must exist");
                removed.push((old, g));
                removed.push((g, old));
            }
        }
        let mut edges: Vec<EdgeRec> = Vec::with_capacity(self.col.len());
        for s in 0..self.n {
            let (lo, hi) = (self.row_start[s] as usize, self.row_start[s + 1] as usize);
            for e in lo..hi {
                let d = self.col[e] as usize;
                if removed.contains(&(s, d)) {
                    // per-pair + per-class ledgers still count bytes
                    // that crossed the old mesh
                    if self.edge_bytes[e] > 0 {
                        *self.retired.entry((s, d)).or_insert(0) += self.edge_bytes[e];
                    }
                    continue;
                }
                // re-election drops all warmth (cold handshakes)
                edges.push((s, d, self.links[e].clone(), self.edge_bytes[e], 0));
            }
        }
        for (c, &g) in self.gateways.iter().enumerate() {
            if c != cloud {
                let class = self.class_of(new_gw, g);
                edges.push((new_gw, g, Wan::class_link(class), 0, 0));
                edges.push((g, new_gw, Wan::class_link(class), 0, 0));
            }
        }
        self.rebuild(edges);
        self.gateways[cloud] = new_gw;
    }

    /// Multiply the bandwidth of the directed link (src, dst) by
    /// `factor` (fault injection: `0.1` = 10× slower).
    pub fn degrade_link(
        &mut self,
        src: usize,
        dst: usize,
        factor: f64,
    ) -> Result<(), NetError> {
        assert!(factor > 0.0 && factor.is_finite(), "bad degrade factor {factor}");
        match self.edge_index(src, dst) {
            Some(e) => {
                self.links[e].bandwidth_bps *= factor;
                Ok(())
            }
            None => Err(NetError::MissingLink { src, dst, a: src, b: dst }),
        }
    }

    /// Drop all warm connections (e.g. after a simulated failure).
    pub fn reset_connections(&mut self) {
        self.warm.fill(0);
    }

    /// Total bytes that crossed any link.
    pub fn total_wire_bytes(&self) -> u64 {
        self.by_cloud_class.iter().flatten().sum()
    }

    /// Bytes sent from `src` to `dst` so far (direct link only),
    /// including bytes over a since-torn-down link of that pair.
    pub fn wire_bytes(&self, src: usize, dst: usize) -> u64 {
        let live = self.edge_index(src, dst).map_or(0, |e| self.edge_bytes[e]);
        live + self.retired.get(&(src, dst)).copied().unwrap_or(0)
    }

    /// Total bytes that crossed links of `class` — e.g. how much update
    /// traffic actually paid the inter-region WAN.
    pub fn wire_bytes_class(&self, class: LinkClass) -> u64 {
        self.by_cloud_class.iter().map(|row| row[class.index()]).sum()
    }

    /// Convenience: bytes over [`LinkClass::InterRegion`] links.
    pub fn inter_region_bytes(&self) -> u64 {
        self.wire_bytes_class(LinkClass::InterRegion)
    }

    /// Cumulative wire bytes split by (source cloud, link class) —
    /// `out[cloud][class.index()]`. This is the measurement a cloud bill
    /// is computed from: egress is billed to the cloud the bytes *leave*.
    /// Maintained incrementally at transfer time (u64 sums, so the split
    /// is identical no matter what order transfers land in).
    pub fn wire_bytes_by_cloud_class(&self) -> Vec<[u64; 3]> {
        self.by_cloud_class.clone()
    }

    /// Zero the ledger (per-round accounting).
    pub fn reset_ledger(&mut self) {
        self.edge_bytes.fill(0);
        self.retired.clear();
        self.by_cloud_class.fill([0; 3]);
    }

    /// Snapshot the WAN's run state for the WAL: every directed edge
    /// (link spec is fault-mutable — degradations and re-elections
    /// change it) with its ledgered bytes and warm-protocol bits, plus
    /// gateways, down flags, the retired ledger, the per-cloud-class
    /// split and every noise RNG stream. Edges are walked in CSR (sorted
    /// key) order so the encoding is identical across runs.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_usize(self.col.len());
        for s in 0..self.n {
            let (lo, hi) = (self.row_start[s] as usize, self.row_start[s + 1] as usize);
            for e in lo..hi {
                w.put_usize(s);
                w.put_usize(self.col[e] as usize);
                let l = &self.links[e];
                w.put_f64(l.bandwidth_bps);
                w.put_f64(l.rtt_s);
                w.put_f64(l.jitter);
                w.put_f64(l.loss_rate);
                w.put_u64(self.edge_bytes[e]);
                w.put_u8(self.warm[e]);
            }
        }
        w.put_usize(self.gateways.len());
        for &g in &self.gateways {
            w.put_usize(g);
        }
        w.put_usize(self.down.len());
        for &f in &self.down {
            w.put_bool(f);
        }
        w.put_usize(self.retired.len());
        for (&(s, d), &bytes) in &self.retired {
            w.put_usize(s);
            w.put_usize(d);
            w.put_u64(bytes);
        }
        w.put_usize(self.by_cloud_class.len());
        for row in &self.by_cloud_class {
            for &b in row {
                w.put_u64(b);
            }
        }
        w.put_u64x4(self.rng.state_words());
        w.put_usize(self.cloud_rngs.len());
        for rng in &self.cloud_rngs {
            w.put_u64x4(rng.state_words());
        }
    }

    /// Restore state written by [`Wan::wal_encode`]. `self` must have
    /// been built from the same cluster spec (same node/cloud layout).
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        use anyhow::ensure;
        let n_edges = r.get_usize()?;
        let mut edges: Vec<EdgeRec> = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            ensure!(s < self.n && d < self.n, "WAL WAN link ({s},{d}) out of range");
            let link = Link {
                bandwidth_bps: r.get_f64()?,
                rtt_s: r.get_f64()?,
                jitter: r.get_f64()?,
                loss_rate: r.get_f64()?,
            };
            let bytes = r.get_u64()?;
            let warm = r.get_u8()?;
            edges.push((s, d, link, bytes, warm));
        }
        self.rebuild(edges);
        let n_gw = r.get_usize()?;
        ensure!(
            n_gw == self.gateways.len(),
            "WAL WAN has {n_gw} clouds, run has {}",
            self.gateways.len()
        );
        for g in self.gateways.iter_mut() {
            *g = r.get_usize()?;
        }
        let n_down = r.get_usize()?;
        ensure!(
            n_down == self.down.len(),
            "WAL WAN has {n_down} nodes, run has {}",
            self.down.len()
        );
        for f in self.down.iter_mut() {
            *f = r.get_bool()?;
        }
        let n_retired = r.get_usize()?;
        self.retired.clear();
        for _ in 0..n_retired {
            let s = r.get_usize()?;
            let d = r.get_usize()?;
            let bytes = r.get_u64()?;
            self.retired.insert((s, d), bytes);
        }
        let n_split = r.get_usize()?;
        ensure!(
            n_split == self.by_cloud_class.len(),
            "WAL WAN split has {n_split} clouds, run has {}",
            self.by_cloud_class.len()
        );
        for row in self.by_cloud_class.iter_mut() {
            for b in row.iter_mut() {
                *b = r.get_u64()?;
            }
        }
        self.rng = Pcg64::from_state_words(r.get_u64x4()?);
        let n_crng = r.get_usize()?;
        ensure!(
            n_crng == self.cloud_rngs.len(),
            "WAL WAN has {n_crng} cloud RNG streams, run has {}",
            self.cloud_rngs.len()
        );
        for rng in self.cloud_rngs.iter_mut() {
            *rng = Pcg64::from_state_words(r.get_u64x4()?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mesh_has_all_pairs() {
        let w = Wan::uniform(3, Link::new(1e9, 0.04), 1);
        for s in 0..3 {
            for d in 0..3 {
                assert_eq!(w.link(s, d).is_some(), s != d);
            }
        }
    }

    #[test]
    fn ledger_accumulates() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 2);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1).unwrap();
        w.transfer(0, 1, 1000, Protocol::Grpc, 1).unwrap();
        w.transfer(1, 0, 500, Protocol::Grpc, 1).unwrap();
        assert!(w.wire_bytes(0, 1) >= 2000);
        assert!(w.wire_bytes(1, 0) >= 500);
        assert_eq!(w.total_wire_bytes(),
                   w.wire_bytes(0, 1) + w.wire_bytes(1, 0));
        w.reset_ledger();
        assert_eq!(w.total_wire_bytes(), 0);
    }

    #[test]
    fn second_transfer_is_warm() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.05), 3);
        let cold = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        let warm = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        assert!(warm.handshake_s < cold.handshake_s);
        w.reset_connections();
        let cold2 = w.transfer(0, 1, 10_000, Protocol::Grpc, 1).unwrap();
        assert!((cold2.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn cluster_wan_penalizes_cross_region() {
        let c = crate::cluster::ClusterSpec::paper_default();
        let mut w = Wan::from_cluster(&c, 4);
        // aws(us-east) -> gcp(us-central) is cross-region in this preset
        let t_us = w.transfer(0, 1, 10_000_000, Protocol::Grpc, 8).unwrap();
        // azure is eu-west: same class of link, so just check both are sane
        let t_eu = w.transfer(0, 2, 10_000_000, Protocol::Grpc, 8).unwrap();
        assert!(t_us.time_s > 0.0 && t_eu.time_s > 0.0);
        // all paper-default pairs are gateway-to-gateway across regions
        assert_eq!(w.link_class(0, 1), Some(LinkClass::InterRegion));
        assert_eq!(w.inter_region_bytes(), w.total_wire_bytes());
    }

    #[test]
    fn scaled_cluster_routes_via_gateways() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(4);
        let w = Wan::from_cluster(&c, 7);
        // same cloud: direct intra-AZ link
        assert_eq!(w.route(1, 3).unwrap(), vec![(1, 3)]);
        assert_eq!(w.link_class(1, 3), Some(LinkClass::IntraAz));
        // worker 5 (cloud 1, gw 4) -> leader node 0 (cloud 0, gw 0)
        assert_eq!(w.route(5, 0).unwrap(), vec![(5, 4), (4, 0)]);
        assert_eq!(w.link_class(4, 0), Some(LinkClass::InterRegion));
        // worker to worker across clouds: three hops
        assert_eq!(w.route(5, 9).unwrap(), vec![(5, 4), (4, 8), (8, 9)]);
        // gateways talk directly
        assert_eq!(w.route(4, 8).unwrap(), vec![(4, 8)]);
    }

    #[test]
    fn multi_hop_transfer_ledgers_every_link() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 9);
        // node 3 (cloud 1, gw 2) -> node 0: hops (3,2) intra + (2,0) inter
        let st = w.transfer(3, 0, 1_000_000, Protocol::Grpc, 8).unwrap();
        assert!(w.wire_bytes(3, 2) >= 1_000_000);
        assert!(w.wire_bytes(2, 0) >= 1_000_000);
        assert_eq!(
            st.wire_bytes,
            w.wire_bytes(3, 2) + w.wire_bytes(2, 0)
        );
        // per-class split: exactly one inter-region crossing
        assert_eq!(w.inter_region_bytes(), w.wire_bytes(2, 0));
        assert_eq!(
            w.wire_bytes_class(LinkClass::IntraAz),
            w.wire_bytes(3, 2)
        );
        // the inter-region hop dominates the time
        let intra_only = {
            let mut w2 = Wan::from_cluster(&c, 9);
            w2.transfer(3, 2, 1_000_000, Protocol::Grpc, 8).unwrap()
        };
        assert!(st.time_s > intra_only.time_s);
    }

    #[test]
    fn cloud_class_split_follows_the_ledger() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 21);
        // node 3 (cloud 1, gw 2) -> node 0: intra-az hop src cloud 1,
        // inter-region hop src cloud 1
        w.transfer(3, 0, 1_000_000, Protocol::Grpc, 8).unwrap();
        // node 0 (cloud 0 gateway) -> node 4 (cloud 2 gateway):
        // one inter-region hop src cloud 0
        w.transfer(0, 4, 500_000, Protocol::Grpc, 8).unwrap();
        let split = w.wire_bytes_by_cloud_class();
        assert_eq!(split.len(), 3);
        assert_eq!(split[1][LinkClass::IntraAz.index()], w.wire_bytes(3, 2));
        assert_eq!(split[1][LinkClass::InterRegion.index()], w.wire_bytes(2, 0));
        assert_eq!(split[0][LinkClass::InterRegion.index()], w.wire_bytes(0, 4));
        assert_eq!(split[2], [0, 0, 0]);
        // the split sums back to the flat per-class ledger
        for class in LinkClass::ALL {
            let by_cloud: u64 =
                split.iter().map(|row| row[class.index()]).sum();
            assert_eq!(by_cloud, w.wire_bytes_class(class));
        }
        assert_eq!(LinkClass::parse("inter-region"), Some(LinkClass::InterRegion));
        assert_eq!(LinkClass::parse("x"), None);
    }

    #[test]
    #[should_panic]
    fn loopback_rejected() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 5);
        let _ = w.transfer(1, 1, 10, Protocol::Tcp, 1);
    }

    #[test]
    fn failed_egress_kills_wan_but_not_az_fabric() {
        // scaled(2): cloud 1 = {2, 3}, gateway 2
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 11);
        w.fail_node(2);
        assert!(w.node_down(2));
        // WAN leg through the dead gateway errors out...
        assert_eq!(w.route(3, 0), Err(NetError::NodeDown { node: 2 }));
        assert!(w.transfer(2, 0, 100, Protocol::Grpc, 1).is_err());
        // ...but the intra-AZ fabric still works
        assert_eq!(w.route(3, 2).unwrap(), vec![(3, 2)]);
        assert!(w.transfer(3, 2, 100, Protocol::Grpc, 1).is_ok());
        // restore brings the WAN back
        w.restore_node(2);
        assert!(!w.node_down(2));
        assert!(w.transfer(3, 0, 100, Protocol::Grpc, 1).is_ok());
    }

    #[test]
    fn reelection_rebuilds_the_mesh_and_drops_warmth() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 12);
        // warm the dying gateway's WAN link, then fail it over
        let cold = w.transfer(2, 0, 10_000, Protocol::Grpc, 1).unwrap();
        let inter_before = w.inter_region_bytes();
        let pair_before = w.wire_bytes(2, 0);
        assert!(inter_before >= 10_000);
        w.fail_node(2);
        w.reelect_gateway(1, 3);
        assert_eq!(w.gateway(1), 3);
        // bytes that crossed the torn-down mesh stay in the ledgers
        assert_eq!(w.inter_region_bytes(), inter_before);
        assert_eq!(w.wire_bytes(2, 0), pair_before);
        // the old mesh links are gone, the new gateway inherits the class
        assert_eq!(w.link_class(2, 0), None);
        assert_eq!(w.link_class(3, 0), Some(LinkClass::InterRegion));
        assert_eq!(w.link_class(3, 4), Some(LinkClass::InterRegion));
        // routes now transit the new gateway
        assert_eq!(w.route(2, 0).unwrap(), vec![(2, 3), (3, 0)]);
        // failover pays a cold handshake again
        let after = w.transfer(3, 0, 10_000, Protocol::Grpc, 1).unwrap();
        assert!((after.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn degrade_link_slows_transfers() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 13);
        w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap(); // warm up
        let before = w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap();
        w.degrade_link(0, 1, 0.01).unwrap();
        let after = w.transfer(0, 1, 1_000_000, Protocol::Grpc, 4).unwrap();
        assert!(after.time_s > before.time_s * 5.0);
        assert!(w.degrade_link(0, 0, 0.5).is_err()); // no such link
    }

    #[test]
    fn scoped_transfers_overlay_then_merge_exactly() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 31);
        let mut rng = Pcg64::new(31, 0xC0FFEE);
        let mut scratch = WanScratch::default();
        // member 3 -> gateway 2 of cloud 1, twice: wire bytes must match
        // the mutating path (jitter noise only affects times) and the
        // second transfer must see the scratch-established warmth
        let a = w
            .transfer_scoped(3, 2, 50_000, Protocol::Grpc, 4, &mut rng, &mut scratch)
            .unwrap();
        let b = w
            .transfer_scoped(3, 2, 50_000, Protocol::Grpc, 4, &mut rng, &mut scratch)
            .unwrap();
        assert!(b.handshake_s < a.handshake_s);
        // nothing landed on the shared state yet
        assert_eq!(w.total_wire_bytes(), 0);
        w.apply_scratch(&scratch);
        assert_eq!(w.wire_bytes(3, 2), a.wire_bytes + b.wire_bytes);
        assert_eq!(w.total_wire_bytes(), a.wire_bytes + b.wire_bytes);
        let split = w.wire_bytes_by_cloud_class();
        assert_eq!(split[1][LinkClass::IntraAz.index()], a.wire_bytes + b.wire_bytes);
        // applied warmth carries over to the mutating path
        let c2 = w.transfer(3, 2, 50_000, Protocol::Grpc, 4).unwrap();
        assert!(c2.handshake_s < a.handshake_s);
        // wire bytes are rng-independent: a mutating transfer on a fresh
        // topology produces the same byte count as the scoped one
        let mut w2 = Wan::from_cluster(&c, 99);
        let direct = w2.transfer(3, 2, 50_000, Protocol::Grpc, 4).unwrap();
        assert_eq!(direct.wire_bytes, a.wire_bytes);
    }
}

//! Routed WAN topology between cloud worker nodes + the leader.
//!
//! Nodes 0..n-1 are the cluster's worker nodes; the aggregation leader is
//! co-located with node 0 (the paper's setup has the global model hosted
//! on one of the clouds). Links are asymmetric-capable (directed) and
//! carry a [`LinkClass`]:
//!
//! * [`LinkClass::IntraAz`] — nodes inside the same cloud (AZ-level
//!   peers): fat, sub-millisecond.
//! * [`LinkClass::IntraRegion`] — gateways of different clouds in the
//!   same region: quick cross-AZ class links.
//! * [`LinkClass::InterRegion`] — gateways across regions: the paper's
//!   WAN bottleneck.
//!
//! Only the *gateway* node of each cloud (its first member) has links to
//! other clouds; a transfer between two arbitrary workers is routed
//! `src → gw(src) → gw(dst) → dst` (degenerate hops skipped) and priced
//! per hop, store-and-forward. The per-link byte ledger therefore tells
//! exactly how many bytes crossed each class of link — the measurement
//! behind the hierarchical-vs-star comparison.

use std::collections::HashMap;

use crate::cluster::ClusterSpec;
use crate::netsim::link::{Link, TransferStats};
use crate::netsim::protocol::Protocol;
use crate::util::rng::Pcg64;

/// RNG stream id for network noise (distinct from data/DP streams).
const WAN_STREAM: u64 = 0x57414e;

/// What kind of path segment a link is (for per-class byte accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// same cloud, different AZ-level node
    IntraAz,
    /// different clouds, same region (gateway-to-gateway)
    IntraRegion,
    /// different regions (gateway-to-gateway) — the WAN bottleneck
    InterRegion,
}

/// Directed routed WAN with connection-warmth tracking and per-link
/// byte accounting.
#[derive(Clone, Debug)]
pub struct Wan {
    n: usize,
    /// links[(src, dst)]
    links: HashMap<(usize, usize), Link>,
    /// link class per (src, dst) — parallel to `links`
    classes: HashMap<(usize, usize), LinkClass>,
    /// owning cloud per node (identity for flat meshes)
    cloud_of: Vec<usize>,
    /// gateway node per cloud
    gateways: Vec<usize>,
    /// protocol connections already established (src, dst, proto)
    warm: HashMap<(usize, usize, Protocol), bool>,
    /// cumulative wire bytes per (src, dst)
    ledger: HashMap<(usize, usize), u64>,
    rng: Pcg64,
}

impl Wan {
    /// Uniform mesh: every pair gets the same link spec (class
    /// [`LinkClass::InterRegion`]); every node is its own cloud, so all
    /// routes are single-hop.
    pub fn uniform(n: usize, link: Link, seed: u64) -> Wan {
        let mut links = HashMap::new();
        let mut classes = HashMap::new();
        for s in 0..n {
            for d in 0..n {
                if s != d {
                    links.insert((s, d), link.clone());
                    classes.insert((s, d), LinkClass::InterRegion);
                }
            }
        }
        Wan {
            n,
            links,
            classes,
            cloud_of: (0..n).collect(),
            gateways: (0..n).collect(),
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    /// Link presets per class (bandwidth bps, rtt s, jitter, loss).
    fn class_link(class: LinkClass) -> Link {
        match class {
            // same cloud, AZ-to-AZ: very fat and near-instant
            LinkClass::IntraAz => Link {
                bandwidth_bps: 25e9,
                rtt_s: 0.0005,
                jitter: 0.01,
                loss_rate: 0.00001,
            },
            // same region, cross-cloud: fat and quick
            LinkClass::IntraRegion => Link {
                bandwidth_bps: 5e9,
                rtt_s: 0.002,
                jitter: 0.03,
                loss_rate: 0.0001,
            },
            // inter-region WAN: the paper's bottleneck
            LinkClass::InterRegion => Link {
                bandwidth_bps: 1e9,
                rtt_s: 0.080,
                jitter: 0.08,
                loss_rate: 0.002,
            },
        }
    }

    /// Routed topology shaped by the cluster's clouds and regions:
    /// full intra-cloud mesh per cloud, plus a gateway-to-gateway mesh
    /// between clouds (intra- or inter-region per the cloud regions).
    /// With single-node clouds this degenerates to the flat star/mesh of
    /// the paper's 3-platform setup.
    pub fn from_cluster(cluster: &ClusterSpec, seed: u64) -> Wan {
        let n = cluster.n();
        let cloud_of: Vec<usize> = (0..n).map(|i| cluster.cloud_of(i)).collect();
        let n_clouds = cluster.n_clouds();
        let gateways: Vec<usize> = (0..n_clouds).map(|c| cluster.gateway(c)).collect();

        let mut links = HashMap::new();
        let mut classes = HashMap::new();
        let mut add = |s: usize, d: usize, class: LinkClass| {
            links.insert((s, d), Wan::class_link(class));
            classes.insert((s, d), class);
        };

        // intra-cloud mesh
        for s in 0..n {
            for d in 0..n {
                if s != d && cloud_of[s] == cloud_of[d] {
                    add(s, d, LinkClass::IntraAz);
                }
            }
        }
        // gateway-to-gateway mesh between clouds
        for a in 0..n_clouds {
            for b in 0..n_clouds {
                if a == b {
                    continue;
                }
                let (ga, gb) = (gateways[a], gateways[b]);
                let same_region = cluster.platforms[ga].region
                    == cluster.platforms[gb].region;
                let class = if same_region {
                    LinkClass::IntraRegion
                } else {
                    LinkClass::InterRegion
                };
                add(ga, gb, class);
            }
        }

        Wan {
            n,
            links,
            classes,
            cloud_of,
            gateways,
            warm: HashMap::new(),
            ledger: HashMap::new(),
            rng: Pcg64::new(seed, WAN_STREAM),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Mutable access for ablations (e.g. degrade one link mid-run).
    pub fn link_mut(&mut self, src: usize, dst: usize) -> Option<&mut Link> {
        self.links.get_mut(&(src, dst))
    }

    pub fn link(&self, src: usize, dst: usize) -> Option<&Link> {
        self.links.get(&(src, dst))
    }

    /// Class of the direct link (src, dst), if one exists.
    pub fn link_class(&self, src: usize, dst: usize) -> Option<LinkClass> {
        self.classes.get(&(src, dst)).copied()
    }

    /// The hop sequence a transfer src→dst takes: the direct link when
    /// one exists, otherwise via the clouds' gateways (degenerate hops
    /// skipped). Every returned hop has a link.
    pub fn route(&self, src: usize, dst: usize) -> Vec<(usize, usize)> {
        assert!(src != dst, "loopback transfers are free; don't route them");
        if self.links.contains_key(&(src, dst)) {
            return vec![(src, dst)];
        }
        let gs = self.gateways[self.cloud_of[src]];
        let gd = self.gateways[self.cloud_of[dst]];
        let mut hops = Vec::with_capacity(3);
        if src != gs {
            hops.push((src, gs));
        }
        if gs != gd {
            hops.push((gs, gd));
        }
        if gd != dst {
            hops.push((gd, dst));
        }
        hops
    }

    /// Simulate a transfer along the route src→dst (store-and-forward per
    /// hop); updates warmth and the byte ledger per traversed link.
    /// Returns combined stats: times and bytes summed over hops.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> TransferStats {
        assert!(src != dst, "loopback transfers are free; don't simulate them");
        let hops = self.route(src, dst);
        let mut total = TransferStats { time_s: 0.0, wire_bytes: 0, handshake_s: 0.0 };
        for (s, d) in hops {
            let st = self.transfer_hop(s, d, payload_bytes, protocol, streams);
            total.time_s += st.time_s;
            total.wire_bytes += st.wire_bytes;
            total.handshake_s += st.handshake_s;
        }
        total
    }

    /// One direct-link hop (the pre-routing `transfer` semantics).
    fn transfer_hop(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        protocol: Protocol,
        streams: usize,
    ) -> TransferStats {
        let link = self.links.get(&(src, dst)).expect("missing link").clone();
        let warm = *self.warm.get(&(src, dst, protocol)).unwrap_or(&false);
        let stats =
            link.transfer(payload_bytes, protocol, warm, streams, &mut self.rng);
        self.warm.insert((src, dst, protocol), true);
        *self.ledger.entry((src, dst)).or_insert(0) += stats.wire_bytes;
        stats
    }

    /// Drop all warm connections (e.g. after a simulated failure).
    pub fn reset_connections(&mut self) {
        self.warm.clear();
    }

    /// Total bytes that crossed any link.
    pub fn total_wire_bytes(&self) -> u64 {
        self.ledger.values().sum()
    }

    /// Bytes sent from `src` to `dst` so far (direct link only).
    pub fn wire_bytes(&self, src: usize, dst: usize) -> u64 {
        *self.ledger.get(&(src, dst)).unwrap_or(&0)
    }

    /// Total bytes that crossed links of `class` — e.g. how much update
    /// traffic actually paid the inter-region WAN.
    pub fn wire_bytes_class(&self, class: LinkClass) -> u64 {
        self.ledger
            .iter()
            .filter(|(k, _)| self.classes.get(k) == Some(&class))
            .map(|(_, v)| v)
            .sum()
    }

    /// Convenience: bytes over [`LinkClass::InterRegion`] links.
    pub fn inter_region_bytes(&self) -> u64 {
        self.wire_bytes_class(LinkClass::InterRegion)
    }

    /// Zero the ledger (per-round accounting).
    pub fn reset_ledger(&mut self) {
        self.ledger.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mesh_has_all_pairs() {
        let w = Wan::uniform(3, Link::new(1e9, 0.04), 1);
        for s in 0..3 {
            for d in 0..3 {
                assert_eq!(w.link(s, d).is_some(), s != d);
            }
        }
    }

    #[test]
    fn ledger_accumulates() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 2);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1);
        w.transfer(0, 1, 1000, Protocol::Grpc, 1);
        w.transfer(1, 0, 500, Protocol::Grpc, 1);
        assert!(w.wire_bytes(0, 1) >= 2000);
        assert!(w.wire_bytes(1, 0) >= 500);
        assert_eq!(w.total_wire_bytes(),
                   w.wire_bytes(0, 1) + w.wire_bytes(1, 0));
        w.reset_ledger();
        assert_eq!(w.total_wire_bytes(), 0);
    }

    #[test]
    fn second_transfer_is_warm() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.05), 3);
        let cold = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        let warm = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        assert!(warm.handshake_s < cold.handshake_s);
        w.reset_connections();
        let cold2 = w.transfer(0, 1, 10_000, Protocol::Grpc, 1);
        assert!((cold2.handshake_s - cold.handshake_s).abs() < 1e-9);
    }

    #[test]
    fn cluster_wan_penalizes_cross_region() {
        let c = crate::cluster::ClusterSpec::paper_default();
        let mut w = Wan::from_cluster(&c, 4);
        // aws(us-east) -> gcp(us-central) is cross-region in this preset
        let t_us = w.transfer(0, 1, 10_000_000, Protocol::Grpc, 8);
        // azure is eu-west: same class of link, so just check both are sane
        let t_eu = w.transfer(0, 2, 10_000_000, Protocol::Grpc, 8);
        assert!(t_us.time_s > 0.0 && t_eu.time_s > 0.0);
        // all paper-default pairs are gateway-to-gateway across regions
        assert_eq!(w.link_class(0, 1), Some(LinkClass::InterRegion));
        assert_eq!(w.inter_region_bytes(), w.total_wire_bytes());
    }

    #[test]
    fn scaled_cluster_routes_via_gateways() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(4);
        let w = Wan::from_cluster(&c, 7);
        // same cloud: direct intra-AZ link
        assert_eq!(w.route(1, 3), vec![(1, 3)]);
        assert_eq!(w.link_class(1, 3), Some(LinkClass::IntraAz));
        // worker 5 (cloud 1, gw 4) -> leader node 0 (cloud 0, gw 0)
        assert_eq!(w.route(5, 0), vec![(5, 4), (4, 0)]);
        assert_eq!(w.link_class(4, 0), Some(LinkClass::InterRegion));
        // worker to worker across clouds: three hops
        assert_eq!(w.route(5, 9), vec![(5, 4), (4, 8), (8, 9)]);
        // gateways talk directly
        assert_eq!(w.route(4, 8), vec![(4, 8)]);
    }

    #[test]
    fn multi_hop_transfer_ledgers_every_link() {
        let c = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let mut w = Wan::from_cluster(&c, 9);
        // node 3 (cloud 1, gw 2) -> node 0: hops (3,2) intra + (2,0) inter
        let st = w.transfer(3, 0, 1_000_000, Protocol::Grpc, 8);
        assert!(w.wire_bytes(3, 2) >= 1_000_000);
        assert!(w.wire_bytes(2, 0) >= 1_000_000);
        assert_eq!(
            st.wire_bytes,
            w.wire_bytes(3, 2) + w.wire_bytes(2, 0)
        );
        // per-class split: exactly one inter-region crossing
        assert_eq!(w.inter_region_bytes(), w.wire_bytes(2, 0));
        assert_eq!(
            w.wire_bytes_class(LinkClass::IntraAz),
            w.wire_bytes(3, 2)
        );
        // the inter-region hop dominates the time
        let intra_only = {
            let mut w2 = Wan::from_cluster(&c, 9);
            w2.transfer(3, 2, 1_000_000, Protocol::Grpc, 8)
        };
        assert!(st.time_s > intra_only.time_s);
    }

    #[test]
    #[should_panic]
    fn loopback_rejected() {
        let mut w = Wan::uniform(2, Link::new(1e9, 0.01), 5);
        w.transfer(1, 1, 10, Protocol::Tcp, 1);
    }
}

//! Deterministic fault injection for the simulated cross-cloud fabric.
//!
//! A [`FaultPlan`] is a list of timed [`FaultEvent`]s the schedulers
//! replay at round boundaries (async: pseudo-round boundaries) on the
//! shared event engine's clock. Every event is specified — or generated
//! from a seed — ahead of the run, so a faulty run is exactly as
//! reproducible as a clean one: same seed + same plan ⇒ bit-identical
//! histories, which `tests/determinism.rs` pins across thread counts.
//!
//! The taxonomy mirrors what actually breaks in cross-cloud training:
//!
//! * [`FaultEvent::GatewayDown`] — a cloud's WAN egress (the gateway
//!   role hosted on its gateway node) fails. Intra-AZ fabric survives;
//!   the cloud must re-elect a standby gateway to keep talking across
//!   regions (see `Wan::fail_node` / `ClusterSpec::reelect_gateway`).
//! * [`FaultEvent::GatewayRestore`] — a previously killed gateway's WAN
//!   egress comes back (transient outage). The cloud *fails back*: the
//!   restored node outranks the standby under the lowest-id election
//!   rule, so the gateway role returns to it at the round boundary.
//! * [`FaultEvent::LinkDegrade`] — a directed link loses bandwidth
//!   (`factor` multiplies `bandwidth_bps`; `0.1` = 10× slower).
//! * [`FaultEvent::NodeSlowdown`] — a worker node's compute degrades
//!   (`factor` divides `compute_speed`; `2.0` = twice as slow), the
//!   persistent-straggler counterpart of the transient straggler model.
//! * [`FaultEvent::CoordinatorCrash`] — the coordinator process itself
//!   dies at the start of round `at`. The run aborts with a typed
//!   [`crate::coordinator::CoordinatorCrashed`] error; the harness drops
//!   the coordinator and resumes from the write-ahead log
//!   (`Coordinator::resume`), so recovery is a simulated, replayable,
//!   priced scenario like any other fault. Requires `wal_dir` to be set
//!   and `at >= 1` (a crash before round 0 leaves an empty log).
//! * [`FaultEvent::WorkerLeave`] — `node` drops out of the training
//!   roster (spot preemption, scale-down). Its shard is re-planned over
//!   the survivors, secure aggregation re-keys over the new roster, and
//!   if it held the gateway role the cloud re-elects.
//! * [`FaultEvent::WorkerJoin`] — a previously departed `node` re-joins
//!   the roster (spot capacity restored); the mirror image of
//!   `WorkerLeave`.
//!
//! Spec grammar (CLI `--fault`, config JSON `"faults": [...]`, events
//! separated by `;`):
//!
//! ```text
//! gateway-down:cloud=1,at=round3
//! restore:cloud=1,at=round5
//! link-degrade:src=0,dst=4,at=2,factor=0.25
//! node-slowdown:node=5,at=round4,factor=2
//! coordinator-crash:at=round4
//! worker-leave:node=4,at=round2
//! worker-join:node=4,at=round6
//! ```

use std::fmt;

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::util::rng::Pcg64;

/// RNG stream id for seed-generated chaos plans.
const FAULT_STREAM: u64 = 0xFA117;

/// RNG stream id for seed-generated spot-preemption plans.
const SPOT_STREAM: u64 = 0x5907;

/// One timed fault. `at` is the aggregation round (0-based) at whose
/// start the fault strikes; in async mode, the pseudo-round boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// The WAN egress of `cloud`'s current gateway node fails.
    GatewayDown { cloud: usize, at: usize },
    /// The earliest-failed egress in `cloud` comes back; the gateway
    /// role fails back to the restored node (transient-outage recovery).
    GatewayRestore { cloud: usize, at: usize },
    /// Directed link `src → dst` keeps only `factor` of its bandwidth.
    LinkDegrade { src: usize, dst: usize, at: usize, factor: f64 },
    /// `node` computes `factor`× slower from round `at` on.
    NodeSlowdown { node: usize, at: usize, factor: f64 },
    /// The coordinator dies at the start of round `at`, before any other
    /// fault due that round is applied (so resume replays them exactly
    /// once). Recovery goes through the write-ahead log.
    CoordinatorCrash { at: usize },
    /// `node` leaves the training roster at the start of round `at`
    /// (spot preemption / elastic scale-down).
    WorkerLeave { node: usize, at: usize },
    /// A previously departed `node` re-joins the roster at the start of
    /// round `at`.
    WorkerJoin { node: usize, at: usize },
}

impl FaultEvent {
    /// Round at whose start this event fires.
    pub fn at(&self) -> usize {
        match *self {
            FaultEvent::GatewayDown { at, .. }
            | FaultEvent::GatewayRestore { at, .. }
            | FaultEvent::LinkDegrade { at, .. }
            | FaultEvent::NodeSlowdown { at, .. }
            | FaultEvent::CoordinatorCrash { at }
            | FaultEvent::WorkerLeave { at, .. }
            | FaultEvent::WorkerJoin { at, .. } => at,
        }
    }

    /// Parse one `kind:key=value,...` spec (see module docs for the
    /// grammar). Unknown kinds/keys and missing keys are hard errors so
    /// typos cannot silently drop a fault from an experiment.
    pub fn parse(spec: &str) -> Result<FaultEvent> {
        let spec = spec.trim();
        let (kind, rest) = spec
            .split_once(':')
            .with_context(|| format!("fault spec {spec:?}: expected kind:key=value,..."))?;
        let kind = kind.trim();
        // per-kind key sets: a key another kind would accept is still a
        // typo here (e.g. factor= on gateway-down) and must not be
        // silently dropped
        let allowed: &[&str] = match kind {
            "gateway-down" | "restore" => &["cloud", "at"],
            "link-degrade" => &["src", "dst", "at", "factor"],
            "node-slowdown" => &["node", "at", "factor"],
            "coordinator-crash" => &["at"],
            "worker-leave" | "worker-join" => &["node", "at"],
            other => bail!(
                "fault spec {spec:?}: unknown kind {other:?} \
                 (expected gateway-down | restore | link-degrade | \
                 node-slowdown | coordinator-crash | worker-leave | \
                 worker-join)"
            ),
        };
        let mut cloud = None;
        let mut src = None;
        let mut dst = None;
        let mut node = None;
        let mut at = None;
        let mut factor = None;
        for pair in rest.split(',') {
            let (k, v) = pair
                .split_once('=')
                .with_context(|| format!("fault spec {spec:?}: bad pair {pair:?}"))?;
            let (k, v) = (k.trim(), v.trim());
            if !allowed.contains(&k) {
                bail!(
                    "fault spec {spec:?}: key {k:?} is not valid for \
                     {kind} (allowed: {allowed:?})"
                );
            }
            match k {
                "cloud" => set_once(spec, k, &mut cloud, parse_usize(spec, k, v)?)?,
                "src" => set_once(spec, k, &mut src, parse_usize(spec, k, v)?)?,
                "dst" => set_once(spec, k, &mut dst, parse_usize(spec, k, v)?)?,
                "node" => set_once(spec, k, &mut node, parse_usize(spec, k, v)?)?,
                // `at=round3` and `at=3` are both accepted
                "at" => set_once(
                    spec,
                    k,
                    &mut at,
                    parse_usize(spec, k, v.trim_start_matches("round"))?,
                )?,
                "factor" => set_once(
                    spec,
                    k,
                    &mut factor,
                    v.parse::<f64>().with_context(|| {
                        format!("fault spec {spec:?}: bad factor {v:?}")
                    })?,
                )?,
                _ => unreachable!("key checked against the allowed set"),
            }
        }
        let req = |name: &str, v: Option<usize>| {
            v.with_context(|| format!("fault spec {spec:?}: missing {name}="))
        };
        let ev = match kind {
            "gateway-down" => FaultEvent::GatewayDown {
                cloud: req("cloud", cloud)?,
                at: req("at", at)?,
            },
            "restore" => FaultEvent::GatewayRestore {
                cloud: req("cloud", cloud)?,
                at: req("at", at)?,
            },
            "link-degrade" => FaultEvent::LinkDegrade {
                src: req("src", src)?,
                dst: req("dst", dst)?,
                at: req("at", at)?,
                factor: factor
                    .with_context(|| format!("fault spec {spec:?}: missing factor="))?,
            },
            "node-slowdown" => FaultEvent::NodeSlowdown {
                node: req("node", node)?,
                at: req("at", at)?,
                factor: factor
                    .with_context(|| format!("fault spec {spec:?}: missing factor="))?,
            },
            "coordinator-crash" => {
                FaultEvent::CoordinatorCrash { at: req("at", at)? }
            }
            "worker-leave" => FaultEvent::WorkerLeave {
                node: req("node", node)?,
                at: req("at", at)?,
            },
            "worker-join" => FaultEvent::WorkerJoin {
                node: req("node", node)?,
                at: req("at", at)?,
            },
            _ => unreachable!("kind checked above"),
        };
        ev.validate()?;
        Ok(ev)
    }

    /// Structural sanity (cluster-independent; the coordinator checks
    /// node/cloud ids against its cluster at build time).
    pub fn validate(&self) -> Result<()> {
        match *self {
            FaultEvent::LinkDegrade { src, dst, factor, .. } => {
                if src == dst {
                    bail!("link-degrade: src == dst ({src})");
                }
                if !(factor > 0.0 && factor.is_finite()) {
                    bail!("link-degrade: factor must be finite and > 0, got {factor}");
                }
            }
            FaultEvent::NodeSlowdown { factor, .. } => {
                if !(factor >= 1.0 && factor.is_finite()) {
                    bail!("node-slowdown: factor must be finite and >= 1, got {factor}");
                }
            }
            FaultEvent::CoordinatorCrash { at } => {
                if at == 0 {
                    bail!(
                        "coordinator-crash: at must be >= 1 (a crash before \
                         round 0 leaves an empty WAL with nothing to resume)"
                    );
                }
            }
            FaultEvent::GatewayDown { .. }
            | FaultEvent::GatewayRestore { .. }
            | FaultEvent::WorkerLeave { .. }
            | FaultEvent::WorkerJoin { .. } => {}
        }
        Ok(())
    }
}

impl fmt::Display for FaultEvent {
    /// The canonical spec string (round-trips through [`FaultEvent::parse`]).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            FaultEvent::GatewayDown { cloud, at } => {
                write!(f, "gateway-down:cloud={cloud},at={at}")
            }
            FaultEvent::GatewayRestore { cloud, at } => {
                write!(f, "restore:cloud={cloud},at={at}")
            }
            FaultEvent::LinkDegrade { src, dst, at, factor } => {
                write!(f, "link-degrade:src={src},dst={dst},at={at},factor={factor}")
            }
            FaultEvent::NodeSlowdown { node, at, factor } => {
                write!(f, "node-slowdown:node={node},at={at},factor={factor}")
            }
            FaultEvent::CoordinatorCrash { at } => {
                write!(f, "coordinator-crash:at={at}")
            }
            FaultEvent::WorkerLeave { node, at } => {
                write!(f, "worker-leave:node={node},at={at}")
            }
            FaultEvent::WorkerJoin { node, at } => {
                write!(f, "worker-join:node={node},at={at}")
            }
        }
    }
}

fn parse_usize(spec: &str, key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>()
        .with_context(|| format!("fault spec {spec:?}: bad {key} {v:?}"))
}

/// A duplicated key is a typo for some other key — silently keeping the
/// last value would run a different fault than written.
fn set_once<T>(spec: &str, key: &str, slot: &mut Option<T>, val: T) -> Result<()> {
    if slot.is_some() {
        bail!("fault spec {spec:?}: duplicate key {key:?}");
    }
    *slot = Some(val);
    Ok(())
}

/// An ordered fault schedule (stable-sorted by round, so same-round
/// events apply in the order they were written).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(FaultEvent::at);
        FaultPlan { events }
    }

    /// Parse a `;`-separated list of event specs (empty input ⇒ empty plan).
    pub fn parse(specs: &str) -> Result<FaultPlan> {
        let events = specs
            .split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(FaultEvent::parse)
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan::new(events))
    }

    /// A reproducible chaos schedule: `n_events` faults drawn from the
    /// taxonomy, uniformly over `rounds`, shaped by `cluster`. Gateway
    /// kills only target clouds with a standby member, and degraded
    /// links are ones guaranteed to exist for the whole run (intra-cloud
    /// mesh links, which no re-election ever moves; gateway-mesh links
    /// only when every cloud is single-node, i.e. no re-election can
    /// happen). Same seed + cluster ⇒ same plan.
    pub fn random(seed: u64, n_events: usize, rounds: usize, cluster: &ClusterSpec) -> FaultPlan {
        let mut rng = Pcg64::new(seed, FAULT_STREAM);
        let n = cluster.n();
        let survivable: Vec<usize> = (0..cluster.n_clouds())
            .filter(|&c| cluster.cloud_members(c).len() >= 2)
            .collect();
        let mut killed = vec![false; cluster.n_clouds()];
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let at = rng.below_usize(rounds.max(1));
            let kind = rng.below(3);
            let ev = if kind == 0 && !survivable.is_empty() {
                let cloud = survivable[rng.below_usize(survivable.len())];
                if killed[cloud] {
                    // one egress failure per cloud: keep a standby alive
                    FaultEvent::NodeSlowdown {
                        node: rng.below_usize(n),
                        at,
                        factor: 1.5 + rng.uniform() * 2.5,
                    }
                } else {
                    killed[cloud] = true;
                    FaultEvent::GatewayDown { cloud, at }
                }
            } else if kind == 1 && !survivable.is_empty() {
                // a link inside a multi-node cloud: the full intra-cloud
                // mesh exists and never moves under re-election
                let cloud = survivable[rng.below_usize(survivable.len())];
                let members = cluster.cloud_members(cloud);
                let a = rng.below_usize(members.len());
                let b = (a + 1 + rng.below_usize(members.len() - 1)) % members.len();
                FaultEvent::LinkDegrade {
                    src: members[a],
                    dst: members[b],
                    at,
                    factor: 0.1 + rng.uniform() * 0.8,
                }
            } else if kind == 1 && n >= 2 {
                // flat cluster (all clouds single-node): the static
                // gateway mesh links every pair
                let src = rng.below_usize(n);
                let dst = (src + 1 + rng.below_usize(n - 1)) % n;
                FaultEvent::LinkDegrade {
                    src,
                    dst,
                    at,
                    factor: 0.1 + rng.uniform() * 0.8,
                }
            } else {
                FaultEvent::NodeSlowdown {
                    node: rng.below_usize(n),
                    at,
                    factor: 1.5 + rng.uniform() * 2.5,
                }
            };
            events.push(ev);
        }
        FaultPlan::new(events)
    }

    /// A reproducible spot-market interruption schedule: every round,
    /// each active node is preempted (`worker-leave:`) with probability
    /// `p_preempt`, and a preempted node's capacity comes back
    /// (`worker-join:`) `recovery_rounds` later — the "10%/hour
    /// preemption" scenario from the paper's cost analysis, with a round
    /// standing in for the billing hour. The generator tracks the roster
    /// it is building and never preempts a cloud down to zero active
    /// members; each cloud's first member is its on-demand anchor node
    /// and is never preempted at all (real spot fleets keep one
    /// on-demand instance per zone, and the coordinator refuses plans
    /// that preempt the leader — which placement always puts on an
    /// anchor). Every plan it emits is survivable by construction.
    /// Same seed + cluster ⇒ same plan.
    pub fn spot_preemptions(
        seed: u64,
        rounds: usize,
        cluster: &ClusterSpec,
        p_preempt: f64,
        recovery_rounds: usize,
    ) -> FaultPlan {
        assert!((0.0..=1.0).contains(&p_preempt), "p_preempt must be in [0, 1]");
        assert!(recovery_rounds >= 1, "recovery must take at least one round");
        let mut rng = Pcg64::new(seed, SPOT_STREAM);
        let n = cluster.n();
        let mut active = vec![true; n];
        // joins scheduled per round (round -> nodes coming back)
        let mut rejoin_at = vec![Vec::new(); rounds];
        let mut events = Vec::new();
        for r in 1..rounds {
            for &node in &rejoin_at[r] {
                active[node] = true;
                events.push(FaultEvent::WorkerJoin { node, at: r });
            }
            for node in 0..n {
                if !active[node] {
                    continue;
                }
                let cloud = cluster.cloud_of(node);
                let survivors = cluster
                    .cloud_members(cloud)
                    .into_iter()
                    .filter(|&m| active[m])
                    .count();
                // draw unconditionally so the stream does not depend on
                // which nodes happen to be sole survivors or anchors
                let hit = rng.uniform() < p_preempt;
                let anchor = cluster.cloud_members(cloud)[0];
                if hit && survivors >= 2 && node != anchor {
                    active[node] = false;
                    events.push(FaultEvent::WorkerLeave { node, at: r });
                    let back = r + recovery_rounds;
                    if back < rounds {
                        rejoin_at[back].push(node);
                    }
                }
            }
        }
        FaultPlan::new(events)
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Events that strike at the start of `round`.
    pub fn due(&self, round: usize) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.at() == round)
    }

    /// Drop coordinator-crash events striking at or before `round` (WAL
    /// resume: the crash that stopped the run must not fire again; every
    /// other past fault's *effect* is restored from the log).
    pub fn strip_crashes_through(&mut self, round: usize) {
        self.events.retain(|e| {
            !matches!(e, FaultEvent::CoordinatorCrash { at } if *at <= round)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        assert_eq!(
            FaultEvent::parse("gateway-down:cloud=1,at=round3").unwrap(),
            FaultEvent::GatewayDown { cloud: 1, at: 3 }
        );
        assert_eq!(
            FaultEvent::parse("link-degrade:src=0,dst=4,at=2,factor=0.25").unwrap(),
            FaultEvent::LinkDegrade { src: 0, dst: 4, at: 2, factor: 0.25 }
        );
        assert_eq!(
            FaultEvent::parse(" node-slowdown:node=5, at=round4, factor=2 ").unwrap(),
            FaultEvent::NodeSlowdown { node: 5, at: 4, factor: 2.0 }
        );
        assert_eq!(
            FaultEvent::parse("restore:cloud=1,at=round5").unwrap(),
            FaultEvent::GatewayRestore { cloud: 1, at: 5 }
        );
        assert_eq!(
            FaultEvent::parse("coordinator-crash:at=round4").unwrap(),
            FaultEvent::CoordinatorCrash { at: 4 }
        );
        assert_eq!(
            FaultEvent::parse("worker-leave:node=4,at=round2").unwrap(),
            FaultEvent::WorkerLeave { node: 4, at: 2 }
        );
        assert_eq!(
            FaultEvent::parse("worker-join:node=4,at=6").unwrap(),
            FaultEvent::WorkerJoin { node: 4, at: 6 }
        );
    }

    #[test]
    fn display_round_trips() {
        for spec in [
            "gateway-down:cloud=2,at=7",
            "restore:cloud=2,at=9",
            "link-degrade:src=1,dst=0,at=0,factor=0.5",
            "node-slowdown:node=3,at=9,factor=3",
            "coordinator-crash:at=2",
            "worker-leave:node=1,at=4",
            "worker-join:node=1,at=8",
        ] {
            let ev = FaultEvent::parse(spec).unwrap();
            assert_eq!(FaultEvent::parse(&ev.to_string()).unwrap(), ev);
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "gateway-down",                                // no args
            "gateway-down:cloud=1",                        // missing at
            "gateway-down:cloud=x,at=1",                   // bad number
            "gateway-down:cloud=1,at=1,zone=7",            // unknown key
            "gateway-down:cloud=1,at=1,factor=0.5",        // key of another kind
            "node-slowdown:node=1,at=2,factor=2,cloud=1",  // key of another kind
            "node-slowdown:node=1,at=2,at=5,factor=2",     // duplicate key
            "meteor-strike:at=1",                          // unknown kind
            "restore:cloud=1",                             // missing at
            "restore:cloud=1,at=2,factor=0.5",             // key of another kind
            "link-degrade:src=0,dst=1,at=1",               // missing factor
            "link-degrade:src=2,dst=2,at=1,factor=0.5",    // src == dst
            "link-degrade:src=0,dst=1,at=1,factor=0",      // zero factor
            "node-slowdown:node=0,at=1,factor=0.5",        // speedup
            "coordinator-crash:at=0",                      // empty-WAL crash
            "coordinator-crash:at=1,cloud=0",              // key of another kind
            "coordinator-crash:cloud=1",                   // missing at
            "worker-leave:at=1",                           // missing node
            "worker-leave:node=1,at=1,factor=2",           // key of another kind
            "worker-join:node=1",                          // missing at
            "worker-join:cloud=1,at=2",                    // key of another kind
        ] {
            assert!(FaultEvent::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn plan_parses_lists_and_sorts_by_round() {
        let p = FaultPlan::parse(
            "node-slowdown:node=1,at=5,factor=2; gateway-down:cloud=0,at=2",
        )
        .unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.events()[0], FaultEvent::GatewayDown { cloud: 0, at: 2 });
        assert_eq!(p.due(5).count(), 1);
        assert_eq!(p.due(3).count(), 0);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ").unwrap().is_empty());
    }

    #[test]
    fn strip_crashes_removes_only_fired_crashes() {
        let mut p = FaultPlan::parse(
            "coordinator-crash:at=2; node-slowdown:node=0,at=2,factor=2; \
             coordinator-crash:at=6",
        )
        .unwrap();
        p.strip_crashes_through(2);
        assert_eq!(p.len(), 2);
        assert_eq!(p.due(2).count(), 1); // the slowdown survives
        assert_eq!(
            p.events()[1],
            FaultEvent::CoordinatorCrash { at: 6 } // a later crash survives
        );
    }

    #[test]
    fn random_plan_is_deterministic_and_survivable() {
        let cluster = crate::cluster::ClusterSpec::paper_default_scaled(4);
        let a = FaultPlan::random(7, 12, 10, &cluster);
        let b = FaultPlan::random(7, 12, 10, &cluster);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        // at most one gateway kill per cloud, every event validates
        let mut kills = vec![0usize; cluster.n_clouds()];
        for ev in a.events() {
            ev.validate().unwrap();
            assert!(ev.at() < 10);
            if let FaultEvent::GatewayDown { cloud, .. } = *ev {
                kills[cloud] += 1;
            }
        }
        assert!(kills.iter().all(|&k| k <= 1));
        let c = FaultPlan::random(8, 12, 10, &cluster);
        assert_ne!(a, c);
    }

    #[test]
    fn spot_plan_is_deterministic_and_survivable() {
        let cluster = crate::cluster::ClusterSpec::paper_default_scaled(3);
        let a = FaultPlan::spot_preemptions(11, 20, &cluster, 0.2, 3);
        let b = FaultPlan::spot_preemptions(11, 20, &cluster, 0.2, 3);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "20 rounds at 20%/round must preempt someone");
        assert_ne!(a, FaultPlan::spot_preemptions(12, 20, &cluster, 0.2, 3));
        // replay the plan: the roster invariant (>= 1 active member per
        // cloud) must hold at every round, and joins must only re-add
        // nodes that left
        let mut active = vec![true; cluster.n()];
        for r in 0..20 {
            for ev in a.due(r) {
                match *ev {
                    FaultEvent::WorkerLeave { node, at } => {
                        assert_eq!(at, r);
                        assert!(active[node], "leave of an inactive node");
                        let cloud = cluster.cloud_of(node);
                        assert_ne!(
                            node,
                            cluster.cloud_members(cloud)[0],
                            "preempted an on-demand anchor node"
                        );
                        active[node] = false;
                    }
                    FaultEvent::WorkerJoin { node, .. } => {
                        assert!(!active[node], "join of an active node");
                        active[node] = true;
                    }
                    ref other => panic!("unexpected event {other:?}"),
                }
            }
            for c in 0..cluster.n_clouds() {
                let alive = cluster
                    .cloud_members(c)
                    .into_iter()
                    .filter(|&m| active[m])
                    .count();
                assert!(alive >= 1, "cloud {c} emptied at round {r}");
            }
        }
    }

    #[test]
    fn spot_plan_with_zero_rate_is_empty() {
        let cluster = crate::cluster::ClusterSpec::paper_default_scaled(2);
        assert!(FaultPlan::spot_preemptions(1, 10, &cluster, 0.0, 2).is_empty());
    }

    #[test]
    fn random_plan_never_kills_single_node_clouds() {
        // paper_default: every cloud has exactly one member — a gateway
        // kill would strand the cloud, so the generator must not emit any
        let cluster = crate::cluster::ClusterSpec::paper_default();
        let p = FaultPlan::random(3, 50, 20, &cluster);
        assert!(p
            .events()
            .iter()
            .all(|e| !matches!(e, FaultEvent::GatewayDown { .. })));
    }
}

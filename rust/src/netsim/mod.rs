//! WAN simulator: the inter-cloud network substrate.
//!
//! The coordinator never sleeps on real sockets — all communication costs
//! are *simulated* (deterministically, given the experiment seed) while
//! payload bytes are *real* (actual serialized/compressed/encrypted
//! updates). This matches the reproduction goal: Tables 2–3 depend on
//! bytes-on-wire and relative transfer times, not on a specific testbed's
//! absolute throughput.

pub mod faults;
pub mod link;
pub mod protocol;
mod topology;

pub use faults::{FaultEvent, FaultPlan};
pub use link::{Link, TransferStats, MSS_BYTES};
pub use protocol::Protocol;
pub use topology::{LinkClass, NetError, Wan, WanScratch};

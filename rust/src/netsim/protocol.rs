//! Wire-protocol models: TCP, gRPC (HTTP/2 over TCP) and QUIC.
//!
//! The paper (§3.2) treats the protocol as a communication-efficiency
//! knob: "protocols specifically designed for distributed computing, such
//! as gRPC or QUIC, can better handle high-latency, low-bandwidth network
//! environments", and "multiplexing techniques can fully utilize network
//! resources". These analytic models reproduce the first-order effects:
//!
//! * **handshake cost** — RTTs before the first payload byte flows
//!   (TCP 1.5, gRPC 2.5 incl. TLS+SETTINGS, QUIC 1.0 / 0.0 when resumed);
//! * **framing overhead** — header bytes per segment;
//! * **head-of-line blocking** — on TCP-based transports a lost segment
//!   stalls *all* multiplexed streams for ~1 RTT; QUIC retransmits affect
//!   only the stream that lost the packet;
//! * **slow start** — fresh connections ramp the congestion window, which
//!   costs ~log2(bdp_segments) extra RTTs on fat links.

/// Protocol selector (paper Table 1 lists gRPC and QUIC).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Protocol {
    /// Raw TCP stream (baseline, single stream, no multiplexing).
    Tcp,
    /// gRPC over HTTP/2: multiplexed streams over one TCP connection.
    Grpc,
    /// QUIC: multiplexed streams over UDP, stream-level loss recovery.
    Quic,
}

impl Protocol {
    pub fn name(&self) -> &'static str {
        match self {
            Protocol::Tcp => "tcp",
            Protocol::Grpc => "grpc",
            Protocol::Quic => "quic",
        }
    }

    /// Dense index (0..3) — used for per-protocol bitmasks and tables.
    pub fn index(&self) -> usize {
        match self {
            Protocol::Tcp => 0,
            Protocol::Grpc => 1,
            Protocol::Quic => 2,
        }
    }

    pub fn parse(s: &str) -> Option<Protocol> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Some(Protocol::Tcp),
            "grpc" => Some(Protocol::Grpc),
            "quic" => Some(Protocol::Quic),
            _ => None,
        }
    }

    /// RTTs spent before payload flows on a *fresh* connection.
    pub fn handshake_rtts(&self) -> f64 {
        match self {
            Protocol::Tcp => 1.5,  // SYN/SYN-ACK + half
            Protocol::Grpc => 2.5, // TCP + TLS1.3 + HTTP/2 SETTINGS
            Protocol::Quic => 1.0, // combined transport+crypto
        }
    }

    /// RTTs on a *resumed* connection (QUIC 0-RTT).
    pub fn resumed_rtts(&self) -> f64 {
        match self {
            Protocol::Tcp => 1.5, // no resumption
            Protocol::Grpc => 1.0,
            Protocol::Quic => 0.0,
        }
    }

    /// Fractional byte overhead of segment/stream framing.
    pub fn framing_overhead(&self) -> f64 {
        match self {
            Protocol::Tcp => 0.027,  // 40B TCP/IP headers per 1460B MSS
            Protocol::Grpc => 0.035, // + HTTP/2 frame headers, HPACK
            Protocol::Quic => 0.040, // UDP + QUIC packet headers + AEAD tag
        }
    }

    /// Maximum concurrently useful streams (multiplexing limit).
    pub fn max_streams(&self) -> usize {
        match self {
            Protocol::Tcp => 1,
            Protocol::Grpc => 32,
            Protocol::Quic => 64,
        }
    }

    /// Expected stall time added per loss event, as a multiple of RTT,
    /// when `streams` streams are multiplexed.
    ///
    /// TCP-based transports stall the whole connection (head-of-line
    /// blocking): every stream waits for the retransmit. QUIC only stalls
    /// the affected stream, so with `s` parallel streams the expected
    /// *aggregate* slowdown is ~1/s of the TCP penalty.
    pub fn loss_stall_rtts(&self, streams: usize) -> f64 {
        let s = streams.max(1) as f64;
        match self {
            Protocol::Tcp => 1.0,
            Protocol::Grpc => 1.0, // HTTP/2 over TCP still HoL-blocks
            Protocol::Quic => 1.0 / s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [Protocol::Tcp, Protocol::Grpc, Protocol::Quic] {
            assert_eq!(Protocol::parse(p.name()), Some(p));
        }
        assert_eq!(Protocol::parse("GRPC"), Some(Protocol::Grpc));
        assert_eq!(Protocol::parse("http3"), None);
    }

    #[test]
    fn quic_resumes_free() {
        assert_eq!(Protocol::Quic.resumed_rtts(), 0.0);
        assert!(Protocol::Grpc.resumed_rtts() > 0.0);
    }

    #[test]
    fn quic_avoids_hol_blocking() {
        let tcp = Protocol::Grpc.loss_stall_rtts(16);
        let quic = Protocol::Quic.loss_stall_rtts(16);
        assert!(quic < tcp / 8.0);
    }

    #[test]
    fn grpc_multiplexes_tcp_does_not() {
        assert_eq!(Protocol::Tcp.max_streams(), 1);
        assert!(Protocol::Grpc.max_streams() > 1);
    }
}

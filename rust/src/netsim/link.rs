//! Point-to-point WAN link model.

use crate::netsim::protocol::Protocol;
use crate::util::rng::Pcg64;

/// A directed inter-cloud link.
#[derive(Clone, Debug)]
pub struct Link {
    /// bottleneck bandwidth, bits per second
    pub bandwidth_bps: f64,
    /// round-trip time, seconds
    pub rtt_s: f64,
    /// multiplicative jitter std (0.05 = ±5% per-transfer noise)
    pub jitter: f64,
    /// packet loss probability per segment
    pub loss_rate: f64,
}

/// TCP maximum segment size used for loss/slow-start arithmetic.
pub const MSS_BYTES: f64 = 1460.0;

/// Initial congestion window (segments), RFC 6928.
const INIT_CWND_SEGMENTS: f64 = 10.0;

/// Outcome of one simulated transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferStats {
    /// end-to-end seconds from send start to last byte delivered
    pub time_s: f64,
    /// bytes that crossed the wire (payload + framing + retransmits)
    pub wire_bytes: u64,
    /// handshake RTTs charged (0 when connection was warm and QUIC)
    pub handshake_s: f64,
}

impl Link {
    pub fn new(bandwidth_bps: f64, rtt_s: f64) -> Link {
        Link { bandwidth_bps, rtt_s, jitter: 0.0, loss_rate: 0.0 }
    }

    /// Simulate one transfer of `payload_bytes` over this link.
    ///
    /// `warm` — whether a connection to the peer is already established;
    /// `streams` — number of multiplexed application streams;
    /// `rng` — jitter/loss noise source (deterministic per experiment).
    pub fn transfer(
        &self,
        payload_bytes: u64,
        protocol: Protocol,
        warm: bool,
        streams: usize,
        rng: &mut Pcg64,
    ) -> TransferStats {
        assert!(self.bandwidth_bps > 0.0);
        let streams = streams.clamp(1, protocol.max_streams());
        let payload = payload_bytes as f64;

        // --- wire volume: framing + expected retransmitted segments
        let framed = payload * (1.0 + protocol.framing_overhead());
        let n_segments = (framed / MSS_BYTES).ceil();
        let expected_retx = if self.loss_rate > 0.0 {
            n_segments * self.loss_rate / (1.0 - self.loss_rate)
        } else {
            0.0
        };
        let wire = framed + expected_retx * MSS_BYTES;

        // --- handshake
        let hs_rtts =
            if warm { protocol.resumed_rtts() } else { protocol.handshake_rtts() };
        let handshake_s = hs_rtts * self.rtt_s;

        // --- slow start: RTTs to ramp cwnd to the bandwidth-delay product
        // (only on cold connections; warm ones are assumed at cruise).
        let slow_start_s = if warm {
            0.0
        } else {
            let bdp_segments =
                (self.bandwidth_bps * self.rtt_s / 8.0 / MSS_BYTES).max(1.0);
            let needed = (n_segments).min(bdp_segments);
            let ramp_rtts =
                (needed / INIT_CWND_SEGMENTS).max(1.0).log2().max(0.0);
            ramp_rtts * self.rtt_s
        };

        // --- serialization + propagation
        let serialize_s = wire * 8.0 / self.bandwidth_bps;
        let propagation_s = self.rtt_s / 2.0;

        // --- loss stalls (HoL-blocking model, see Protocol)
        let loss_events = n_segments * self.loss_rate;
        let stall_s =
            loss_events * protocol.loss_stall_rtts(streams) * self.rtt_s;

        let mut time = handshake_s + slow_start_s + serialize_s
            + propagation_s + stall_s;

        if self.jitter > 0.0 {
            let noise = 1.0 + self.jitter * rng.normal();
            time *= noise.max(0.1);
        }

        TransferStats {
            time_s: time,
            wire_bytes: wire.round() as u64,
            handshake_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(99, 0)
    }

    /// 1 Gbps, 40 ms RTT, clean link.
    fn clean() -> Link {
        Link::new(1e9, 0.040)
    }

    #[test]
    fn big_transfer_dominated_by_bandwidth() {
        let l = clean();
        // 1 GB over 1 Gbps ~= 8.3 s incl framing
        let st = l.transfer(1_000_000_000, Protocol::Grpc, true, 8, &mut rng());
        assert!(st.time_s > 8.0 && st.time_s < 9.5, "t={}", st.time_s);
        assert!(st.wire_bytes > 1_000_000_000);
    }

    #[test]
    fn cold_connection_pays_handshake() {
        let l = clean();
        let cold = l.transfer(10_000, Protocol::Grpc, false, 1, &mut rng());
        let warm = l.transfer(10_000, Protocol::Grpc, true, 1, &mut rng());
        assert!(cold.time_s > warm.time_s);
        assert!(cold.handshake_s > warm.handshake_s);
    }

    #[test]
    fn quic_beats_grpc_on_lossy_high_rtt() {
        // the paper's motivating scenario: high-latency lossy WAN
        let l = Link { bandwidth_bps: 100e6, rtt_s: 0.120, jitter: 0.0,
                       loss_rate: 0.01 };
        let grpc = l.transfer(50_000_000, Protocol::Grpc, true, 16, &mut rng());
        let quic = l.transfer(50_000_000, Protocol::Quic, true, 16, &mut rng());
        assert!(
            quic.time_s < grpc.time_s * 0.7,
            "quic={} grpc={}",
            quic.time_s,
            grpc.time_s
        );
    }

    #[test]
    fn quic_grpc_comparable_on_clean_link() {
        let l = clean();
        let grpc = l.transfer(50_000_000, Protocol::Grpc, true, 16, &mut rng());
        let quic = l.transfer(50_000_000, Protocol::Quic, true, 16, &mut rng());
        let ratio = quic.time_s / grpc.time_s;
        assert!((0.9..1.1).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn loss_increases_wire_bytes() {
        let l = Link { loss_rate: 0.02, ..clean() };
        let clean_st =
            clean().transfer(10_000_000, Protocol::Tcp, true, 1, &mut rng());
        let lossy_st = l.transfer(10_000_000, Protocol::Tcp, true, 1, &mut rng());
        assert!(lossy_st.wire_bytes > clean_st.wire_bytes);
        assert!(lossy_st.time_s > clean_st.time_s);
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let l = Link { jitter: 0.1, ..clean() };
        let a = l.transfer(1_000_000, Protocol::Quic, true, 4, &mut Pcg64::new(5, 1));
        let b = l.transfer(1_000_000, Protocol::Quic, true, 4, &mut Pcg64::new(5, 1));
        assert_eq!(a, b);
    }

    #[test]
    fn zero_payload_costs_only_latency() {
        let st = clean().transfer(0, Protocol::Tcp, true, 1, &mut rng());
        assert!(st.time_s >= 0.02); // at least propagation
        assert_eq!(st.wire_bytes, 0);
    }
}

//! Cost-aware leader placement: which cloud should host the global
//! model?
//!
//! The seed code hardcoded "cloud 0 is always the leader". This module
//! turns that into a decision: given the cluster's routed topology and a
//! [`PriceBook`], it exhaustively scores every cloud (and the gateway
//! choice inside it) by the expected *egress dollars per round* and picks
//! the argmin. Compute dollars are placement-independent (every worker
//! trains the same steps wherever the leader lives), so they are
//! deliberately left out of the score.
//!
//! The model counts link-class crossings exactly as
//! [`crate::netsim::Wan::route`] routes them (`src → gw(src) → gw(dst) →
//! dst`, degenerate hops skipped) and prices a dense update/broadcast
//! payload at each source cloud's *first-tier* marginal rate. Protocol
//! framing, compression and volume discounts scale every candidate by
//! similar factors, so they cannot flip the argmin; the realized bill is
//! the [`crate::cost::CostLedger`]'s job, not this model's.
//!
//! Placement never changes training math — worker updates, aggregation
//! order and eval are leader-independent — only routing and therefore
//! time and dollars (pinned by `tests/cost_placement.rs`).

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::cost::pricing::PriceBook;
use crate::netsim::LinkClass;

/// The leader-placement knob (config `"placement"`, CLI `--placement`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// the leader lives on cloud `c`'s gateway (the seed behaviour is
    /// `Fixed(0)`)
    Fixed(usize),
    /// score every cloud against the price book and pick the cheapest
    Auto,
}

impl Default for Placement {
    fn default() -> Self {
        Placement::Fixed(0)
    }
}

impl Placement {
    /// Parse `"auto"`, `"fixed"` (= cloud 0) or `"fixed:N"`.
    pub fn parse(s: &str) -> Result<Placement> {
        let s = s.trim();
        if s == "auto" {
            return Ok(Placement::Auto);
        }
        if s == "fixed" {
            return Ok(Placement::Fixed(0));
        }
        if let Some(c) = s.strip_prefix("fixed:") {
            let c = c
                .parse::<usize>()
                .with_context(|| format!("placement {s:?}: bad cloud id"))?;
            return Ok(Placement::Fixed(c));
        }
        bail!("unknown placement {s:?} (expected auto | fixed | fixed:N)")
    }

    /// Canonical name (round-trips through [`Placement::parse`]).
    pub fn name(&self) -> String {
        match self {
            Placement::Auto => "auto".into(),
            Placement::Fixed(c) => format!("fixed:{c}"),
        }
    }
}

/// One candidate leader cloud's expected per-round bill.
#[derive(Clone, Debug)]
pub struct LeaderScore {
    pub cloud: usize,
    /// the node that would host the leader (the cloud's current gateway)
    pub gateway: usize,
    /// expected egress dollars per round (the score)
    pub egress_usd_per_round: f64,
    /// modeled payload bytes crossing each link class per round
    pub bytes_by_class: [u64; 3],
}

/// Traffic model for one round (dense payload sizes; see module docs).
#[derive(Clone, Copy, Debug)]
pub struct RoundTraffic {
    /// one worker update's payload bytes (uplink)
    pub update_bytes: u64,
    /// one model broadcast's payload bytes (downlink)
    pub bcast_bytes: u64,
    /// two-level reduce (one partial per cloud over the WAN) vs flat star
    pub hierarchical: bool,
}

/// Link class between two clouds' gateways (mirrors
/// [`crate::netsim::Wan::from_cluster`]'s region rule). Shared with the
/// serving router so request egress is priced exactly like training
/// traffic.
pub fn cloud_pair_class(cluster: &ClusterSpec, a: usize, b: usize) -> LinkClass {
    let (ga, gb) = (cluster.gateway(a), cluster.gateway(b));
    if cluster.platforms[ga].region == cluster.platforms[gb].region {
        LinkClass::IntraRegion
    } else {
        LinkClass::InterRegion
    }
}

/// Score one candidate leader cloud: walk every transfer a round makes,
/// count its hops per (source cloud, class), and price them.
fn score_cloud(
    cluster: &ClusterSpec,
    book: &PriceBook,
    traffic: &RoundTraffic,
    leader_cloud: usize,
) -> LeaderScore {
    let n_clouds = cluster.n_clouds();
    // bytes[src_cloud][class]
    let mut bytes = vec![[0u64; 3]; n_clouds];
    let mut add = |cloud: usize, class: LinkClass, b: u64| {
        bytes[cloud][class.index()] += b;
    };
    let up = traffic.update_bytes;
    let down = traffic.bcast_bytes;

    for c in 0..n_clouds {
        let members = cluster.cloud_members(c).len() as u64;
        let wan_class = cloud_pair_class(cluster, c, leader_cloud);
        if traffic.hierarchical {
            // members ⇄ gateway over the AZ fabric (the gateway member
            // loops back locally)
            add(c, LinkClass::IntraAz, (members - 1) * (up + down));
            if c != leader_cloud {
                // one partial aggregate up, one broadcast down
                add(c, wan_class, up);
                add(leader_cloud, wan_class, down);
            }
        } else if c == leader_cloud {
            // leader-cloud workers reach the leader over the AZ fabric
            add(c, LinkClass::IntraAz, (members - 1) * (up + down));
        } else {
            // every worker w routes w → gw(c) → leader and back: the
            // non-gateway members pay the intra hop, all members' payloads
            // pay the WAN hop
            add(c, LinkClass::IntraAz, (members - 1) * (up + down));
            add(c, wan_class, members * up);
            add(leader_cloud, wan_class, members * down);
        }
    }

    let mut usd = 0.0;
    for (c, row) in bytes.iter().enumerate() {
        for class in LinkClass::ALL {
            let b = row[class.index()];
            if b > 0 {
                usd += b as f64 / 1e9
                    * book.egress_rate(c, class).marginal_rate(0.0);
            }
        }
    }
    let mut by_class = [0u64; 3];
    for row in &bytes {
        for k in 0..3 {
            by_class[k] += row[k];
        }
    }
    LeaderScore {
        cloud: leader_cloud,
        gateway: cluster.gateway(leader_cloud),
        egress_usd_per_round: usd,
        bytes_by_class: by_class,
    }
}

/// Score every cloud as a leader candidate, in cloud-id order. The
/// gateway choice inside a cloud is the cluster's current (egress-ok)
/// gateway: members of a cloud share a region and AZ fabric, so any
/// other eligible member scores identically — the lowest-id eligible
/// member is the deterministic representative.
pub fn score_leaders(
    cluster: &ClusterSpec,
    book: &PriceBook,
    traffic: &RoundTraffic,
) -> Vec<LeaderScore> {
    (0..cluster.n_clouds())
        .map(|c| score_cloud(cluster, book, traffic, c))
        .collect()
}

/// The argmin leader (strictly-less comparison, so ties resolve to the
/// lowest cloud id — deterministic across runs and platforms).
pub fn choose_leader(
    cluster: &ClusterSpec,
    book: &PriceBook,
    traffic: &RoundTraffic,
) -> LeaderScore {
    score_leaders(cluster, book, traffic)
        .into_iter()
        .reduce(|best, s| {
            if s.egress_usd_per_round < best.egress_usd_per_round {
                s
            } else {
                best
            }
        })
        .expect("cluster has at least one cloud")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traffic(hier: bool) -> RoundTraffic {
        RoundTraffic { update_bytes: 1_000_000, bcast_bytes: 1_000_000, hierarchical: hier }
    }

    #[test]
    fn placement_parses_and_round_trips() {
        assert_eq!(Placement::parse("auto").unwrap(), Placement::Auto);
        assert_eq!(Placement::parse("fixed").unwrap(), Placement::Fixed(0));
        assert_eq!(Placement::parse("fixed:2").unwrap(), Placement::Fixed(2));
        assert!(Placement::parse("fixed:x").is_err());
        assert!(Placement::parse("argmin").is_err());
        for p in [Placement::Auto, Placement::Fixed(3)] {
            assert_eq!(Placement::parse(&p.name()).unwrap(), p);
        }
        assert_eq!(Placement::default(), Placement::Fixed(0));
    }

    #[test]
    fn uniform_prices_tie_to_cloud_zero() {
        let cluster = ClusterSpec::paper_default_scaled(4);
        let book = PriceBook::uniform(3.0, 0.05);
        for hier in [false, true] {
            let best = choose_leader(&cluster, &book, &traffic(hier));
            assert_eq!(best.cloud, 0, "hier={hier}");
            assert_eq!(best.gateway, cluster.gateway(0));
        }
    }

    #[test]
    fn auto_avoids_the_expensive_egress_cloud() {
        // leader cloud L sends 2 broadcasts (src L) and receives one
        // partial from each other cloud (src c): score(L) grows with
        // cloud L's own rate, so the argmin is the *cheapest* sender
        let cluster = ClusterSpec::paper_default_scaled(4);
        let mut book = PriceBook::uniform(3.0, 0.0);
        book.egress = [
            crate::cost::EgressRate::flat(0.0),
            crate::cost::EgressRate::flat(0.09),
            crate::cost::EgressRate::flat(0.09),
        ];
        book.overrides = vec![
            (0, LinkClass::InterRegion, crate::cost::EgressRate::flat(0.20)),
            (1, LinkClass::InterRegion, crate::cost::EgressRate::flat(0.15)),
            (2, LinkClass::InterRegion, crate::cost::EgressRate::flat(0.05)),
        ];
        // paper clouds are pairwise inter-region, so the overrides bind
        let best = choose_leader(&cluster, &book, &traffic(true));
        assert_eq!(best.cloud, 2);
        let scores = score_leaders(&cluster, &book, &traffic(true));
        assert_eq!(scores.len(), 3);
        assert!(scores[2].egress_usd_per_round < scores[0].egress_usd_per_round);
        assert!(scores[2].egress_usd_per_round < scores[1].egress_usd_per_round);
    }

    #[test]
    fn hier_crossing_counts_beat_the_star() {
        let cluster = ClusterSpec::paper_default_scaled(8);
        let book = PriceBook::paper_default();
        let star = score_cloud(&cluster, &book, &traffic(false), 0);
        let hier = score_cloud(&cluster, &book, &traffic(true), 0);
        let k = LinkClass::InterRegion.index();
        // star ships m updates + m broadcasts per non-leader cloud over
        // the WAN; hier ships exactly one of each
        assert_eq!(star.bytes_by_class[k], 8 * hier.bytes_by_class[k]);
        assert!(hier.egress_usd_per_round * 4.0 < star.egress_usd_per_round);
        // intra-AZ volume is identical
        assert_eq!(
            star.bytes_by_class[LinkClass::IntraAz.index()],
            hier.bytes_by_class[LinkClass::IntraAz.index()]
        );
    }
}

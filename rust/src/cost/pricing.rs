//! Price books: what a byte and a node-hour cost on each cloud.
//!
//! A [`PriceBook`] holds per-cloud compute rates ($/node-hour) and
//! per-link-class egress rates ($/GB) with optional per-src-cloud
//! overrides and tiered volume discounts — the shape of real public-cloud
//! bills: compute is metered per instance-hour, network per GB *leaving*
//! a cloud, cheaper in bulk and cheaper over same-region interconnect
//! than over the inter-region internet.
//!
//! Everything is deterministic: tier boundaries are walked in order and
//! dollar sums are pure functions of cumulative byte counts, so pricing a
//! run twice (or on a different thread count) is bit-identical.

use anyhow::{bail, Context, Result};

use crate::netsim::LinkClass;
use crate::util::json::Json;

/// One volume tier of an egress rate: traffic up to `upto_gb` cumulative
/// gigabytes (decimal GB, `f64::INFINITY` for the last tier) is billed at
/// `usd_per_gb`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tier {
    pub upto_gb: f64,
    pub usd_per_gb: f64,
}

/// A tiered $/GB egress rate (volume discounts accumulate over the whole
/// run, per source cloud and link class).
#[derive(Clone, Debug, PartialEq)]
pub struct EgressRate {
    /// ascending tiers; the last tier must be unbounded
    pub tiers: Vec<Tier>,
}

impl EgressRate {
    /// Single-tier rate: every GB costs the same.
    pub fn flat(usd_per_gb: f64) -> EgressRate {
        EgressRate { tiers: vec![Tier { upto_gb: f64::INFINITY, usd_per_gb }] }
    }

    /// Tiered rate from `(upto_gb, usd_per_gb)` pairs (use
    /// `f64::INFINITY` for the last threshold).
    pub fn tiered(tiers: &[(f64, f64)]) -> EgressRate {
        EgressRate {
            tiers: tiers
                .iter()
                .map(|&(upto_gb, usd_per_gb)| Tier { upto_gb, usd_per_gb })
                .collect(),
        }
    }

    /// Structural sanity: at least one tier, thresholds strictly
    /// ascending, last unbounded, rates finite and non-negative.
    pub fn validate(&self) -> Result<()> {
        if self.tiers.is_empty() {
            bail!("egress rate needs at least one tier");
        }
        let mut prev = 0.0f64;
        for (i, t) in self.tiers.iter().enumerate() {
            if !(t.usd_per_gb >= 0.0) || !t.usd_per_gb.is_finite() {
                bail!("tier {i}: rate must be finite and >= 0, got {}", t.usd_per_gb);
            }
            if !(t.upto_gb > prev) {
                bail!(
                    "tier {i}: threshold {} must exceed the previous ({prev})",
                    t.upto_gb
                );
            }
            prev = t.upto_gb;
        }
        let last = self.tiers.last().unwrap();
        if last.upto_gb.is_finite() {
            bail!("last tier must be unbounded (upto_gb = null/inf)");
        }
        Ok(())
    }

    /// Marginal $/GB at cumulative volume `at_gb`.
    pub fn marginal_rate(&self, at_gb: f64) -> f64 {
        for t in &self.tiers {
            if at_gb < t.upto_gb {
                return t.usd_per_gb;
            }
        }
        self.tiers.last().expect("validated non-empty").usd_per_gb
    }

    /// Dollars for `delta_bytes` of new traffic given `billed_bytes`
    /// already billed against this rate (tier discounts straddle the
    /// boundary exactly).
    pub fn cost(&self, billed_bytes: u64, delta_bytes: u64) -> f64 {
        let a = billed_bytes as f64 / 1e9;
        let b = (billed_bytes + delta_bytes) as f64 / 1e9;
        let mut usd = 0.0;
        let mut lo = 0.0f64;
        for t in &self.tiers {
            let seg = (b.min(t.upto_gb) - a.max(lo)).max(0.0);
            usd += seg * t.usd_per_gb;
            if b <= t.upto_gb {
                break;
            }
            lo = t.upto_gb;
        }
        usd
    }

    fn to_json(&self) -> Json {
        Json::arr(self.tiers.iter().map(|t| {
            Json::obj(vec![
                (
                    "upto_gb",
                    if t.upto_gb.is_finite() {
                        Json::num(t.upto_gb)
                    } else {
                        Json::Null
                    },
                ),
                ("usd_per_gb", Json::num(t.usd_per_gb)),
            ])
        }))
    }

    fn from_json(v: &Json) -> Result<EgressRate> {
        let arr = v.as_arr().context("egress rate must be an array of tiers")?;
        let mut tiers = Vec::with_capacity(arr.len());
        for t in arr {
            let upto_gb = match t.get("upto_gb") {
                None | Some(Json::Null) => f64::INFINITY,
                Some(x) => x.as_f64().context("tier upto_gb must be a number or null")?,
            };
            let usd_per_gb = t
                .get("usd_per_gb")
                .and_then(Json::as_f64)
                .context("tier missing usd_per_gb")?;
            tiers.push(Tier { upto_gb, usd_per_gb });
        }
        let rate = EgressRate { tiers };
        rate.validate()?;
        Ok(rate)
    }
}

/// Per-cloud compute and egress prices for one experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct PriceBook {
    pub name: String,
    /// $/node-hour per cloud id; clouds beyond the list pay
    /// `default_compute_per_node_hour`
    pub compute_per_node_hour: Vec<f64>,
    pub default_compute_per_node_hour: f64,
    /// $/node-hour for spot/preemptible capacity per cloud id; clouds
    /// beyond the list pay `default_spot_per_node_hour`. Billed instead
    /// of the on-demand rate when the experiment runs with `spot` set
    /// (the capacity is interruptible — pair with
    /// [`crate::netsim::FaultPlan::spot_preemptions`]).
    pub spot_per_node_hour: Vec<f64>,
    pub default_spot_per_node_hour: f64,
    /// base $/GB egress per link class, indexed by [`LinkClass::index`]
    pub egress: [EgressRate; 3],
    /// src-cloud-specific overrides `(cloud, class, rate)` — e.g. one
    /// provider's pricier inter-region egress. First match wins; keep
    /// the list sorted for readable serialization.
    pub overrides: Vec<(usize, LinkClass, EgressRate)>,
}

impl PriceBook {
    /// Realistic public-cloud numbers for the paper's 3-cloud testbed
    /// (compute matches [`crate::cluster::ClusterSpec::paper_default`]'s
    /// p3.2xlarge-class instances; egress follows the familiar published
    /// shapes: ~$0.01/GB cross-AZ, ~$0.02/GB same-region interconnect,
    /// ~$0.09/GB inter-region internet with bulk discounts, and cloud 1
    /// (the GCP stand-in) charging a premium for inter-region egress).
    pub fn paper_default() -> PriceBook {
        PriceBook {
            name: "paper-default".into(),
            compute_per_node_hour: vec![3.06, 2.48, 3.40],
            default_compute_per_node_hour: 3.0,
            // spot capacity at the familiar ~70% discount off on-demand
            spot_per_node_hour: vec![0.92, 0.74, 1.02],
            default_spot_per_node_hour: 0.9,
            egress: [
                // IntraAz: cross-AZ transfer inside one cloud
                EgressRate::flat(0.01),
                // IntraRegion: same-region cross-cloud interconnect
                EgressRate::flat(0.02),
                // InterRegion: internet egress with volume discounts
                EgressRate::tiered(&[
                    (10_240.0, 0.09),
                    (51_200.0, 0.085),
                    (153_600.0, 0.07),
                    (f64::INFINITY, 0.05),
                ]),
            ],
            overrides: vec![(
                1,
                LinkClass::InterRegion,
                EgressRate::tiered(&[
                    (1_024.0, 0.12),
                    (10_240.0, 0.11),
                    (f64::INFINITY, 0.08),
                ]),
            )],
        }
    }

    /// Flat uniform book (every cloud, every class, one rate) — handy
    /// for tests and ablations where tiering is noise.
    pub fn uniform(compute_per_node_hour: f64, usd_per_gb: f64) -> PriceBook {
        PriceBook {
            name: "uniform".into(),
            compute_per_node_hour: Vec::new(),
            default_compute_per_node_hour: compute_per_node_hour,
            // uniform books price spot at the same ~70% discount
            spot_per_node_hour: Vec::new(),
            default_spot_per_node_hour: compute_per_node_hour * 0.3,
            egress: [
                EgressRate::flat(usd_per_gb),
                EgressRate::flat(usd_per_gb),
                EgressRate::flat(usd_per_gb),
            ],
            overrides: Vec::new(),
        }
    }

    /// $/node-hour of compute on `cloud`.
    pub fn compute_rate(&self, cloud: usize) -> f64 {
        self.compute_per_node_hour
            .get(cloud)
            .copied()
            .unwrap_or(self.default_compute_per_node_hour)
    }

    /// $/node-hour of spot/preemptible compute on `cloud`.
    pub fn spot_rate(&self, cloud: usize) -> f64 {
        self.spot_per_node_hour
            .get(cloud)
            .copied()
            .unwrap_or(self.default_spot_per_node_hour)
    }

    /// The egress rate traffic leaving `cloud` over a `class` link pays
    /// (override if present, else the class base rate).
    pub fn egress_rate(&self, cloud: usize, class: LinkClass) -> &EgressRate {
        self.overrides
            .iter()
            .find(|(c, k, _)| *c == cloud && *k == class)
            .map(|(_, _, r)| r)
            .unwrap_or(&self.egress[class.index()])
    }

    /// Dollars for `delta_bytes` leaving `cloud` over `class`, given
    /// `billed_bytes` already billed for that (cloud, class) pair.
    pub fn egress_cost(
        &self,
        cloud: usize,
        class: LinkClass,
        billed_bytes: u64,
        delta_bytes: u64,
    ) -> f64 {
        self.egress_rate(cloud, class).cost(billed_bytes, delta_bytes)
    }

    pub fn validate(&self) -> Result<()> {
        for (i, r) in self.compute_per_node_hour.iter().enumerate() {
            if !(*r >= 0.0) || !r.is_finite() {
                bail!("compute rate for cloud {i} must be finite and >= 0");
            }
        }
        if !(self.default_compute_per_node_hour >= 0.0)
            || !self.default_compute_per_node_hour.is_finite()
        {
            bail!("default compute rate must be finite and >= 0");
        }
        for (i, r) in self.spot_per_node_hour.iter().enumerate() {
            if !(*r >= 0.0) || !r.is_finite() {
                bail!("spot rate for cloud {i} must be finite and >= 0");
            }
        }
        if !(self.default_spot_per_node_hour >= 0.0)
            || !self.default_spot_per_node_hour.is_finite()
        {
            bail!("default spot rate must be finite and >= 0");
        }
        for class in LinkClass::ALL {
            self.egress[class.index()]
                .validate()
                .with_context(|| format!("egress rate for {}", class.name()))?;
        }
        for (cloud, class, rate) in &self.overrides {
            rate.validate().with_context(|| {
                format!("egress override for cloud {cloud}, {}", class.name())
            })?;
        }
        Ok(())
    }

    /// Serialize (JSON round-trips through [`PriceBook::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "compute_per_node_hour",
                Json::arr(self.compute_per_node_hour.iter().map(|&r| Json::num(r))),
            ),
            (
                "default_compute_per_node_hour",
                Json::num(self.default_compute_per_node_hour),
            ),
            (
                "spot_per_node_hour",
                Json::arr(self.spot_per_node_hour.iter().map(|&r| Json::num(r))),
            ),
            (
                "default_spot_per_node_hour",
                Json::num(self.default_spot_per_node_hour),
            ),
            (
                "egress",
                Json::obj(
                    LinkClass::ALL
                        .iter()
                        .map(|&c| (c.name(), self.egress[c.index()].to_json()))
                        .collect(),
                ),
            ),
            (
                "overrides",
                Json::arr(self.overrides.iter().map(|(cloud, class, rate)| {
                    Json::obj(vec![
                        ("cloud", Json::num(*cloud as f64)),
                        ("class", Json::str(class.name())),
                        ("tiers", rate.to_json()),
                    ])
                })),
            ),
        ])
    }

    /// Parse from a JSON value. Missing fields default to
    /// [`PriceBook::paper_default`]'s — except that supplying `egress`
    /// (or `overrides`) resets the default per-cloud overrides, so a
    /// custom book's rates are never silently shadowed by the paper
    /// book's cloud-1 premium; list overrides explicitly to keep them.
    pub fn from_json(v: &Json) -> Result<PriceBook> {
        let mut book = PriceBook::paper_default();
        if v.get("egress").is_some() || v.get("overrides").is_some() {
            book.overrides = Vec::new();
        }
        if let Some(s) = v.get("name").and_then(Json::as_str) {
            book.name = s.to_string();
        }
        if let Some(arr) = v.get("compute_per_node_hour").and_then(Json::as_arr) {
            book.compute_per_node_hour = arr
                .iter()
                .map(|x| x.as_f64().context("compute rate must be a number"))
                .collect::<Result<Vec<f64>>>()?;
        }
        book.default_compute_per_node_hour = v.opt_f64(
            "default_compute_per_node_hour",
            book.default_compute_per_node_hour,
        );
        if let Some(arr) = v.get("spot_per_node_hour").and_then(Json::as_arr) {
            book.spot_per_node_hour = arr
                .iter()
                .map(|x| x.as_f64().context("spot rate must be a number"))
                .collect::<Result<Vec<f64>>>()?;
        }
        book.default_spot_per_node_hour =
            v.opt_f64("default_spot_per_node_hour", book.default_spot_per_node_hour);
        if let Some(eg) = v.get("egress") {
            for class in LinkClass::ALL {
                if let Some(r) = eg.get(class.name()) {
                    book.egress[class.index()] = EgressRate::from_json(r)
                        .with_context(|| format!("egress.{}", class.name()))?;
                }
            }
        }
        if let Some(arr) = v.get("overrides").and_then(Json::as_arr) {
            book.overrides = arr
                .iter()
                .map(|o| {
                    let cloud = o
                        .get("cloud")
                        .and_then(Json::as_usize)
                        .context("override missing cloud")?;
                    let class = o
                        .get("class")
                        .and_then(Json::as_str)
                        .and_then(LinkClass::parse)
                        .context("override missing/unknown class")?;
                    let rate = EgressRate::from_json(
                        o.get("tiers").context("override missing tiers")?,
                    )?;
                    Ok((cloud, class, rate))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        book.validate()?;
        Ok(book)
    }

    /// Parse from JSON text (see EXPERIMENTS.md §Cost for the schema).
    pub fn parse(text: &str) -> Result<PriceBook> {
        let v = Json::parse(text).context("price book JSON")?;
        PriceBook::from_json(&v)
    }

    /// Load from a JSON file (the CLI's `--price-book FILE`).
    pub fn load(path: &std::path::Path) -> Result<PriceBook> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading price book {path:?}"))?;
        PriceBook::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_rate_is_linear() {
        let r = EgressRate::flat(0.1);
        assert!((r.cost(0, 1_000_000_000) - 0.1).abs() < 1e-12);
        assert!((r.cost(5_000_000_000, 2_000_000_000) - 0.2).abs() < 1e-12);
        assert_eq!(r.cost(0, 0), 0.0);
    }

    #[test]
    fn tiers_straddle_boundaries_exactly() {
        // 1 GB at $0.10, beyond at $0.02
        let r = EgressRate::tiered(&[(1.0, 0.10), (f64::INFINITY, 0.02)]);
        // 0.5 GB entirely in tier 0
        assert!((r.cost(0, 500_000_000) - 0.05).abs() < 1e-12);
        // 2 GB from zero: 1 GB * 0.10 + 1 GB * 0.02
        assert!((r.cost(0, 2_000_000_000) - 0.12).abs() < 1e-12);
        // resuming past the boundary bills the cheap tier only
        assert!((r.cost(1_500_000_000, 500_000_000) - 0.01).abs() < 1e-12);
        // incremental billing sums to the one-shot bill
        let one_shot = r.cost(0, 3_000_000_000);
        let a = r.cost(0, 800_000_000);
        let b = r.cost(800_000_000, 2_200_000_000);
        assert!((one_shot - (a + b)).abs() < 1e-9);
        assert!((r.marginal_rate(0.5) - 0.10).abs() < 1e-12);
        assert!((r.marginal_rate(1.5) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(EgressRate { tiers: vec![] }.validate().is_err());
        // finite last tier
        assert!(EgressRate::tiered(&[(10.0, 0.1)]).validate().is_err());
        // non-ascending thresholds
        assert!(EgressRate::tiered(&[(10.0, 0.1), (5.0, 0.05), (f64::INFINITY, 0.01)])
            .validate()
            .is_err());
        // negative rate
        assert!(EgressRate::tiered(&[(f64::INFINITY, -0.1)]).validate().is_err());
        assert!(PriceBook::paper_default().validate().is_ok());
    }

    #[test]
    fn overrides_shadow_base_rates() {
        let book = PriceBook::paper_default();
        // cloud 1 pays the override for inter-region...
        assert!(
            (book.egress_rate(1, LinkClass::InterRegion).marginal_rate(0.0) - 0.12)
                .abs()
                < 1e-12
        );
        // ...but the base rate for everything else
        assert!(
            (book.egress_rate(1, LinkClass::IntraAz).marginal_rate(0.0) - 0.01).abs()
                < 1e-12
        );
        assert!(
            (book.egress_rate(0, LinkClass::InterRegion).marginal_rate(0.0) - 0.09)
                .abs()
                < 1e-12
        );
        // compute falls back to the default beyond the listed clouds
        assert!((book.compute_rate(2) - 3.40).abs() < 1e-12);
        assert!((book.compute_rate(7) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn spot_rates_discount_on_demand() {
        let book = PriceBook::paper_default();
        for c in 0..3 {
            assert!(book.spot_rate(c) < 0.5 * book.compute_rate(c));
        }
        assert!((book.spot_rate(7) - 0.9).abs() < 1e-12);
        // round-trips through JSON and parses from partial JSON
        let back = PriceBook::parse(&book.to_json().to_string()).unwrap();
        assert_eq!(book.spot_per_node_hour, back.spot_per_node_hour);
        let custom = PriceBook::parse(
            r#"{"spot_per_node_hour": [0.5], "default_spot_per_node_hour": 0.4}"#,
        )
        .unwrap();
        assert!((custom.spot_rate(0) - 0.5).abs() < 1e-12);
        assert!((custom.spot_rate(9) - 0.4).abs() < 1e-12);
        // negative spot rates are rejected
        assert!(PriceBook::parse(r#"{"spot_per_node_hour": [-1.0]}"#).is_err());
    }

    #[test]
    fn json_round_trip() {
        let book = PriceBook::paper_default();
        let back = PriceBook::parse(&book.to_json().to_string()).unwrap();
        assert_eq!(book, back);
        // partial JSON keeps paper defaults for the rest
        let partial = PriceBook::parse(
            r#"{"name": "x", "egress": {"inter-region": [{"usd_per_gb": 0.2}]}}"#,
        )
        .unwrap();
        assert_eq!(partial.name, "x");
        assert!(
            (partial.egress_rate(0, LinkClass::InterRegion).marginal_rate(0.0) - 0.2)
                .abs()
                < 1e-12
        );
        assert!(
            (partial.egress_rate(0, LinkClass::IntraAz).marginal_rate(0.0) - 0.01)
                .abs()
                < 1e-12
        );
        // supplying egress drops the paper book's default overrides:
        // cloud 1 pays the user's rate, not the stale $0.12 premium
        assert!(partial.overrides.is_empty());
        assert!(
            (partial.egress_rate(1, LinkClass::InterRegion).marginal_rate(0.0) - 0.2)
                .abs()
                < 1e-12
        );
        // a book with no egress/overrides keys keeps the paper defaults
        let bare = PriceBook::parse(r#"{"name": "bare"}"#).unwrap();
        assert_eq!(bare.overrides, PriceBook::paper_default().overrides);
        // malformed books are rejected
        assert!(PriceBook::parse(r#"{"egress": {"inter-region": [{"upto_gb": 5, "usd_per_gb": 0.1}]}}"#).is_err());
        assert!(PriceBook::parse("{").is_err());
    }
}

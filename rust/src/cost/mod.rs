//! Cloud economics: price books, the run's dollar ledger, and
//! cost-aware leader placement.
//!
//! The paper claims cross-cloud federated training reduces *training
//! costs*, not just bytes and hours. This subsystem makes that claim
//! measurable: [`PriceBook`] turns the WAN's per-(cloud, link-class)
//! byte ledger and the workers' compute seconds into dollars,
//! [`CostLedger`] accrues them per round with real volume-tier state,
//! and [`placement`] uses the same prices to *decide* where the
//! aggregation leader should live instead of assuming cloud 0.

pub mod ledger;
pub mod placement;
pub mod pricing;

pub use ledger::{CostBreakdown, CostLedger};
pub use placement::{
    choose_leader, cloud_pair_class, score_leaders, LeaderScore, Placement,
    RoundTraffic,
};
pub use pricing::{EgressRate, PriceBook, Tier};

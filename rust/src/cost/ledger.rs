//! The run's dollar ledger: bytes and node-seconds in, dollars out.
//!
//! A [`CostLedger`] is fed by the coordinator at every round boundary
//! with (a) the WAN's cumulative per-(source cloud, link class) byte
//! split and (b) the round's per-worker compute seconds, and prices both
//! against a [`PriceBook`]. It keeps the tier state (cumulative billed
//! volume per cloud and class), so volume discounts accumulate across
//! rounds exactly as a monthly cloud bill would.
//!
//! Determinism: byte deltas are u64, compute seconds come from the
//! deterministic simulation, and every f64 summation walks clouds and
//! classes in a fixed order — pricing a run twice, or on a different
//! thread count, produces bit-identical dollars.

use crate::cluster::ClusterSpec;
use crate::cost::pricing::PriceBook;
use crate::netsim::LinkClass;
use crate::util::json::Json;

/// Dollars, broken down by cloud and by kind (compute vs egress per link
/// class). Used both per-round and cumulatively.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// compute dollars per cloud id
    pub compute_usd: Vec<f64>,
    /// egress dollars per source cloud per link class
    /// (`egress_usd[cloud][class.index()]`)
    pub egress_usd: Vec<[f64; 3]>,
}

impl CostBreakdown {
    pub fn zero(n_clouds: usize) -> CostBreakdown {
        CostBreakdown {
            compute_usd: vec![0.0; n_clouds],
            egress_usd: vec![[0.0; 3]; n_clouds],
        }
    }

    pub fn n_clouds(&self) -> usize {
        self.compute_usd.len()
    }

    /// Total dollars: the exact sum of every per-cloud, per-class entry,
    /// walked in fixed (cloud, compute-then-classes) order — so
    /// `total_usd()` always decomposes bit-exactly into its entries.
    pub fn total_usd(&self) -> f64 {
        let mut usd = 0.0;
        for (compute, egress) in self.compute_usd.iter().zip(&self.egress_usd) {
            usd += compute;
            for e in egress {
                usd += e;
            }
        }
        usd
    }

    /// Compute dollars across clouds.
    pub fn compute_total_usd(&self) -> f64 {
        self.compute_usd.iter().sum()
    }

    /// Egress dollars across clouds and classes.
    pub fn egress_total_usd(&self) -> f64 {
        self.egress_usd.iter().flatten().sum()
    }

    /// Egress dollars over links of one class, across clouds.
    pub fn egress_class_usd(&self, class: LinkClass) -> f64 {
        self.egress_usd.iter().map(|row| row[class.index()]).sum()
    }

    /// Every dollar billed to one cloud (compute + egress).
    pub fn cloud_usd(&self, cloud: usize) -> f64 {
        self.compute_usd[cloud] + self.egress_usd[cloud].iter().sum::<f64>()
    }

    /// Accumulate `other` into `self` entry-by-entry (used for the
    /// cumulative ledger — cumulative entries are exact sums of the
    /// per-round entries).
    pub fn add(&mut self, other: &CostBreakdown) {
        if self.n_clouds() < other.n_clouds() {
            self.compute_usd.resize(other.n_clouds(), 0.0);
            self.egress_usd.resize(other.n_clouds(), [0.0; 3]);
        }
        for c in 0..other.n_clouds() {
            self.compute_usd[c] += other.compute_usd[c];
            for k in 0..3 {
                self.egress_usd[c][k] += other.egress_usd[c][k];
            }
        }
    }

    /// JSON form for run reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_usd", Json::num(self.total_usd())),
            ("compute_usd", Json::num(self.compute_total_usd())),
            ("egress_usd", Json::num(self.egress_total_usd())),
            (
                "egress_by_class_usd",
                Json::obj(
                    LinkClass::ALL
                        .iter()
                        .map(|&c| (c.name(), Json::num(self.egress_class_usd(c))))
                        .collect(),
                ),
            ),
            (
                "by_cloud",
                Json::arr((0..self.n_clouds()).map(|c| {
                    Json::obj(vec![
                        ("cloud", Json::num(c as f64)),
                        ("compute_usd", Json::num(self.compute_usd[c])),
                        (
                            "egress_usd",
                            Json::obj(
                                LinkClass::ALL
                                    .iter()
                                    .map(|&k| {
                                        (
                                            k.name(),
                                            Json::num(self.egress_usd[c][k.index()]),
                                        )
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Prices a run as it happens (see module docs).
#[derive(Clone, Debug)]
pub struct CostLedger {
    book: PriceBook,
    /// bill compute at the book's spot/preemptible rates instead of
    /// on-demand (egress prices are the same either way)
    spot: bool,
    /// bytes already billed per (cloud, class) — the tier state
    billed_bytes: Vec<[u64; 3]>,
    cum: CostBreakdown,
}

impl CostLedger {
    pub fn new(book: PriceBook, n_clouds: usize) -> CostLedger {
        CostLedger {
            book,
            spot: false,
            billed_bytes: vec![[0u64; 3]; n_clouds],
            cum: CostBreakdown::zero(n_clouds),
        }
    }

    pub fn book(&self) -> &PriceBook {
        &self.book
    }

    /// Switch compute billing to the book's spot rates (config, set once
    /// at build — not WAL state).
    pub fn set_spot(&mut self, spot: bool) {
        self.spot = spot;
    }

    /// Price everything that happened since the last observation:
    /// `cum_bytes` is the WAN's *cumulative* per-(cloud, class) byte
    /// split ([`crate::netsim::Wan::wire_bytes_by_cloud_class`]) and
    /// `platform_secs` the window's per-worker compute seconds. Returns
    /// the window's breakdown; the cumulative one accrues internally.
    pub fn observe(
        &mut self,
        cum_bytes: &[[u64; 3]],
        platform_secs: &[f64],
        cluster: &ClusterSpec,
    ) -> CostBreakdown {
        let n_clouds = self.billed_bytes.len();
        assert!(
            cum_bytes.len() <= n_clouds,
            "byte split covers {} clouds, ledger sized for {n_clouds}",
            cum_bytes.len()
        );
        let mut round = CostBreakdown::zero(n_clouds);
        for (c, row) in cum_bytes.iter().enumerate() {
            for k in 0..3 {
                let billed = self.billed_bytes[c][k];
                debug_assert!(row[k] >= billed, "WAN byte ledger went backwards");
                let delta = row[k].saturating_sub(billed);
                if delta > 0 {
                    round.egress_usd[c][k] = self.book.egress_cost(
                        c,
                        LinkClass::ALL[k],
                        billed,
                        delta,
                    );
                    self.billed_bytes[c][k] = row[k];
                }
            }
        }
        for (w, secs) in platform_secs.iter().enumerate() {
            let cloud = cluster.cloud_of(w);
            let rate = if self.spot {
                self.book.spot_rate(cloud)
            } else {
                self.book.compute_rate(cloud)
            };
            round.compute_usd[cloud] += secs / 3600.0 * rate;
        }
        self.cum.add(&round);
        round
    }

    /// Everything billed so far (exact sum of the per-window breakdowns).
    pub fn cumulative(&self) -> &CostBreakdown {
        &self.cum
    }

    /// Snapshot the accrual state for the WAL: the tier positions
    /// (`billed_bytes`) and the cumulative dollars, as raw bit patterns.
    /// The price book is config and is rebuilt on resume.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_usize(self.billed_bytes.len());
        for row in &self.billed_bytes {
            for &b in row {
                w.put_u64(b);
            }
        }
        for &usd in &self.cum.compute_usd {
            w.put_f64(usd);
        }
        for row in &self.cum.egress_usd {
            for &usd in row {
                w.put_f64(usd);
            }
        }
    }

    /// Restore state written by [`CostLedger::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        let n = r.get_usize()?;
        anyhow::ensure!(
            n == self.billed_bytes.len(),
            "WAL cost ledger covers {n} clouds, run has {}",
            self.billed_bytes.len()
        );
        for row in self.billed_bytes.iter_mut() {
            for b in row.iter_mut() {
                *b = r.get_u64()?;
            }
        }
        for usd in self.cum.compute_usd.iter_mut() {
            *usd = r.get_f64()?;
        }
        for row in self.cum.egress_usd.iter_mut() {
            for usd in row.iter_mut() {
                *usd = r.get_f64()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_decomposes_exactly() {
        let mut b = CostBreakdown::zero(3);
        b.compute_usd = vec![1.5, 0.25, 3.125];
        b.egress_usd = vec![
            [0.1, 0.2, 0.3],
            [0.01, 0.02, 0.03],
            [0.001, 0.002, 0.003],
        ];
        // mirror total_usd's summation order: exact bit equality
        let mut manual = 0.0;
        for c in 0..3 {
            manual += b.compute_usd[c];
            for e in &b.egress_usd[c] {
                manual += e;
            }
        }
        assert_eq!(manual.to_bits(), b.total_usd().to_bits());
        assert!((b.cloud_usd(0) - 2.1).abs() < 1e-12);
        assert!(
            (b.egress_class_usd(LinkClass::IntraAz) - 0.111).abs() < 1e-12
        );
    }

    #[test]
    fn ledger_prices_deltas_and_accrues_tiers() {
        let cluster = crate::cluster::ClusterSpec::paper_default();
        // 1 GB tier boundary on inter-region for every cloud
        let mut book = PriceBook::uniform(3.6, 0.0);
        book.egress[LinkClass::InterRegion.index()] =
            crate::cost::EgressRate::tiered(&[(1.0, 0.10), (f64::INFINITY, 0.02)]);
        let mut ledger = CostLedger::new(book, 3);

        // first window: 0.6 GB from cloud 0, one node-hour of compute
        let w1 = vec![[0, 0, 600_000_000u64], [0; 3], [0; 3]];
        let r1 = ledger.observe(&w1, &[3600.0, 0.0, 0.0], &cluster);
        assert!((r1.egress_usd[0][2] - 0.06).abs() < 1e-12);
        assert!((r1.compute_usd[0] - 3.6).abs() < 1e-12);
        assert_eq!(r1.compute_usd[1], 0.0);

        // second window: 0.8 GB more from cloud 0 — 0.4 GB in tier 0,
        // 0.4 GB in the discounted tier
        let w2 = vec![[0, 0, 1_400_000_000u64], [0; 3], [0; 3]];
        let r2 = ledger.observe(&w2, &[0.0; 3], &cluster);
        assert!((r2.egress_usd[0][2] - (0.4 * 0.10 + 0.4 * 0.02)).abs() < 1e-12);

        // cumulative is the exact sum of the windows
        let cum = ledger.cumulative();
        assert_eq!(
            cum.egress_usd[0][2].to_bits(),
            (r1.egress_usd[0][2] + r2.egress_usd[0][2]).to_bits()
        );
        assert_eq!(cum.compute_usd[0].to_bits(), r1.compute_usd[0].to_bits());
    }

    #[test]
    fn spot_billing_uses_spot_rates() {
        let cluster = crate::cluster::ClusterSpec::paper_default();
        let book = PriceBook::paper_default();
        let mut on_demand = CostLedger::new(book.clone(), 3);
        let mut spot = CostLedger::new(book.clone(), 3);
        spot.set_spot(true);
        let bytes = vec![[0u64; 3]; 3];
        let secs = [3600.0, 0.0, 0.0];
        let a = on_demand.observe(&bytes, &secs, &cluster);
        let b = spot.observe(&bytes, &secs, &cluster);
        assert!((a.compute_usd[0] - book.compute_rate(0)).abs() < 1e-12);
        assert!((b.compute_usd[0] - book.spot_rate(0)).abs() < 1e-12);
        assert!(b.compute_usd[0] < a.compute_usd[0] * 0.5);
    }

    #[test]
    fn wal_roundtrip_restores_tier_positions() {
        let cluster = crate::cluster::ClusterSpec::paper_default();
        let mut book = PriceBook::uniform(3.6, 0.0);
        book.egress[LinkClass::InterRegion.index()] =
            crate::cost::EgressRate::tiered(&[(1.0, 0.10), (f64::INFINITY, 0.02)]);
        let mut a = CostLedger::new(book.clone(), 3);
        let w1 = vec![[0, 0, 600_000_000u64], [0; 3], [0; 3]];
        a.observe(&w1, &[3600.0, 0.0, 0.0], &cluster);

        // snapshot -> fresh ledger -> restore
        let mut w = crate::wal::ByteWriter::new();
        a.wal_encode(&mut w);
        let bytes = w.into_bytes();
        let mut b = CostLedger::new(book, 3);
        let mut r = crate::wal::ByteReader::new(&bytes);
        b.wal_decode(&mut r).unwrap();
        r.finish().unwrap();

        // both must bill the second window identically — including the
        // tier boundary crossing that depends on billed_bytes
        let w2 = vec![[0, 0, 1_400_000_000u64], [0; 3], [0; 3]];
        let ra = a.observe(&w2, &[0.0; 3], &cluster);
        let rb = b.observe(&w2, &[0.0; 3], &cluster);
        assert_eq!(ra, rb);
        assert_eq!(
            a.cumulative().total_usd().to_bits(),
            b.cumulative().total_usd().to_bits()
        );
    }

    #[test]
    fn wal_decode_rejects_cloud_count_mismatch() {
        let a = CostLedger::new(PriceBook::paper_default(), 3);
        let mut w = crate::wal::ByteWriter::new();
        a.wal_encode(&mut w);
        let bytes = w.into_bytes();
        let mut b = CostLedger::new(PriceBook::paper_default(), 2);
        let mut r = crate::wal::ByteReader::new(&bytes);
        assert!(b.wal_decode(&mut r).is_err());
    }

    #[test]
    fn repricing_is_bit_identical() {
        let cluster = crate::cluster::ClusterSpec::paper_default_scaled(2);
        let windows: Vec<Vec<[u64; 3]>> = vec![
            vec![[123, 0, 456_789], [7, 0, 0], [0, 0, 999_999]],
            vec![[123, 0, 2_456_789], [7, 0, 88], [5, 0, 1_999_999]],
        ];
        let secs = vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0];
        let price = || {
            let mut l = CostLedger::new(PriceBook::paper_default(), 3);
            for w in &windows {
                l.observe(w, &secs, &cluster);
            }
            l.cumulative().clone()
        };
        let a = price();
        let b = price();
        assert_eq!(a, b);
        assert_eq!(a.total_usd().to_bits(), b.total_usd().to_bits());
    }
}

//! `log`-crate backend: leveled, timestamped stderr logger.
//!
//! Level comes from `CROSSFED_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INIT: Once = Once::new();

struct StderrLogger {
    start: Instant,
    max_level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max_level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the global logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("CROSSFED_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        let logger = Box::new(StderrLogger { start: Instant::now(), max_level: level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(match level {
                Level::Error => LevelFilter::Error,
                Level::Warn => LevelFilter::Warn,
                Level::Info => LevelFilter::Info,
                Level::Debug => LevelFilter::Debug,
                Level::Trace => LevelFilter::Trace,
            });
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}

//! Dependency-free data-parallel driver for the per-round hot path.
//!
//! Every kernel that touches multi-MB update vectors (ParamSet linear
//! algebra, the codecs, the CTR keystream) routes through here. Design
//! constraints (EXPERIMENTS.md §Perf):
//!
//! * **Deterministic for any thread count.** Work is cut into *fixed-size*
//!   blocks ([`BLOCK`] elements) whose boundaries do not depend on how
//!   many worker threads run, and anything order-sensitive (reductions,
//!   RNG-consuming codecs) is combined by the caller in block order. The
//!   serial fallback walks the same blocks, so serial and parallel
//!   results are bit-identical.
//! * **No dependencies.** `std::thread::scope` over
//!   `available_parallelism()` — the offline image has no rayon.
//! * **Cheap below threshold.** Inputs under [`PAR_THRESHOLD`] total
//!   elements never pay thread-spawn cost; the closure runs inline.
//!
//! Thread-count resolution order: [`with_threads`] override (thread-local,
//! used by tests/benches for serial-vs-parallel comparisons) →
//! `CROSSFED_THREADS` env var → `available_parallelism()`.

use std::cell::Cell;
use std::sync::OnceLock;
use std::thread;

/// Elements per work block. Fixed (not derived from the thread count) so
/// block boundaries — and therefore results — are reproducible across
/// machines.
pub const BLOCK: usize = 1 << 14;

/// Total-element threshold below which kernels stay serial.
pub const PAR_THRESHOLD: usize = 1 << 15;

fn hardware_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::env::var("CROSSFED_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = Cell::new(None);
}

/// Worker threads the current call may use.
pub fn current_threads() -> usize {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(hardware_threads)
}

/// Run `f` with the calling thread's parallelism pinned to `n`, restored
/// on exit (panic-safe). The override is thread-local, so concurrently
/// running tests do not interfere with each other.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    assert!(n > 0, "thread count must be positive");
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            OVERRIDE.with(|o| o.set(prev));
        }
    }
    let _guard = Restore(OVERRIDE.with(|o| o.replace(Some(n))));
    f()
}

/// Execute independent work items across `current_threads()` workers
/// (round-robin). Items must be disjoint (e.g. `chunks_mut` blocks); the
/// caller is responsible for making per-item work order-insensitive.
pub fn run_items<I: Send>(items: Vec<I>, f: impl Fn(I) + Sync) {
    let nt = current_threads().min(items.len());
    if nt <= 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let mut lanes: Vec<Vec<I>> = Vec::with_capacity(nt);
    lanes.resize_with(nt, Vec::new);
    for (i, it) in items.into_iter().enumerate() {
        lanes[i % nt].push(it);
    }
    let f = &f;
    thread::scope(|s| {
        let mut lanes = lanes.into_iter();
        let own = lanes.next().unwrap();
        for lane in lanes {
            s.spawn(move || {
                for it in lane {
                    f(it);
                }
            });
        }
        // the calling thread works too instead of idling at the join
        for it in own {
            f(it);
        }
    });
}

/// [`run_items`] gated on problem size: at or below [`PAR_THRESHOLD`]
/// total elements the items run inline on the calling thread.
pub fn run_items_auto<I: Send>(
    total_elems: usize,
    items: Vec<I>,
    f: impl Fn(I) + Sync,
) {
    if total_elems <= PAR_THRESHOLD || current_threads() == 1 {
        for it in items {
            f(it);
        }
    } else {
        run_items(items, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn with_threads_overrides_and_restores() {
        let before = current_threads();
        with_threads(3, || assert_eq!(current_threads(), 3));
        assert_eq!(current_threads(), before);
        // nested overrides unwind correctly
        with_threads(2, || {
            with_threads(5, || assert_eq!(current_threads(), 5));
            assert_eq!(current_threads(), 2);
        });
    }

    #[test]
    fn run_items_visits_every_item_once() {
        for nt in [1, 2, 7] {
            let hits = AtomicUsize::new(0);
            let mut data = vec![0u8; 1000];
            let items: Vec<&mut [u8]> = data.chunks_mut(13).collect();
            with_threads(nt, || {
                run_items(items, |c| {
                    hits.fetch_add(c.len(), Ordering::Relaxed);
                    c.fill(1);
                });
            });
            assert_eq!(hits.load(Ordering::Relaxed), 1000);
            assert!(data.iter().all(|&b| b == 1));
        }
    }

    #[test]
    fn empty_and_single_item_ok() {
        run_items(Vec::<usize>::new(), |_| panic!("no items"));
        let got = AtomicUsize::new(0);
        run_items(vec![41usize], |x| {
            got.store(x + 1, Ordering::Relaxed);
        });
        assert_eq!(got.load(Ordering::Relaxed), 42);
    }

    #[test]
    fn auto_threshold_stays_serial() {
        // below threshold the closure must run on the calling thread
        let caller = thread::current().id();
        run_items_auto(10, vec![0usize; 4], |_| {
            assert_eq!(thread::current().id(), caller);
        });
    }
}

//! Minimal-but-complete JSON codec.
//!
//! The offline image has no `serde`; configs, AOT manifests and metric
//! dumps all flow through this module instead. It implements the full
//! JSON grammar (RFC 8259): objects, arrays, strings with escapes
//! (including \uXXXX surrogate pairs), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a BTreeMap so that
/// serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers used by config/manifest loading.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("missing required field {key:?}"),
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("field {key:?} is not a string"),
        })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("field {key:?} is not a number"),
        })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?.as_usize().ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("field {key:?} is not a non-negative integer"),
        })
    }

    /// Optional-field helpers (return default when missing).
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---- construction helpers ------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 9e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no inf/nan; emit null like most encoders
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            s.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            s.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            s.push('/');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            s.push('\u{8}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            s.push('\u{c}');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            s.push('\n');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            s.push('\r');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            s.push('\t');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: must be followed by \uXXXX low
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(
                                            self.err("invalid low surrogate")
                                        );
                                    }
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("bad codepoint"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("raw control character in string"));
                    }
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d as u32;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "x", "c": null}], "d": -2.5e-1}"#,
        )
        .unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.25);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // re-serializes as raw utf-8 and reparses
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\q\"",
                     "[1] junk", "\"\u{1}\""] {
            assert!(Json::parse(text).is_err(), "should reject {text:?}");
        }
    }

    #[test]
    fn deep_roundtrip_pretty() {
        let text = r#"{"model":{"d":64,"layers":[1,2,3]},"s":"hi","b":true}"#;
        let v = Json::parse(text).unwrap();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 中文");
    }

    #[test]
    fn large_int_precision() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.as_i64().unwrap(), 123456789012);
        assert_eq!(v.to_string(), "123456789012");
    }
}

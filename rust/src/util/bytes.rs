//! Byte-level codecs and formatting shared by transport/compress/crypto.

/// f32 slice -> little-endian bytes.
pub fn f32s_to_le(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// little-endian bytes -> f32 vec (len must be a multiple of 4).
pub fn le_to_f32s(bytes: &[u8]) -> Option<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

/// u32 slice -> little-endian bytes.
pub fn u32s_to_le(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// little-endian bytes -> u32 vec.
pub fn le_to_u32s(bytes: &[u8]) -> Option<Vec<u32>> {
    if bytes.len() % 4 != 0 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

/// Human-readable byte size ("3.62 GB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1000.0 && unit < UNITS.len() - 1 {
        v /= 1000.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Human-readable duration from seconds ("2.1 h", "35 s").
pub fn human_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.2} h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.1} s")
    } else {
        format!("{:.1} ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE, -0.0];
        assert_eq!(le_to_f32s(&f32s_to_le(&xs)).unwrap(), xs);
    }

    #[test]
    fn u32_roundtrip() {
        let xs = vec![0u32, 1, u32::MAX, 0xdeadbeef];
        assert_eq!(le_to_u32s(&u32s_to_le(&xs)).unwrap(), xs);
    }

    #[test]
    fn rejects_ragged() {
        assert!(le_to_f32s(&[1, 2, 3]).is_none());
        assert!(le_to_u32s(&[1, 2, 3, 4, 5]).is_none());
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(4_500_000_000), "4.50 GB");
        assert_eq!(human_duration(4.0), "4.0 s");
        assert_eq!(human_duration(7200.0), "2.00 h");
    }
}

//! Byte-level codecs and formatting shared by transport/compress/crypto.
//!
//! The f32<->LE conversions are block-parallel (they sit on the per-round
//! transport hot path for multi-MB payloads); the `_into` variants write
//! into caller-owned buffers so steady state allocates nothing.

use crate::util::par;

/// f32 slice -> little-endian bytes.
pub fn f32s_to_le(xs: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len() * 4];
    f32s_to_le_into(xs, &mut out);
    out
}

/// f32 slice -> little-endian bytes, into a caller-sized buffer
/// (`out.len() == 4 * xs.len()`). Block-parallel above the threshold.
pub fn f32s_to_le_into(xs: &[f32], out: &mut [u8]) {
    assert_eq!(out.len(), xs.len() * 4, "LE buffer size mismatch");
    let items: Vec<(&mut [u8], &[f32])> = out
        .chunks_mut(par::BLOCK * 4)
        .zip(xs.chunks(par::BLOCK))
        .collect();
    par::run_items_auto(xs.len(), items, |(d, s)| {
        for (db, x) in d.chunks_exact_mut(4).zip(s) {
            db.copy_from_slice(&x.to_le_bytes());
        }
    });
}

/// little-endian bytes -> f32 vec (len must be a multiple of 4).
pub fn le_to_f32s(bytes: &[u8]) -> Option<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return None;
    }
    let mut out = vec![0.0f32; bytes.len() / 4];
    le_to_f32s_into(bytes, &mut out)?;
    Some(out)
}

/// little-endian bytes -> caller-sized f32 buffer
/// (`bytes.len() == 4 * out.len()`). Block-parallel above the threshold.
pub fn le_to_f32s_into(bytes: &[u8], out: &mut [f32]) -> Option<()> {
    if bytes.len() != out.len() * 4 {
        return None;
    }
    let items: Vec<(&mut [f32], &[u8])> = out
        .chunks_mut(par::BLOCK)
        .zip(bytes.chunks(par::BLOCK * 4))
        .collect();
    par::run_items_auto(out.len(), items, |(d, s)| {
        for (x, c) in d.iter_mut().zip(s.chunks_exact(4)) {
            *x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    });
    Some(())
}

/// u32 slice -> little-endian bytes.
pub fn u32s_to_le(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// little-endian bytes -> u32 vec.
pub fn le_to_u32s(bytes: &[u8]) -> Option<Vec<u32>> {
    if bytes.len() % 4 != 0 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

/// Human-readable byte size ("3.62 GB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1000.0 && unit < UNITS.len() - 1 {
        v /= 1000.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Human-readable duration from seconds ("2.1 h", "35 s").
pub fn human_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.2} h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.1} s")
    } else {
        format!("{:.1} ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE, -0.0];
        assert_eq!(le_to_f32s(&f32s_to_le(&xs)).unwrap(), xs);
    }

    #[test]
    fn u32_roundtrip() {
        let xs = vec![0u32, 1, u32::MAX, 0xdeadbeef];
        assert_eq!(le_to_u32s(&u32s_to_le(&xs)).unwrap(), xs);
    }

    #[test]
    fn rejects_ragged() {
        assert!(le_to_f32s(&[1, 2, 3]).is_none());
        assert!(le_to_u32s(&[1, 2, 3, 4, 5]).is_none());
        let mut out = vec![0.0f32; 2];
        assert!(le_to_f32s_into(&[0u8; 9], &mut out).is_none());
    }

    #[test]
    fn into_variants_match_allocating_ones_any_thread_count() {
        // big enough to engage the parallel path
        let xs: Vec<f32> = (0..par::PAR_THRESHOLD + 777)
            .map(|i| (i as f32 * 0.7).sin())
            .collect();
        let serial = par::with_threads(1, || f32s_to_le(&xs));
        let parallel = par::with_threads(8, || f32s_to_le(&xs));
        assert_eq!(serial, parallel);
        let back_s = par::with_threads(1, || le_to_f32s(&serial).unwrap());
        let back_p = par::with_threads(8, || le_to_f32s(&serial).unwrap());
        assert_eq!(back_s, back_p);
        assert_eq!(back_s, xs);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(4_500_000_000), "4.50 GB");
        assert_eq!(human_duration(4.0), "4.0 s");
        assert_eq!(human_duration(7200.0), "2.00 h");
    }
}

//! Small statistics toolkit used by the metrics pipeline and benches.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponentially weighted moving average — the load monitor's smoother.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    /// Overwrite the smoothed value (WAL state restore; `alpha` is fixed
    /// at construction and not part of the snapshot).
    pub fn set_value(&mut self, value: Option<f64>) {
        self.value = value;
    }
}

/// Percentile over a sample (linear interpolation, like numpy's default).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Summary of a sample: mean/std/min/median/p95/max.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Coefficient of variation of a load vector — the imbalance metric used
/// by the partition monitor (0 = perfectly balanced).
pub fn imbalance_cv(loads: &[f64]) -> f64 {
    if loads.len() < 2 {
        return 0.0;
    }
    let mut w = Welford::new();
    for &x in loads {
        w.push(x);
    }
    if w.mean().abs() < 1e-12 {
        return 0.0;
    }
    // population std for a fixed set of platforms
    let var = loads.iter().map(|x| (x - w.mean()).powi(2)).sum::<f64>()
        / loads.len() as f64;
    var.sqrt() / w.mean()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - direct).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.push(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_sane() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn imbalance_zero_when_equal() {
        assert_eq!(imbalance_cv(&[5.0, 5.0, 5.0]), 0.0);
        assert!(imbalance_cv(&[1.0, 5.0, 9.0]) > 0.5);
    }
}

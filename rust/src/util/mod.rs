//! Foundation substrates built in-repo (the offline image vendors only the
//! `xla` crate's dependency closure — no serde/rand/clap/criterion).

pub mod bytes;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod stats;

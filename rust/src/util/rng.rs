//! Deterministic PRNG substrate.
//!
//! The offline image has no `rand` crate, so crossfed ships its own:
//! a PCG64 (XSL-RR) generator seeded via SplitMix64, plus the
//! distributions the simulator needs (uniform, normal via Box–Muller,
//! exponential, Dirichlet via Gamma/Marsaglia-Tsang).
//!
//! Everything in the simulator is seeded from an experiment-level seed so
//! that every run — partitioning, network jitter, DP noise, stragglers —
//! is exactly reproducible.

/// PCG64 XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64 — used to expand a u64 seed into stream/state material.
#[inline]
pub fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let mut sm2 = stream ^ 0xda3e_39cb_94b9_5bdb;
        let i0 = splitmix64(&mut sm2) as u128;
        let i1 = splitmix64(&mut sm2) as u128;
        let mut rng = Pcg64 { state: (s0 << 64) | s1, inc: ((i0 << 64) | i1) | 1 };
        rng.next_u64();
        rng
    }

    /// Raw generator state as four words (WAL snapshots): the 128-bit
    /// state and increment, each split high/low.
    pub fn state_words(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_words`] — continues the
    /// stream exactly where the snapshot left off.
    pub fn from_state_words(w: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: ((w[0] as u128) << 64) | w[1] as u128,
            inc: ((w[2] as u128) << 64) | w[3] as u128,
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn child(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(seed, tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Lemire's debiased multiply.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; no caching so
    /// the stream stays reproducible under reordering).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 supported through
    /// the boost trick for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `n` categories — the standard
    /// federated-learning non-IID skew generator.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = xs.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to uniform
            return vec![1.0 / n as f64; n];
        }
        for x in &mut xs {
            *x /= sum;
        }
        xs
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_words_roundtrip_continues_stream() {
        let mut a = Pcg64::new(99, 5);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_state_words(a.state_words());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Pcg64::new(7, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(3, 0);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::new(13, 0);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::new(17, 0);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let w = r.dirichlet(alpha, 7);
            assert_eq!(w.len(), 7);
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(w.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let mut r = Pcg64::new(19, 0);
        // with alpha = 0.05 the max weight should usually dominate
        let mut max_sum = 0.0;
        for _ in 0..50 {
            let w = r.dirichlet(0.05, 5);
            max_sum += w.iter().cloned().fold(0.0, f64::max);
        }
        assert!(max_sum / 50.0 > 0.7);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(23, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(29, 0);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(31, 0);
        let n = 30_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}

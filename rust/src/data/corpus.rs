//! Deterministic synthetic corpus with topic structure.
//!
//! Sentences are sampled from a 2nd-order Markov chain over per-topic
//! word pools, so the corpus has (a) learnable local statistics — an LM
//! makes real progress on it — and (b) topic labels for non-IID sharding
//! (each document carries a topic, and Dirichlet sharding skews topics
//! across cloud platforms, mirroring label-skew federated benchmarks).

use crate::util::rng::Pcg64;

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub n_docs: usize,
    pub doc_sentences: usize,
    pub n_topics: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { n_docs: 300, doc_sentences: 12, n_topics: 6, seed: 1234 }
    }
}

/// One generated document.
#[derive(Clone, Debug)]
pub struct Doc {
    pub topic: usize,
    pub text: String,
}

/// The generated corpus.
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub docs: Vec<Doc>,
    pub n_topics: usize,
}

/// Shared function words (every topic uses these — gives the LM easy wins).
const FUNCTION_WORDS: [&str; 16] = [
    "the", "of", "and", "to", "in", "a", "is", "that", "for", "it", "as",
    "with", "on", "by", "at", "from",
];

/// Topic word pools: distinct content vocabularies per topic.
const TOPIC_POOLS: [[&str; 12]; 8] = [
    ["model", "training", "gradient", "layer", "epoch", "loss", "batch",
     "weight", "tensor", "neural", "network", "optimizer"],
    ["cloud", "platform", "instance", "region", "compute", "storage",
     "cluster", "deploy", "scale", "virtual", "machine", "server"],
    ["market", "price", "stock", "trade", "asset", "yield", "bond",
     "equity", "index", "portfolio", "margin", "volume"],
    ["patient", "clinical", "treatment", "diagnosis", "therapy", "dose",
     "symptom", "trial", "disease", "hospital", "medical", "health"],
    ["protocol", "packet", "latency", "bandwidth", "router", "stream",
     "socket", "network", "transfer", "channel", "buffer", "queue"],
    ["privacy", "encryption", "cipher", "key", "secure", "mask", "noise",
     "attack", "leak", "secret", "trust", "audit"],
    ["energy", "solar", "grid", "power", "battery", "carbon", "wind",
     "turbine", "voltage", "storage", "plant", "fuel"],
    ["language", "token", "word", "sentence", "corpus", "text", "grammar",
     "meaning", "context", "translation", "speech", "dialogue"],
];

impl SyntheticCorpus {
    /// Generate deterministically from the config.
    pub fn generate(cfg: &CorpusConfig) -> SyntheticCorpus {
        assert!(cfg.n_topics >= 1 && cfg.n_topics <= TOPIC_POOLS.len());
        let mut rng = Pcg64::new(cfg.seed, 0xC0885);
        let mut docs = Vec::with_capacity(cfg.n_docs);
        for d in 0..cfg.n_docs {
            let topic = d % cfg.n_topics;
            let text = Self::gen_doc(topic, cfg.doc_sentences, &mut rng);
            docs.push(Doc { topic, text });
        }
        SyntheticCorpus { docs, n_topics: cfg.n_topics }
    }

    fn gen_doc(topic: usize, sentences: usize, rng: &mut Pcg64) -> String {
        let pool = &TOPIC_POOLS[topic];
        let mut out = String::new();
        for _ in 0..sentences {
            let len = 6 + rng.below_usize(8);
            // 2nd-order chain state: last two word kinds steer the next
            let mut prev_content = false;
            for w in 0..len {
                if w > 0 {
                    out.push(' ');
                }
                // alternate-ish: content words follow function words with
                // high probability, giving stable bigram statistics
                let p_content = if prev_content { 0.25 } else { 0.75 };
                if rng.uniform() < p_content {
                    out.push_str(pool[rng.below_usize(pool.len())]);
                    prev_content = true;
                } else {
                    out.push_str(
                        FUNCTION_WORDS[rng.below_usize(FUNCTION_WORDS.len())],
                    );
                    prev_content = false;
                }
            }
            out.push('.');
            out.push(' ');
        }
        out.push('\n');
        out
    }

    /// All text concatenated (for tokenizer stats / held-out splits).
    pub fn full_text(&self) -> String {
        self.docs.iter().map(|d| d.text.as_str()).collect()
    }

    /// Total character count.
    pub fn n_chars(&self) -> usize {
        self.docs.iter().map(|d| d.text.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = CorpusConfig::default();
        let a = SyntheticCorpus::generate(&cfg);
        let b = SyntheticCorpus::generate(&cfg);
        assert_eq!(a.docs.len(), b.docs.len());
        assert_eq!(a.docs[0].text, b.docs[0].text);
        let cfg2 = CorpusConfig { seed: 99, ..cfg };
        let c = SyntheticCorpus::generate(&cfg2);
        assert_ne!(a.docs[0].text, c.docs[0].text);
    }

    #[test]
    fn topics_round_robin_and_distinct() {
        let cfg = CorpusConfig { n_docs: 12, n_topics: 4, ..Default::default() };
        let c = SyntheticCorpus::generate(&cfg);
        assert_eq!(c.docs[0].topic, 0);
        assert_eq!(c.docs[5].topic, 1);
        // different topics use different content words
        let t0 = &c.docs[0].text;
        assert!(t0.contains("model") || t0.contains("gradient")
                || t0.contains("loss") || t0.contains("training")
                || t0.contains("layer") || t0.contains("epoch")
                || t0.contains("batch") || t0.contains("weight")
                || t0.contains("tensor") || t0.contains("neural")
                || t0.contains("network") || t0.contains("optimizer"));
    }

    #[test]
    fn corpus_is_ascii_printable() {
        let c = SyntheticCorpus::generate(&CorpusConfig::default());
        for doc in &c.docs {
            assert!(doc.text.bytes().all(|b| (32..=126).contains(&b) || b == b'\n'));
        }
    }

    #[test]
    fn corpus_size_scales() {
        let small = SyntheticCorpus::generate(&CorpusConfig {
            n_docs: 10, ..Default::default()
        });
        let big = SyntheticCorpus::generate(&CorpusConfig {
            n_docs: 100, ..Default::default()
        });
        assert!(big.n_chars() > 5 * small.n_chars());
    }
}

//! Batch iterator over a token shard.
//!
//! Produces `(tokens, targets)` pairs shaped `(batch, seq)` where targets
//! are tokens shifted by one — standard next-token LM training. Windows
//! are sampled at random offsets (seeded), so repeated epochs see
//! different slices.

use crate::runtime::Batch;
use crate::util::rng::Pcg64;

/// Infinite randomized batch sampler over one shard's tokens.
#[derive(Clone, Debug)]
pub struct BatchIter {
    tokens: Vec<i32>,
    batch_size: usize,
    seq_len: usize,
    rng: Pcg64,
}

impl BatchIter {
    /// `tokens` must be longer than `seq_len + 1`. If the shard is too
    /// small it is tiled (documents repeat — matches how tiny federated
    /// clients loop their local data).
    pub fn new(tokens: &[i32], batch_size: usize, seq_len: usize, seed: u64) -> BatchIter {
        assert!(batch_size > 0 && seq_len > 0);
        let mut t = tokens.to_vec();
        if t.is_empty() {
            t = vec![0];
        }
        while t.len() < seq_len + 2 {
            let mut copy = t.clone();
            t.append(&mut copy);
        }
        BatchIter { tokens: t, batch_size, seq_len, rng: Pcg64::new(seed, 0xBA7C4) }
    }

    /// Sample the next batch.
    pub fn next_batch(&mut self) -> Batch {
        let n = self.tokens.len();
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for _ in 0..self.batch_size {
            let start = self.rng.below_usize(n - self.seq_len - 1);
            tokens.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            targets
                .extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        Batch { tokens, targets }
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sampler RNG state (WAL snapshot; the token buffer is regenerated
    /// from the partition plan on resume).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore the sampler RNG (WAL resume).
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        self.rng = Pcg64::from_state_words(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_shift() {
        let toks: Vec<i32> = (0..500).map(|i| i % 96).collect();
        let mut it = BatchIter::new(&toks, 4, 16, 1);
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), 64);
        assert_eq!(b.targets.len(), 64);
        // targets are tokens shifted by one within each row
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(b.tokens[row * 16 + i + 1], b.targets[row * 16 + i]);
            }
        }
    }

    #[test]
    fn tiny_shard_tiles() {
        let toks = vec![5i32, 6, 7];
        let mut it = BatchIter::new(&toks, 2, 32, 2);
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), 64);
        assert!(b.tokens.iter().all(|&t| (5..=7).contains(&t)));
    }

    #[test]
    fn deterministic_stream() {
        let toks: Vec<i32> = (0..300).collect();
        let mut a = BatchIter::new(&toks, 2, 8, 9);
        let mut b = BatchIter::new(&toks, 2, 8, 9);
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
        let mut c = BatchIter::new(&toks, 2, 8, 10);
        assert_ne!(a.next_batch().tokens, c.next_batch().tokens);
    }

    #[test]
    fn batches_vary_over_time() {
        let toks: Vec<i32> = (0..1000).collect();
        let mut it = BatchIter::new(&toks, 1, 8, 3);
        let b1 = it.next_batch();
        let b2 = it.next_batch();
        assert_ne!(b1.tokens, b2.tokens);
    }
}

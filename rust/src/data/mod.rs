//! Data substrate: corpus generation, tokenization, sharding, batching.
//!
//! Substitution (DESIGN.md): the paper trains on WikiText-103, which the
//! offline image cannot download. This module generates a deterministic
//! synthetic corpus with genuine n-gram structure (a Markov chain over
//! word templates with per-topic vocabularies) so that (a) an LM trained
//! on it has a decreasing, non-trivial loss, and (b) shards can be made
//! *non-IID by topic* — the heterogeneity that drives the paper's
//! aggregation comparisons.

mod batcher;
mod corpus;
mod shard;
mod tokenizer;

pub use batcher::BatchIter;
pub use corpus::{CorpusConfig, SyntheticCorpus};
pub use shard::{dirichlet_shards, equal_shards, skew_tv, weighted_shards, Shard};
pub use tokenizer::CharTokenizer;

//! Sharding the corpus across cloud platforms.
//!
//! Three strategies, matching the experiment matrix:
//! * [`equal_shards`] — IID round-robin (the "fixed partitioning" base);
//! * [`weighted_shards`] — sized by platform capacity weights;
//! * [`dirichlet_shards`] — topic-skewed non-IID (Dirichlet(α) per topic
//!   over platforms), the standard federated heterogeneity generator and
//!   the regime where the paper's dynamic weighting/gradient aggregation
//!   claims bite.

use crate::data::corpus::SyntheticCorpus;
use crate::data::tokenizer::CharTokenizer;
use crate::util::rng::Pcg64;

/// One platform's local dataset: token stream + provenance.
#[derive(Clone, Debug)]
pub struct Shard {
    pub platform: usize,
    pub tokens: Vec<i32>,
    /// docs assigned (indices into the corpus)
    pub doc_ids: Vec<usize>,
    /// per-topic doc counts (heterogeneity diagnostics)
    pub topic_counts: Vec<usize>,
}

impl Shard {
    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sample-count weight n_i used by FedAvg (formula 1).
    pub fn n_samples(&self) -> usize {
        self.tokens.len()
    }

    fn from_docs(
        platform: usize,
        doc_ids: Vec<usize>,
        corpus: &SyntheticCorpus,
    ) -> Shard {
        let tok = CharTokenizer;
        let mut tokens = Vec::new();
        let mut topic_counts = vec![0usize; corpus.n_topics];
        for &d in &doc_ids {
            tokens.extend(tok.encode(&corpus.docs[d].text));
            topic_counts[corpus.docs[d].topic] += 1;
        }
        Shard { platform, tokens, doc_ids, topic_counts }
    }
}

/// IID: docs dealt in equal contiguous blocks. (Blocks, not round-robin:
/// topics cycle through the corpus with period `n_topics`, and round-robin
/// dealing would alias with that cycle whenever `n` divides `n_topics`,
/// producing accidentally *maximal* topic skew.)
pub fn equal_shards(corpus: &SyntheticCorpus, n: usize) -> Vec<Shard> {
    assert!(n >= 1);
    let n_docs = corpus.docs.len();
    let base = n_docs / n;
    let extra = n_docs % n;
    let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut next = 0;
    for (p, a) in assignments.iter_mut().enumerate() {
        let take = base + usize::from(p < extra);
        a.extend(next..next + take);
        next += take;
    }
    assignments
        .into_iter()
        .enumerate()
        .map(|(p, ids)| Shard::from_docs(p, ids, corpus))
        .collect()
}

/// Capacity-weighted: platform i receives ~weights[i] fraction of docs.
pub fn weighted_shards(
    corpus: &SyntheticCorpus,
    weights: &[f64],
) -> Vec<Shard> {
    assert!(!weights.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0);
    let n = weights.len();
    let n_docs = corpus.docs.len();
    // largest-remainder apportionment
    let mut counts: Vec<usize> = weights
        .iter()
        .map(|w| (w / total * n_docs as f64).floor() as usize)
        .collect();
    let mut assigned: usize = counts.iter().sum();
    let mut remainders: Vec<(usize, f64)> = weights
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let exact = w / total * n_docs as f64;
            (i, exact - exact.floor())
        })
        .collect();
    remainders.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut ri = 0;
    while assigned < n_docs {
        counts[remainders[ri % n].0] += 1;
        assigned += 1;
        ri += 1;
    }

    let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut next = 0usize;
    for (p, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            assignments[p].push(next);
            next += 1;
        }
    }
    assignments
        .into_iter()
        .enumerate()
        .map(|(p, ids)| Shard::from_docs(p, ids, corpus))
        .collect()
}

/// Non-IID: for each topic, split its docs across platforms by a
/// Dirichlet(alpha) draw. Small alpha → strong label skew.
pub fn dirichlet_shards(
    corpus: &SyntheticCorpus,
    n: usize,
    alpha: f64,
    seed: u64,
) -> Vec<Shard> {
    assert!(n >= 1);
    let mut rng = Pcg64::new(seed, 0xD112);
    let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); n];

    for topic in 0..corpus.n_topics {
        let docs: Vec<usize> = (0..corpus.docs.len())
            .filter(|&d| corpus.docs[d].topic == topic)
            .collect();
        let weights = rng.dirichlet(alpha, n);
        for &d in &docs {
            // sample platform from the topic's platform distribution
            let u = rng.uniform();
            let mut acc = 0.0;
            let mut chosen = n - 1;
            for (p, &w) in weights.iter().enumerate() {
                acc += w;
                if u < acc {
                    chosen = p;
                    break;
                }
            }
            assignments[chosen].push(d);
        }
    }

    // guarantee non-empty shards: steal one doc for any empty platform
    for p in 0..n {
        if assignments[p].is_empty() {
            let donor = (0..n)
                .max_by_key(|&q| assignments[q].len())
                .expect("nonempty");
            let doc = assignments[donor].pop().expect("donor has docs");
            assignments[p].push(doc);
        }
    }

    assignments
        .into_iter()
        .enumerate()
        .map(|(p, ids)| Shard::from_docs(p, ids, corpus))
        .collect()
}

/// Label-skew measure: mean total-variation distance between each shard's
/// topic distribution and the global one (0 = IID).
pub fn skew_tv(shards: &[Shard]) -> f64 {
    let n_topics = shards[0].topic_counts.len();
    let mut global = vec![0.0f64; n_topics];
    for s in shards {
        for (g, &c) in global.iter_mut().zip(&s.topic_counts) {
            *g += c as f64;
        }
    }
    let total: f64 = global.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    for g in &mut global {
        *g /= total;
    }
    let mut tv_sum = 0.0;
    for s in shards {
        let local_total: f64 = s.topic_counts.iter().map(|&c| c as f64).sum();
        if local_total == 0.0 {
            continue;
        }
        let tv: f64 = s
            .topic_counts
            .iter()
            .zip(&global)
            .map(|(&c, &g)| (c as f64 / local_total - g).abs())
            .sum::<f64>()
            / 2.0;
        tv_sum += tv;
    }
    tv_sum / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::generate(&CorpusConfig {
            n_docs: 120,
            doc_sentences: 4,
            n_topics: 6,
            seed: 7,
        })
    }

    #[test]
    fn equal_shards_cover_all_docs() {
        let c = corpus();
        let shards = equal_shards(&c, 3);
        assert_eq!(shards.len(), 3);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.doc_ids.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..120).collect::<Vec<_>>());
        // balanced
        for s in &shards {
            assert_eq!(s.doc_ids.len(), 40);
        }
        // near-IID
        assert!(skew_tv(&shards) < 0.05, "tv={}", skew_tv(&shards));
    }

    #[test]
    fn weighted_shards_respect_weights() {
        let c = corpus();
        let shards = weighted_shards(&c, &[3.0, 1.0]);
        assert_eq!(shards[0].doc_ids.len(), 90);
        assert_eq!(shards[1].doc_ids.len(), 30);
        let total: usize = shards.iter().map(|s| s.doc_ids.len()).sum();
        assert_eq!(total, 120);
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed_high_alpha_is_not() {
        let c = corpus();
        let skewed = dirichlet_shards(&c, 3, 0.1, 42);
        let iid = dirichlet_shards(&c, 3, 100.0, 42);
        assert!(
            skew_tv(&skewed) > skew_tv(&iid) + 0.1,
            "skewed={} iid={}",
            skew_tv(&skewed),
            skew_tv(&iid)
        );
        // all docs assigned exactly once
        let mut all: Vec<usize> =
            skewed.iter().flat_map(|s| s.doc_ids.clone()).collect();
        all.sort_unstable();
        assert_eq!(all.len(), 120);
        all.dedup();
        assert_eq!(all.len(), 120);
    }

    #[test]
    fn dirichlet_no_empty_shards() {
        let c = corpus();
        for seed in 0..10 {
            let shards = dirichlet_shards(&c, 5, 0.05, seed);
            for s in &shards {
                assert!(!s.doc_ids.is_empty(), "seed={seed}");
                assert!(s.n_tokens() > 0);
            }
        }
    }

    #[test]
    fn shards_tokenize() {
        let c = corpus();
        let shards = equal_shards(&c, 2);
        for s in &shards {
            assert!(s.n_tokens() > 100);
            assert!(s.tokens.iter().all(|&t| (0..96).contains(&t)));
        }
    }

    #[test]
    fn deterministic_dirichlet() {
        let c = corpus();
        let a = dirichlet_shards(&c, 3, 0.3, 5);
        let b = dirichlet_shards(&c, 3, 0.3, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.doc_ids, y.doc_ids);
        }
    }
}

//! Char-level tokenizer over a fixed printable-ASCII alphabet.
//!
//! Char-level keeps the vocab at 96 (matching the AOT model presets) and
//! needs no learned merges, so the rust and python sides can never
//! disagree about token ids.

/// Vocabulary: byte 32..=126 (95 printable ASCII chars) + '\n' as id 95.
#[derive(Clone, Copy, Debug, Default)]
pub struct CharTokenizer;

pub const VOCAB_SIZE: usize = 96;
const NEWLINE_ID: i32 = 95;

impl CharTokenizer {
    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    /// Encode text; unknown bytes map to ' ' (id 0).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes()
            .map(|b| match b {
                32..=126 => (b - 32) as i32,
                b'\n' => NEWLINE_ID,
                _ => 0,
            })
            .collect()
    }

    /// Decode ids back to text (inverse of encode for valid ids).
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| match id {
                0..=94 => (id as u8 + 32) as char,
                95 => '\n',
                _ => '?',
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_printable() {
        let t = CharTokenizer;
        let text = "Hello, cross-cloud federated training! 123\nnew line";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn ids_in_range() {
        let t = CharTokenizer;
        for id in t.encode("any text ~ { } | \n") {
            assert!((0..VOCAB_SIZE as i32).contains(&id));
        }
    }

    #[test]
    fn unknown_bytes_become_space() {
        let t = CharTokenizer;
        let ids = t.encode("a\tb");
        assert_eq!(t.decode(&ids), "a b");
    }

    #[test]
    fn vocab_matches_model_presets() {
        // python/compile/model.py presets use vocab_size=96
        assert_eq!(CharTokenizer.vocab_size(), 96);
    }
}

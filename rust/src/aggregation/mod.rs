//! Model aggregation algorithms — the paper's §3.3, formulas (1)–(4).
//!
//! * [`FedAvg`] — formula (1): sample-count weighted parameter average.
//! * [`DynamicWeighted`] — formula (2): α_i = softmax(−L_i) performance
//!   weighting.
//! * [`GradientAgg`] — formula (3): aggregate gradients, apply through a
//!   server optimizer.
//! * [`AsyncAgg`] — formula (4): per-arrival mixing
//!   w ← w + α_i (w_i − w), with staleness-discounted α.
//!
//! All aggregators consume [`ClientUpdate`]s whose `delta` field carries
//! either the parameter delta (w_i − w^t) or the accumulated local
//! gradient, depending on [`UpdateKind`]. Operating on deltas makes the
//! three synchronous algorithms directly comparable and keeps secure
//! aggregation (sums of masked deltas) compatible with all of them.
//!
//! [`HierarchicalAggregator`] factors the synchronous algorithms into a
//! per-cloud gateway reduce plus a cross-cloud leader reduce (see
//! [`hierarchy`]), so only one partial aggregate per cloud crosses the
//! inter-region WAN.

mod algorithms;
pub mod hierarchy;

pub use algorithms::{
    build, AggregationKind, Aggregator, AsyncAgg, ClientUpdate,
    DynamicWeighted, FedAvg, GradientAgg, UpdateKind,
};
pub use hierarchy::{HierarchicalAggregator, PartialAggregate};

//! Two-level (hierarchical) aggregation: reduce inside each cloud at its
//! WAN gateway, then reduce the per-cloud partials at the leader.
//!
//! The per-worker weights of the synchronous algorithms all factor as
//! `α_i = w_i / Σ_j w_j` for a *raw weight* `w_i` that depends only on
//! worker-local quantities:
//!
//! * FedAvg (formula 1) and gradient aggregation (formula 3):
//!   `w_i = n_i` (sample count);
//! * dynamic weighting (formula 2): `w_i = exp(−L_i/τ)`.
//!
//! So a gateway can compute the *weighted mean* of its members'
//! updates, `P_c = Σ_{i∈c} w_i Δ_i / z_c` with `z_c = Σ_{i∈c} w_i`, and
//! ship only `(P_c, z_c)` over the WAN; the leader recombines
//! `Σ_c (z_c / Z) P_c` with `Z = Σ_c z_c`, which equals the flat
//! single-level aggregate exactly (in real arithmetic — floating-point
//! summation order differs, so tests compare with tolerance). Shipping
//! the *normalized* partial keeps magnitudes in the same range as a
//! single worker's update, so the lossy codecs stay in their calibrated
//! regime.
//!
//! Async aggregation (formula 4) applies updates on arrival and has no
//! barrier to factor across, so the barrier reduces below do not apply
//! to it. Hierarchical async instead runs FedBuff-style *buffered*
//! aggregation: each gateway scales member updates by the staleness
//! mixing rate ([`HierarchicalAggregator::mixing_rate`]) as they arrive
//! and buffers the running sum; the leader consumes the buffered
//! cloud-level updates on arrival (`coordinator/run_buffered.rs`).
//!
//! Numerical stability of dynamic weights: member weights inside a cloud
//! are computed with the cloud's min-loss shift (exact — the shift
//! cancels in the within-cloud normalization), and the recombination
//! weight `z_c = exp(−lo_c/τ) · Σ exp(−(L_i−lo_c)/τ)` carries the
//! absolute scale with its exponent clamped to ±700, so extreme `|L|/τ`
//! degrades gracefully instead of under/overflowing to a panic. Within
//! the clamp range the two-level reduce equals the flat softmax exactly
//! (in real arithmetic).

use anyhow::Result;

use crate::aggregation::{AggregationKind, ClientUpdate};
use crate::model::ParamSet;
use crate::optimizer::Optimizer;

/// One cloud's reduced contribution: the weighted mean of its members'
/// updates plus the metadata the leader needs to recombine exactly.
#[derive(Clone, Debug)]
pub struct PartialAggregate {
    pub cloud: usize,
    /// number of member updates reduced into this partial
    pub n_members: usize,
    /// Σ n_i over members (FedAvg bookkeeping / diagnostics)
    pub n_samples: usize,
    /// z_c = Σ w_i over members — the partial's recombination weight
    pub weight: f64,
    /// weight-weighted mean member loss (diagnostics)
    pub mean_loss: f32,
    /// P_c = Σ w_i Δ_i / z_c — normalized weighted mean update
    pub delta: ParamSet,
}

/// Two-level reducer for the synchronous aggregation algorithms.
pub struct HierarchicalAggregator {
    kind: AggregationKind,
    /// server optimizer (gradient mode only; owns momentum state)
    server_opt: Optimizer,
}

impl HierarchicalAggregator {
    /// Synchronous kinds use the two-level barrier reduce
    /// ([`HierarchicalAggregator::reduce_cloud`] /
    /// [`HierarchicalAggregator::reduce_global`]);
    /// [`AggregationKind::Async`] uses the buffered gateway path
    /// ([`HierarchicalAggregator::mixing_rate`]) instead.
    pub fn new(kind: AggregationKind, server_opt: Optimizer) -> Result<HierarchicalAggregator> {
        Ok(HierarchicalAggregator { kind, server_opt })
    }

    pub fn kind(&self) -> AggregationKind {
        self.kind
    }

    /// FedBuff gateway mixing rate for buffered-async mode:
    /// `α₀ / (1 + staleness)` — the same staleness discount the leader's
    /// [`crate::aggregation::AsyncAgg`] applies to cloud-level updates,
    /// here applied per member update as it reaches the gateway buffer.
    /// Only defined for [`AggregationKind::Async`].
    pub fn mixing_rate(&self, staleness: u64) -> f32 {
        match self.kind {
            AggregationKind::Async { alpha } => alpha / (1.0 + staleness as f32),
            _ => panic!("mixing_rate is only defined for buffered async"),
        }
    }

    /// Snapshot the server optimizer (the only cross-round state) for
    /// the WAL.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        self.server_opt.wal_encode(w);
    }

    /// Restore state written by [`HierarchicalAggregator::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> Result<()> {
        self.server_opt.wal_decode(r)
    }

    /// Per-member weights for the within-cloud mean, plus the partial's
    /// recombination weight on the absolute scale. Dynamic weights are
    /// min-loss-shifted (exact inside the cloud); the absolute scale's
    /// exponent is clamped so pathological `|L|/τ` never panics.
    fn member_weights(&self, updates: &[ClientUpdate]) -> (Vec<f64>, f64) {
        match self.kind {
            AggregationKind::FedAvg | AggregationKind::GradientAgg => {
                let ws: Vec<f64> =
                    updates.iter().map(|u| u.n_samples as f64).collect();
                let z = ws.iter().sum();
                (ws, z)
            }
            AggregationKind::DynamicWeighted { temperature } => {
                let t = (temperature as f64).max(1e-6);
                let lo = updates
                    .iter()
                    .map(|u| u.local_loss as f64)
                    .fold(f64::INFINITY, f64::min);
                let ws: Vec<f64> = updates
                    .iter()
                    .map(|u| (-(u.local_loss as f64 - lo) / t).exp())
                    .collect();
                // the min-loss member contributes exp(0) = 1, so this
                // sum is always in [1, n] — never degenerate
                let z_shifted: f64 = ws.iter().sum();
                let scale = (-lo / t).clamp(-700.0, 700.0).exp();
                (ws, z_shifted * scale)
            }
            AggregationKind::Async { .. } => {
                panic!("async uses the buffered gateway path, not the barrier reduce")
            }
        }
    }

    /// Gateway-side reduce: weighted mean of one cloud's member updates
    /// (one fused `axpy_many` pass over the model).
    pub fn reduce_cloud(&self, cloud: usize, updates: &[ClientUpdate]) -> PartialAggregate {
        assert!(!updates.is_empty(), "cloud {cloud} reduced without updates");
        let (weights, partial_weight) = self.member_weights(updates);
        let z: f64 = weights.iter().sum();
        assert!(z > 0.0 && z.is_finite(), "degenerate cloud weight z={z}");
        assert!(
            partial_weight > 0.0 && partial_weight.is_finite(),
            "degenerate partial weight {partial_weight}"
        );
        let terms: Vec<(f32, &ParamSet)> = updates
            .iter()
            .zip(&weights)
            .map(|(u, &w)| ((w / z) as f32, &u.delta))
            .collect();
        let mut delta = ParamSet {
            leaves: updates[0]
                .delta
                .leaves
                .iter()
                .map(|l| vec![0.0; l.len()])
                .collect(),
        };
        delta.axpy_many(&terms);
        let mean_loss = updates
            .iter()
            .zip(&weights)
            .map(|(u, &w)| u.local_loss as f64 * w / z)
            .sum::<f64>() as f32;
        PartialAggregate {
            cloud,
            n_members: updates.len(),
            n_samples: updates.iter().map(|u| u.n_samples).sum(),
            weight: partial_weight,
            mean_loss,
            delta,
        }
    }

    /// Leader-side reduce: recombine the per-cloud partials into the
    /// global model. `partials` may carry codec-lossy deltas — whatever
    /// actually crossed the WAN.
    pub fn reduce_global(&mut self, global: &mut ParamSet, partials: &[PartialAggregate]) {
        assert!(!partials.is_empty());
        let z_total: f64 = partials.iter().map(|p| p.weight).sum();
        assert!(
            z_total > 0.0 && z_total.is_finite(),
            "degenerate global weight Z={z_total}"
        );
        let terms: Vec<(f32, &ParamSet)> = partials
            .iter()
            .map(|p| ((p.weight / z_total) as f32, &p.delta))
            .collect();
        match self.kind {
            AggregationKind::FedAvg | AggregationKind::DynamicWeighted { .. } => {
                global.axpy_many(&terms);
            }
            AggregationKind::GradientAgg => {
                let mut agg = ParamSet {
                    leaves: global.leaves.iter().map(|l| vec![0.0; l.len()]).collect(),
                };
                agg.axpy_many(&terms);
                self.server_opt.step(global, &agg);
            }
            AggregationKind::Async { .. } => {
                panic!("async uses the buffered gateway path, not the barrier reduce")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{build, Aggregator};
    use crate::optimizer::OptimizerKind;
    use crate::util::rng::Pcg64;

    fn opt() -> Optimizer {
        Optimizer::new(OptimizerKind::Sgd, 0.5)
    }

    fn updates(n: usize, dim: usize, seed: u64) -> Vec<ClientUpdate> {
        let mut rng = Pcg64::new(seed, 0);
        (0..n)
            .map(|w| ClientUpdate {
                worker: w,
                n_samples: 500 + 137 * w,
                local_loss: 1.0 + 0.3 * w as f32,
                delta: ParamSet {
                    leaves: vec![(0..dim)
                        .map(|_| rng.normal_ms(0.0, 0.1) as f32)
                        .collect()],
                },
                staleness: 0,
            })
            .collect()
    }

    /// Two-level reduce over arbitrary groupings must match the flat
    /// aggregate (same math, different summation order).
    fn assert_matches_flat(kind: AggregationKind, groups: &[&[usize]]) {
        let us = updates(6, 64, 9);
        // flat reference
        let mut flat = ParamSet { leaves: vec![vec![0.5; 64]] };
        let mut reference = build(kind, opt());
        reference.aggregate(&mut flat, &us);
        // hierarchical
        let mut hier_global = ParamSet { leaves: vec![vec![0.5; 64]] };
        let mut hier = HierarchicalAggregator::new(kind, opt()).unwrap();
        let partials: Vec<PartialAggregate> = groups
            .iter()
            .enumerate()
            .map(|(c, g)| {
                let members: Vec<ClientUpdate> =
                    g.iter().map(|&i| us[i].clone()).collect();
                hier.reduce_cloud(c, &members)
            })
            .collect();
        hier.reduce_global(&mut hier_global, &partials);
        let diff = flat.sub(&hier_global).l2_norm();
        assert!(diff < 1e-5, "{kind:?} {groups:?}: diff={diff}");
    }

    #[test]
    fn fedavg_two_level_matches_flat() {
        assert_matches_flat(AggregationKind::FedAvg, &[&[0, 1], &[2, 3], &[4, 5]]);
        assert_matches_flat(AggregationKind::FedAvg, &[&[0], &[1, 2, 3, 4, 5]]);
    }

    #[test]
    fn dynamic_two_level_matches_flat() {
        let kind = AggregationKind::DynamicWeighted { temperature: 1.0 };
        assert_matches_flat(kind, &[&[0, 1, 2], &[3, 4, 5]]);
        let sharp = AggregationKind::DynamicWeighted { temperature: 0.5 };
        assert_matches_flat(sharp, &[&[0, 4], &[1, 3], &[2, 5]]);
    }

    #[test]
    fn gradient_two_level_matches_flat() {
        assert_matches_flat(AggregationKind::GradientAgg, &[&[0, 1], &[2, 3, 4, 5]]);
    }

    #[test]
    fn sharp_temperature_does_not_underflow() {
        // exp(-L/tau) underflows f64 to 0.0 at |L|/tau > ~745; the
        // shifted member weights + clamped scale must keep reducing
        // instead of panicking, and still favor the best cloud
        let kind = AggregationKind::DynamicWeighted { temperature: 0.005 };
        let mut us = updates(4, 8, 2);
        for (i, u) in us.iter_mut().enumerate() {
            u.local_loss = 4.0 + 0.5 * i as f32; // -L/tau down to -1100
        }
        let hier = HierarchicalAggregator::new(kind, opt()).unwrap();
        let a = hier.reduce_cloud(0, &us[..2]);
        let b = hier.reduce_cloud(1, &us[2..]);
        assert!(a.weight > 0.0 && a.weight.is_finite());
        assert!(b.weight > 0.0 && b.weight.is_finite());
        // cloud 0 holds the min-loss member: it must dominate or at
        // least not lose to cloud 1 after clamping
        assert!(a.weight >= b.weight);
        let mut g = ParamSet { leaves: vec![vec![0.0; 8]] };
        let mut hier = HierarchicalAggregator::new(kind, opt()).unwrap();
        hier.reduce_global(&mut g, &[a, b]);
        assert!(g.leaves[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn async_uses_the_buffered_mixing_path() {
        let hier =
            HierarchicalAggregator::new(AggregationKind::Async { alpha: 0.6 }, opt())
                .unwrap();
        assert!((hier.mixing_rate(0) - 0.6).abs() < 1e-6);
        assert!((hier.mixing_rate(2) - 0.2).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "buffered gateway path")]
    fn async_rejects_the_barrier_reduce() {
        let hier =
            HierarchicalAggregator::new(AggregationKind::Async { alpha: 0.6 }, opt())
                .unwrap();
        hier.reduce_cloud(0, &updates(2, 8, 1));
    }

    #[test]
    #[should_panic(expected = "only defined for buffered async")]
    fn sync_kinds_have_no_mixing_rate() {
        let hier =
            HierarchicalAggregator::new(AggregationKind::FedAvg, opt()).unwrap();
        hier.mixing_rate(0);
    }

    #[test]
    fn partial_metadata_is_consistent() {
        let us = updates(3, 16, 4);
        let hier = HierarchicalAggregator::new(AggregationKind::FedAvg, opt()).unwrap();
        let p = hier.reduce_cloud(7, &us);
        assert_eq!(p.cloud, 7);
        assert_eq!(p.n_members, 3);
        assert_eq!(p.n_samples, us.iter().map(|u| u.n_samples).sum::<usize>());
        assert!((p.weight - p.n_samples as f64).abs() < 1e-9);
        // normalized partial has single-update magnitude
        let max_member = us.iter().map(|u| u.delta.l2_norm()).fold(0.0, f64::max);
        assert!(p.delta.l2_norm() <= max_member * 1.5);
        // mean loss lies inside the members' range
        assert!(p.mean_loss >= 1.0 && p.mean_loss <= 1.6);
    }

    #[test]
    fn single_cloud_degenerates_to_flat() {
        let us = updates(4, 32, 11);
        let mut a = ParamSet { leaves: vec![vec![0.0; 32]] };
        let mut b = a.clone();
        let mut hier =
            HierarchicalAggregator::new(AggregationKind::FedAvg, opt()).unwrap();
        let p = hier.reduce_cloud(0, &us);
        hier.reduce_global(&mut a, &[p]);
        let mut flat = build(AggregationKind::FedAvg, opt());
        flat.aggregate(&mut b, &us);
        assert!(a.sub(&b).l2_norm() < 1e-6);
    }
}

//! The four aggregation algorithms.

use crate::model::ParamSet;
use crate::optimizer::Optimizer;

/// What a worker's `delta` payload means.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    /// w_i − w^t : parameter delta after E local steps
    ParamDelta,
    /// mean local gradient over the round (formula 3's ∇w_i)
    Gradient,
}

/// One worker's contribution to a round.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub worker: usize,
    /// n_i — local sample count (FedAvg weights, formula 1)
    pub n_samples: usize,
    /// L_i — local training loss this round (dynamic weights, formula 2)
    pub local_loss: f32,
    /// the update payload (delta or gradient per [`UpdateKind`])
    pub delta: ParamSet,
    /// rounds elapsed since this worker's base model (async staleness)
    pub staleness: u64,
}

/// Aggregation algorithm selector (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregationKind {
    FedAvg,
    DynamicWeighted { temperature: f32 },
    GradientAgg,
    Async { alpha: f32 },
}

impl AggregationKind {
    pub fn name(&self) -> &'static str {
        match self {
            AggregationKind::FedAvg => "fedavg",
            AggregationKind::DynamicWeighted { .. } => "dynamic",
            AggregationKind::GradientAgg => "gradient",
            AggregationKind::Async { .. } => "async",
        }
    }

    pub fn parse(s: &str) -> Option<AggregationKind> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Some(AggregationKind::FedAvg),
            "dynamic" => Some(AggregationKind::DynamicWeighted { temperature: 1.0 }),
            "gradient" => Some(AggregationKind::GradientAgg),
            "async" => Some(AggregationKind::Async { alpha: 0.6 }),
            _ => None,
        }
    }

    /// Which payload the workers must produce for this aggregator.
    pub fn update_kind(&self) -> UpdateKind {
        match self {
            AggregationKind::GradientAgg => UpdateKind::Gradient,
            _ => UpdateKind::ParamDelta,
        }
    }
}

/// Common interface. `aggregate` mutates the global model in place.
pub trait Aggregator: Send {
    fn name(&self) -> &'static str;
    /// Synchronous round aggregation over all updates.
    fn aggregate(&mut self, global: &mut ParamSet, updates: &[ClientUpdate]);
    /// Asynchronous single-update application (default: unsupported).
    fn apply_one(&mut self, _global: &mut ParamSet, _update: &ClientUpdate) {
        panic!("{} is a synchronous aggregator", self.name());
    }
    fn is_async(&self) -> bool {
        false
    }
    /// Snapshot mutable aggregator state for the WAL. Default: stateless
    /// (FedAvg / dynamic / async keep nothing between rounds).
    fn wal_encode(&self, _w: &mut crate::wal::ByteWriter) {}
    /// Restore state written by [`Aggregator::wal_encode`].
    fn wal_decode(
        &mut self,
        _r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// formula (1): FedAvg
// ---------------------------------------------------------------------------

/// w = Σ_i (n_i / n) w_i, applied in delta form: w += Σ (n_i/n) Δ_i.
#[derive(Clone, Debug, Default)]
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate(&mut self, global: &mut ParamSet, updates: &[ClientUpdate]) {
        assert!(!updates.is_empty());
        let n: f64 = updates.iter().map(|u| u.n_samples as f64).sum();
        assert!(n > 0.0, "fedavg needs positive sample counts");
        // one fused parallel pass over the global model (bit-identical to
        // sequential per-update axpy)
        let terms: Vec<(f32, &ParamSet)> = updates
            .iter()
            .map(|u| ((u.n_samples as f64 / n) as f32, &u.delta))
            .collect();
        global.axpy_many(&terms);
    }
}

// ---------------------------------------------------------------------------
// formula (2): dynamic weighted aggregation
// ---------------------------------------------------------------------------

/// α_i = exp(−L_i/τ) / Σ_j exp(−L_j/τ); w += Σ α_i Δ_i.
///
/// τ (temperature) generalizes the paper's formula (τ=1 reproduces it
/// exactly); lower τ concentrates weight on the best-performing platform.
#[derive(Clone, Debug)]
pub struct DynamicWeighted {
    pub temperature: f32,
}

impl Default for DynamicWeighted {
    fn default() -> Self {
        DynamicWeighted { temperature: 1.0 }
    }
}

impl DynamicWeighted {
    /// The softmax weights for a set of losses (exposed for tests/benches).
    pub fn weights(&self, losses: &[f32]) -> Vec<f32> {
        assert!(!losses.is_empty());
        let t = self.temperature.max(1e-6);
        // subtract min loss for numerical stability (shift-invariant)
        let lo = losses.iter().cloned().fold(f32::INFINITY, f32::min);
        let exps: Vec<f32> =
            losses.iter().map(|&l| (-(l - lo) / t).exp()).collect();
        let z: f32 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }
}

impl Aggregator for DynamicWeighted {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn aggregate(&mut self, global: &mut ParamSet, updates: &[ClientUpdate]) {
        assert!(!updates.is_empty());
        let losses: Vec<f32> = updates.iter().map(|u| u.local_loss).collect();
        let weights = self.weights(&losses);
        let terms: Vec<(f32, &ParamSet)> = updates
            .iter()
            .zip(&weights)
            .map(|(u, &w)| (w, &u.delta))
            .collect();
        global.axpy_many(&terms);
    }
}

// ---------------------------------------------------------------------------
// formula (3): gradient aggregation
// ---------------------------------------------------------------------------

/// w^{t+1} = w^t − η Σ_i (n_i/n) ∇w_i, with the step applied through a
/// server [`Optimizer`] (SGD reproduces the formula verbatim; momentum /
/// Adam are the standard strengthening for heterogeneous clients).
pub struct GradientAgg {
    pub server_opt: Optimizer,
}

impl GradientAgg {
    pub fn new(server_opt: Optimizer) -> GradientAgg {
        GradientAgg { server_opt }
    }
}

impl Aggregator for GradientAgg {
    fn name(&self) -> &'static str {
        "gradient"
    }

    fn aggregate(&mut self, global: &mut ParamSet, updates: &[ClientUpdate]) {
        assert!(!updates.is_empty());
        let n: f64 = updates.iter().map(|u| u.n_samples as f64).sum();
        assert!(n > 0.0);
        // weighted mean gradient, accumulated in one fused parallel pass
        let mut agg = ParamSet {
            leaves: global.leaves.iter().map(|l| vec![0.0; l.len()]).collect(),
        };
        let terms: Vec<(f32, &ParamSet)> = updates
            .iter()
            .map(|u| ((u.n_samples as f64 / n) as f32, &u.delta))
            .collect();
        agg.axpy_many(&terms);
        self.server_opt.step(global, &agg);
    }

    // the server optimizer carries momentum/Adam state across rounds
    fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        self.server_opt.wal_encode(w);
    }

    fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        self.server_opt.wal_decode(r)
    }
}

// ---------------------------------------------------------------------------
// formula (4): asynchronous aggregation
// ---------------------------------------------------------------------------

/// w^{t+1} = w^t + α_i (w_i − w^t), per arriving update. The mixing rate
/// is staleness-discounted: α_i = α₀ / (1 + staleness), the standard
/// fix for stale async updates (Xie et al., FedAsync).
#[derive(Clone, Debug)]
pub struct AsyncAgg {
    pub alpha0: f32,
}

impl Default for AsyncAgg {
    fn default() -> Self {
        AsyncAgg { alpha0: 0.6 }
    }
}

impl AsyncAgg {
    pub fn mixing_rate(&self, staleness: u64) -> f32 {
        self.alpha0 / (1.0 + staleness as f32)
    }
}

impl Aggregator for AsyncAgg {
    fn name(&self) -> &'static str {
        "async"
    }

    fn aggregate(&mut self, global: &mut ParamSet, updates: &[ClientUpdate]) {
        // applying a batch sequentially is well-defined (arrival order)
        for u in updates {
            self.apply_one(global, u);
        }
    }

    fn apply_one(&mut self, global: &mut ParamSet, update: &ClientUpdate) {
        // update.delta is (w_i − w_base); relative to the *current* global
        // this is an approximation whose error the staleness discount
        // bounds — exactly the trade the paper describes for async mode.
        global.axpy(self.mixing_rate(update.staleness), &update.delta);
    }

    fn is_async(&self) -> bool {
        true
    }
}

/// Factory from the config enum.
pub fn build(kind: AggregationKind, server_opt: Optimizer) -> Box<dyn Aggregator> {
    match kind {
        AggregationKind::FedAvg => Box::new(FedAvg),
        AggregationKind::DynamicWeighted { temperature } => {
            Box::new(DynamicWeighted { temperature })
        }
        AggregationKind::GradientAgg => Box::new(GradientAgg::new(server_opt)),
        AggregationKind::Async { alpha } => Box::new(AsyncAgg { alpha0: alpha }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::OptimizerKind;

    fn ps(vals: &[f32]) -> ParamSet {
        ParamSet { leaves: vec![vals.to_vec()] }
    }

    fn upd(worker: usize, n: usize, loss: f32, delta: &[f32]) -> ClientUpdate {
        ClientUpdate {
            worker,
            n_samples: n,
            local_loss: loss,
            delta: ps(delta),
            staleness: 0,
        }
    }

    #[test]
    fn fedavg_weights_by_sample_count() {
        // formula 1: with deltas [1,0] (n=3) and [0,1] (n=1):
        // w += 0.75*[1,0] + 0.25*[0,1]
        let mut g = ps(&[0.0, 0.0]);
        FedAvg.aggregate(&mut g, &[
            upd(0, 3, 1.0, &[1.0, 0.0]),
            upd(1, 1, 1.0, &[0.0, 1.0]),
        ]);
        assert!((g.leaves[0][0] - 0.75).abs() < 1e-6);
        assert!((g.leaves[0][1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn fedavg_equal_samples_is_plain_mean() {
        let mut g = ps(&[10.0]);
        FedAvg.aggregate(&mut g, &[
            upd(0, 5, 0.0, &[2.0]),
            upd(1, 5, 0.0, &[4.0]),
        ]);
        assert!((g.leaves[0][0] - 13.0).abs() < 1e-6);
    }

    #[test]
    fn dynamic_weights_are_softmax_of_neg_loss() {
        // formula 2 at τ=1: losses [0, ln 3] -> weights [3/4, 1/4]
        let dw = DynamicWeighted::default();
        let w = dw.weights(&[0.0, (3.0f32).ln()]);
        assert!((w[0] - 0.75).abs() < 1e-5, "{w:?}");
        assert!((w[1] - 0.25).abs() < 1e-5);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dynamic_favors_low_loss_platform() {
        let mut g = ps(&[0.0]);
        DynamicWeighted::default().aggregate(&mut g, &[
            upd(0, 1, 0.5, &[1.0]),  // good model
            upd(1, 1, 5.0, &[-1.0]), // bad model
        ]);
        assert!(g.leaves[0][0] > 0.9, "g={}", g.leaves[0][0]);
    }

    #[test]
    fn dynamic_equal_losses_is_uniform() {
        let dw = DynamicWeighted::default();
        let w = dw.weights(&[2.0, 2.0, 2.0]);
        for x in w {
            assert!((x - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn dynamic_is_shift_invariant_and_stable() {
        let dw = DynamicWeighted::default();
        let a = dw.weights(&[1.0, 2.0]);
        let b = dw.weights(&[101.0, 102.0]); // huge losses must not NaN
        assert!((a[0] - b[0]).abs() < 1e-6);
        assert!(b.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn temperature_sharpens() {
        let sharp = DynamicWeighted { temperature: 0.1 }.weights(&[1.0, 2.0]);
        let soft = DynamicWeighted { temperature: 10.0 }.weights(&[1.0, 2.0]);
        assert!(sharp[0] > 0.99);
        assert!(soft[0] < 0.6);
    }

    #[test]
    fn gradient_agg_formula3_with_sgd() {
        // w^{t+1} = w^t − η Σ (n_i/n) g_i
        let mut g = ps(&[1.0, 1.0]);
        let mut agg = GradientAgg::new(Optimizer::new(OptimizerKind::Sgd, 0.5));
        agg.aggregate(&mut g, &[
            upd(0, 1, 0.0, &[2.0, 0.0]),
            upd(1, 1, 0.0, &[0.0, 4.0]),
        ]);
        // mean grad = [1, 2]; w = [1,1] - 0.5*[1,2] = [0.5, 0.0]
        assert!((g.leaves[0][0] - 0.5).abs() < 1e-6);
        assert!((g.leaves[0][1] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn async_formula4_mixing() {
        // w ← w + α (w_i − w); with w=0, delta=1, α=0.6
        let mut g = ps(&[0.0]);
        let mut a = AsyncAgg::default();
        a.apply_one(&mut g, &upd(0, 1, 0.0, &[1.0]));
        assert!((g.leaves[0][0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn async_staleness_discount() {
        let a = AsyncAgg { alpha0: 0.8 };
        assert!((a.mixing_rate(0) - 0.8).abs() < 1e-6);
        assert!((a.mixing_rate(3) - 0.2).abs() < 1e-6);
        let mut g = ps(&[0.0]);
        let mut agg = AsyncAgg { alpha0: 0.8 };
        let mut u = upd(0, 1, 0.0, &[1.0]);
        u.staleness = 7;
        agg.apply_one(&mut g, &u);
        assert!((g.leaves[0][0] - 0.1).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "synchronous")]
    fn sync_aggregators_reject_apply_one() {
        let mut g = ps(&[0.0]);
        FedAvg.apply_one(&mut g, &upd(0, 1, 0.0, &[1.0]));
    }

    #[test]
    fn parse_and_update_kinds() {
        assert_eq!(AggregationKind::parse("fedavg"), Some(AggregationKind::FedAvg));
        assert_eq!(
            AggregationKind::parse("gradient").unwrap().update_kind(),
            UpdateKind::Gradient
        );
        assert_eq!(
            AggregationKind::parse("dynamic").unwrap().update_kind(),
            UpdateKind::ParamDelta
        );
        assert!(AggregationKind::parse("async").unwrap().name() == "async");
        assert_eq!(AggregationKind::parse("median"), None);
    }

    #[test]
    fn convergence_on_heterogeneous_quadratics() {
        // three clients with optima at -1, 0, 2 (weights 1,1,2):
        // weighted optimum = (−1+0+2·2)/4 = 0.75. FedAvg with exact local
        // solves must converge there.
        let optima = [(-1.0f32, 1usize), (0.0, 1), (2.0, 2)];
        let mut w = ps(&[10.0]);
        for _ in 0..60 {
            let updates: Vec<ClientUpdate> = optima
                .iter()
                .enumerate()
                .map(|(i, &(t, n))| {
                    // one local GD step with lr 0.5: delta = 0.5(t − w)
                    let delta = 0.5 * (t - w.leaves[0][0]);
                    upd(i, n, (w.leaves[0][0] - t).abs(), &[delta])
                })
                .collect();
            FedAvg.aggregate(&mut w, &updates);
        }
        assert!((w.leaves[0][0] - 0.75).abs() < 1e-3, "w={}", w.leaves[0][0]);
    }
}

//! Write-ahead log of round-boundary coordinator state.
//!
//! The coordinator appends one checksummed record per (pseudo-)round so a
//! crashed run can resume *bit-identically*: every RNG stream, channel
//! scratch buffer, cost accrual and queued event is restored exactly as it
//! was, and `tests/wal_resume.rs` pins `resumed == uninterrupted` as a
//! bit-equality over loss history, wire-byte splits and dollar streams.
//!
//! ## File format
//!
//! A WAL file is a sequence of records, each framed as
//!
//! ```text
//! [len: u64 LE] [crc32(payload): u32 LE] [payload: len bytes]
//! ```
//!
//! Record 0 is the header (magic, format version, experiment identity);
//! record k (k >= 1) is the state snapshot taken at the end of round k-1.
//! Appends are `write` + `sync_data` before the round is acknowledged, so
//! a crash can only ever lose or tear the *last* record.
//!
//! On open, a record that stops at end-of-file — short frame, short
//! payload, or checksum mismatch on bytes that run exactly to EOF — is a
//! torn tail: it is truncated away and the log stays usable. A checksum
//! mismatch anywhere *before* EOF means the file was corrupted in place
//! and is a hard error, not a truncation.
//!
//! Everything is serialized as little-endian bit patterns (floats via
//! `to_bits`) — never through decimal formatting — so state survives the
//! round-trip bit-for-bit. The CRC32 (IEEE, reflected 0xEDB88320) is
//! hand-rolled to keep the crate dependency-free.

use std::fs::{File, OpenOptions};
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::ParamSet;

/// First bytes of every WAL file.
pub const WAL_MAGIC: &[u8; 8] = b"XFEDWAL1";
/// Bump on any incompatible record-layout change.
/// v2: RoundRecord gained the per-class wire-byte split.
/// v3: parameter snapshots/deltas are stored as delta-varint lossless
/// blobs (see [`crate::compress::lossless`]) instead of raw `u32` words.
pub const WAL_VERSION: u32 = 3;
/// Frame overhead per record (length + checksum).
pub const FRAME_BYTES: u64 = 12;
/// A full parameter snapshot is written every this many records; records
/// in between carry XOR deltas against the previous record's parameters.
pub const SNAPSHOT_EVERY: usize = 8;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320)
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC32 of `data` (same polynomial as zip/zlib/ethernet).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Byte codec
// ---------------------------------------------------------------------------

/// Little-endian binary encoder for WAL payloads.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// f32 as its exact bit pattern.
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// f64 as its exact bit pattern.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn put_opt_f32(&mut self, v: Option<f32>) {
        match v {
            None => self.put_u8(0),
            Some(x) => {
                self.put_u8(1);
                self.put_f32(x);
            }
        }
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            None => self.put_u8(0),
            Some(x) => {
                self.put_u8(1);
                self.put_f64(x);
            }
        }
    }

    /// Four words — the shape of a [`crate::util::rng::Pcg64`] snapshot.
    pub fn put_u64x4(&mut self, v: [u64; 4]) {
        for x in v {
            self.put_u64(x);
        }
    }
}

/// Little-endian binary decoder. Every read is bounds-checked; running
/// past the end is a clean error, never a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "wal: truncated payload (wanted {n} bytes at offset {}, {} left)",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("wal: bad bool byte {other}"),
        }
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        Ok(self.get_u64()? as usize)
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u64()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        Ok(std::str::from_utf8(b).context("wal: non-utf8 string")?.to_string())
    }

    pub fn get_opt_f32(&mut self) -> Result<Option<f32>> {
        Ok(if self.get_u8()? == 1 { Some(self.get_f32()?) } else { None })
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.get_u8()? == 1 { Some(self.get_f64()?) } else { None })
    }

    pub fn get_u64x4(&mut self) -> Result<[u64; 4]> {
        Ok([self.get_u64()?, self.get_u64()?, self.get_u64()?, self.get_u64()?])
    }

    /// All payload bytes must be consumed — leftover bytes mean the
    /// decoder and encoder disagree about the layout.
    pub fn finish(self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("wal: {} undecoded bytes at end of payload", self.remaining());
        }
        Ok(())
    }
}

/// Encode a [`ParamSet`] (leaf-structured f32 bit patterns).
pub fn write_param_set(w: &mut ByteWriter, p: &ParamSet) {
    w.put_u64(p.leaves.len() as u64);
    for leaf in &p.leaves {
        w.put_u64(leaf.len() as u64);
        for &x in leaf {
            w.put_f32(x);
        }
    }
}

/// Decode a [`ParamSet`] written by [`write_param_set`].
pub fn read_param_set(r: &mut ByteReader) -> Result<ParamSet> {
    let n_leaves = r.get_usize()?;
    let mut leaves = Vec::with_capacity(n_leaves);
    for _ in 0..n_leaves {
        let n = r.get_usize()?;
        let mut leaf = Vec::with_capacity(n);
        for _ in 0..n {
            leaf.push(r.get_f32()?);
        }
        leaves.push(leaf);
    }
    Ok(ParamSet { leaves })
}

// ---------------------------------------------------------------------------
// WAL file
// ---------------------------------------------------------------------------

/// Identity of the run a WAL belongs to — checked on resume so a log can
/// never silently restore into a different experiment or model shape.
#[derive(Clone, Debug, PartialEq)]
pub struct WalHeader {
    pub experiment: String,
    pub seed: u64,
    pub n_workers: u32,
    /// per-leaf element counts of the model (shape guard)
    pub leaf_sizes: Vec<u32>,
}

impl WalHeader {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.buf.extend_from_slice(WAL_MAGIC);
        w.put_u32(WAL_VERSION);
        w.put_str(&self.experiment);
        w.put_u64(self.seed);
        w.put_u32(self.n_workers);
        w.put_u64(self.leaf_sizes.len() as u64);
        for &s in &self.leaf_sizes {
            w.put_u32(s);
        }
        w.into_bytes()
    }

    fn decode(payload: &[u8]) -> Result<WalHeader> {
        let mut r = ByteReader::new(payload);
        let magic = r.take(8).context("wal header")?;
        if magic != WAL_MAGIC {
            bail!("wal: bad magic {magic:?} (not a crossfed WAL)");
        }
        let version = r.get_u32()?;
        if version != WAL_VERSION {
            bail!("wal: format version {version} (this build reads {WAL_VERSION})");
        }
        let experiment = r.get_str()?;
        let seed = r.get_u64()?;
        let n_workers = r.get_u32()?;
        let n_leaves = r.get_usize()?;
        let mut leaf_sizes = Vec::with_capacity(n_leaves);
        for _ in 0..n_leaves {
            leaf_sizes.push(r.get_u32()?);
        }
        r.finish()?;
        Ok(WalHeader { experiment, seed, n_workers, leaf_sizes })
    }
}

/// An open write-ahead log. Appends are durable (fsync'd) before they
/// return — a record that `append` acknowledged survives any crash.
pub struct WalFile {
    file: File,
    path: PathBuf,
    /// records written so far, header included
    records: u64,
    bytes: u64,
}

impl WalFile {
    /// Create (truncate) a WAL at `path` and durably write the header.
    pub fn create(path: &Path, header: &WalHeader) -> Result<WalFile> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating WAL dir {dir:?}"))?;
            }
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("creating WAL {path:?}"))?;
        let mut wal =
            WalFile { file, path: path.to_path_buf(), records: 0, bytes: 0 };
        wal.append(&header.encode())?;
        Ok(wal)
    }

    /// Open an existing WAL: validate the header, collect every intact
    /// round record, truncate a torn tail if the last append was cut
    /// short. Returns the log positioned for further appends.
    pub fn open(path: &Path) -> Result<(WalFile, WalHeader, Vec<Vec<u8>>)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening WAL {path:?}"))?;
        let mut raw = Vec::new();
        file.read_to_end(&mut raw).with_context(|| format!("reading WAL {path:?}"))?;

        let mut payloads: Vec<Vec<u8>> = Vec::new();
        let mut offset = 0usize;
        let mut valid_len = 0usize;
        while offset < raw.len() {
            let rest = raw.len() - offset;
            if rest < FRAME_BYTES as usize {
                break; // torn frame at the tail
            }
            let len = u64::from_le_bytes(raw[offset..offset + 8].try_into().unwrap())
                as usize;
            let crc =
                u32::from_le_bytes(raw[offset + 8..offset + 12].try_into().unwrap());
            let body_start = offset + FRAME_BYTES as usize;
            if raw.len() - body_start < len {
                break; // torn payload at the tail
            }
            let payload = &raw[body_start..body_start + len];
            if crc32(payload) != crc {
                if body_start + len == raw.len() {
                    break; // torn tail: record runs to EOF with a bad sum
                }
                bail!(
                    "wal {path:?}: corrupt record {} (checksum mismatch not at \
                     end of file)",
                    payloads.len()
                );
            }
            payloads.push(payload.to_vec());
            offset = body_start + len;
            valid_len = offset;
        }
        if valid_len < raw.len() {
            log::warn!(
                "wal {path:?}: truncating torn tail ({} bytes after record {})",
                raw.len() - valid_len,
                payloads.len().saturating_sub(1),
            );
            file.set_len(valid_len as u64).context("truncating torn WAL tail")?;
            file.sync_data().context("syncing truncated WAL")?;
        }
        if payloads.is_empty() {
            bail!("wal {path:?}: no intact header record");
        }
        let header = WalHeader::decode(&payloads.remove(0))
            .with_context(|| format!("wal {path:?}: header"))?;
        file.seek(SeekFrom::End(0)).context("seeking WAL end")?;
        let records = 1 + payloads.len() as u64;
        let wal = WalFile {
            file,
            path: path.to_path_buf(),
            records,
            bytes: valid_len as u64,
        };
        Ok((wal, header, payloads))
    }

    /// Append one record and fsync before returning — the ack side of
    /// write-ahead logging: the caller may only act on (or report) a
    /// round once its record is durable.
    pub fn append(&mut self, payload: &[u8]) -> Result<()> {
        let mut frame = Vec::with_capacity(FRAME_BYTES as usize + payload.len());
        frame.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        frame.extend_from_slice(&crc32(payload).to_le_bytes());
        frame.extend_from_slice(payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to WAL {:?}", self.path))?;
        self.file
            .sync_data()
            .with_context(|| format!("fsyncing WAL {:?}", self.path))?;
        self.records += 1;
        self.bytes += frame.len() as u64;
        Ok(())
    }

    /// Records written (header included).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Bytes in the log (frames included).
    pub fn len_bytes(&self) -> u64 {
        self.bytes
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// The WAL file for experiment `name` inside `dir`.
pub fn wal_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.wal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> WalHeader {
        WalHeader {
            experiment: "unit".into(),
            seed: 7,
            n_workers: 3,
            leaf_sizes: vec![64, 32],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crossfed-wal-{name}-{}", std::process::id()))
    }

    #[test]
    fn crc32_reference_vector() {
        // the classic check value for this polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn codec_roundtrip_bit_exact() {
        let mut w = ByteWriter::new();
        w.put_u8(9);
        w.put_bool(true);
        w.put_u32(u32::MAX - 1);
        w.put_u64(1 << 63);
        w.put_f32(-0.0);
        w.put_f64(f64::from_bits(0x7ff8_0000_0000_0001)); // a specific NaN
        w.put_str("héllo");
        w.put_opt_f32(None);
        w.put_opt_f64(Some(1.5e-300));
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 9);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u32().unwrap(), u32::MAX - 1);
        assert_eq!(r.get_u64().unwrap(), 1 << 63);
        assert_eq!(r.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_f64().unwrap().to_bits(), 0x7ff8_0000_0000_0001);
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert_eq!(r.get_opt_f32().unwrap(), None);
        assert_eq!(r.get_opt_f64().unwrap(), Some(1.5e-300));
        r.finish().unwrap();
    }

    #[test]
    fn reader_rejects_overrun_and_leftovers() {
        let mut w = ByteWriter::new();
        w.put_u32(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_u64().is_err()); // only 4 bytes there
        let mut r2 = ByteReader::new(&bytes);
        r2.get_u8().unwrap();
        assert!(r2.finish().is_err()); // 3 bytes left over
    }

    #[test]
    fn param_set_roundtrip() {
        let p = ParamSet { leaves: vec![vec![1.5, -2.25, 0.0], vec![], vec![9.0]] };
        let mut w = ByteWriter::new();
        write_param_set(&mut w, &p);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(read_param_set(&mut r).unwrap(), p);
        r.finish().unwrap();
    }

    #[test]
    fn wal_create_append_open_roundtrip() {
        let path = tmp("roundtrip");
        let mut wal = WalFile::create(&path, &header()).unwrap();
        wal.append(b"round-zero").unwrap();
        wal.append(b"round-one").unwrap();
        assert_eq!(wal.records(), 3);
        drop(wal);
        let (wal, h, recs) = WalFile::open(&path).unwrap();
        assert_eq!(h, header());
        assert_eq!(recs, vec![b"round-zero".to_vec(), b"round-one".to_vec()]);
        assert_eq!(wal.records(), 3);
        assert_eq!(
            wal.len_bytes(),
            std::fs::metadata(&path).unwrap().len()
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn append_after_reopen_continues_the_log() {
        let path = tmp("reopen");
        let mut wal = WalFile::create(&path, &header()).unwrap();
        wal.append(b"a").unwrap();
        drop(wal);
        let (mut wal, _, _) = WalFile::open(&path).unwrap();
        wal.append(b"b").unwrap();
        drop(wal);
        let (_, _, recs) = WalFile::open(&path).unwrap();
        assert_eq!(recs, vec![b"a".to_vec(), b"b".to_vec()]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let path = tmp("torn");
        let mut wal = WalFile::create(&path, &header()).unwrap();
        wal.append(b"intact").unwrap();
        wal.append(b"will-be-torn").unwrap();
        drop(wal);
        // tear the last record: chop 5 bytes off the file
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (_, _, recs) = WalFile::open(&path).unwrap();
        assert_eq!(recs, vec![b"intact".to_vec()]);
        // the torn bytes are gone from disk too
        assert!(std::fs::metadata(&path).unwrap().len() < len - 5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_crc_at_tail_is_torn_tail() {
        let path = tmp("tailcrc");
        let mut wal = WalFile::create(&path, &header()).unwrap();
        wal.append(b"good").unwrap();
        wal.append(b"flip").unwrap();
        drop(wal);
        // flip a payload byte of the *last* record
        let mut raw = std::fs::read(&path).unwrap();
        let n = raw.len();
        raw[n - 1] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        let (_, _, recs) = WalFile::open(&path).unwrap();
        assert_eq!(recs, vec![b"good".to_vec()]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mid_file_corruption_is_a_hard_error() {
        let path = tmp("midcrc");
        let mut wal = WalFile::create(&path, &header()).unwrap();
        wal.append(b"first-record").unwrap();
        wal.append(b"second-record").unwrap();
        drop(wal);
        // corrupt the *first* round record's payload, not the tail
        let header_len = header().encode().len();
        let mut raw = std::fs::read(&path).unwrap();
        let idx = FRAME_BYTES as usize + header_len + FRAME_BYTES as usize;
        raw[idx] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        let err = WalFile::open(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt record"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_foreign_files() {
        let path = tmp("foreign");
        std::fs::write(&path, b"this is not a wal at all............").unwrap();
        assert!(WalFile::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}

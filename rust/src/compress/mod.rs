//! Gradient/update compression (§3.2: "Compressing or sparsifying model
//! parameters can significantly reduce the volume of data that needs to
//! be transmitted").
//!
//! All compressors are *real*: they produce actual byte payloads whose
//! lengths feed the communication ledger, and they decompress back into
//! dense vectors the aggregator consumes. Error feedback (Seide et al.)
//! keeps compression from stalling convergence: the residual of each
//! lossy step is added back before the next one.
//!
//! On top of the lossy codecs sits an optional *lossless* byte stage
//! ([`lossless`]): Chimp/Gorilla-style XOR float coding or
//! delta+zigzag+varint over the encoded payload, exact to the bit and
//! applied inside [`Compressor::compress_append`] so every transport
//! frame — uplink, gateway leg, broadcast, serve checkpoint refresh —
//! composes with it transparently.

mod codec;
mod error_feedback;
pub mod lossless;

pub use codec::{CompressedPayload, Compression, Compressor};
pub use error_feedback::ErrorFeedback;
pub use lossless::LosslessStage;

//! Gradient/update compression (§3.2: "Compressing or sparsifying model
//! parameters can significantly reduce the volume of data that needs to
//! be transmitted").
//!
//! All compressors are *real*: they produce actual byte payloads whose
//! lengths feed the communication ledger, and they decompress back into
//! dense vectors the aggregator consumes. Error feedback (Seide et al.)
//! keeps compression from stalling convergence: the residual of each
//! lossy step is added back before the next one.

mod codec;
mod error_feedback;

pub use codec::{CompressedPayload, Compression, Compressor};
pub use error_feedback::ErrorFeedback;
